//! Heterogeneity sweep (the paper's central motivation): how the final
//! loss of CWTM vs LAD-CWTM scales with the data-heterogeneity level σ_H.
//!
//!     cargo run --release --example heterogeneity_sweep
//!
//! Expected shape (paper §VII-A, Fig. 5): the LAD advantage *grows* with
//! σ_H, because robust aggregation alone has a non-diminishing error
//! proportional to the heterogeneity β², while coding divides it by ~d.

use lad::config::{AggregatorKind, AttackKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant, Variant};
use lad::util::csv::CsvWriter;
use lad::util::rng::Rng;

fn main() -> lad::Result<()> {
    let sigmas = [0.0, 0.05, 0.1, 0.2, 0.3, 0.5];
    let mut w = CsvWriter::create(
        "results/heterogeneity_sweep.csv",
        &["sigma_h", "cwtm", "lad_cwtm_d10", "gain"],
    )?;
    println!("{:>8} {:>14} {:>14} {:>8}", "sigma_h", "cwtm", "lad-cwtm(d=10)", "gain");
    for (i, &sigma) in sigmas.iter().enumerate() {
        let mut rng = Rng::new(1000 + i as u64);
        let ds = LinRegDataset::generate(100, 100, sigma, &mut rng);
        let mut base_cfg = TrainConfig::default();
        base_cfg.n_devices = 100;
        base_cfg.n_honest = 80;
        base_cfg.dim = 100;
        base_cfg.iters = 2000;
        base_cfg.lr = 3e-5;
        base_cfg.sigma_h = sigma;
        base_cfg.aggregator = AggregatorKind::Cwtm;
        base_cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
        base_cfg.log_every = 0;

        let mut cwtm_cfg = base_cfg.clone();
        cwtm_cfg.d = 1;
        let mut lad_cfg = base_cfg.clone();
        lad_cfg.d = 10;

        let t1 =
            run_variant(&ds, &Variant { label: "cwtm".into(), cfg: cwtm_cfg, draco_r: None }, 7)?;
        let t2 =
            run_variant(&ds, &Variant { label: "lad".into(), cfg: lad_cfg, draco_r: None }, 7)?;
        let gain = t1.final_loss / t2.final_loss;
        println!(
            "{sigma:>8.2} {:>14.4e} {:>14.4e} {gain:>7.2}x",
            t1.final_loss, t2.final_loss
        );
        w.row(&[sigma, t1.final_loss, t2.final_loss, gain])?;
    }
    w.flush()?;
    println!("\nwritten results/heterogeneity_sweep.csv");
    Ok(())
}
