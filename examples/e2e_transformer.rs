//! END-TO-END driver (DESIGN.md §Experiment index, row "E2E"): train a
//! transformer LM with the complete LAD stack — cyclic gradient coding over
//! heterogeneous corpus shards, sign-flipping Byzantine devices, CWTM-NNM
//! robust aggregation — where EVERY gradient/loss/init is computed by the
//! AOT-compiled JAX artifact through the PJRT runtime. Python is not
//! running anywhere in this process.
//!
//!     make artifacts
//!     cargo run --release --example e2e_transformer -- [--iters N] [--d D]
//!
//! Logs the loss curve and writes results/e2e_transformer.csv; the recorded
//! run lives in EXPERIMENTS.md.

use lad::cli::Args;
use lad::experiments::e2e::{run_default, E2eParams};
use lad::runtime::Runtime;

fn main() -> lad::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let mut p = E2eParams::default();
    p.iters = args.get_usize("iters", p.iters)?;
    p.d = args.get_usize("d", p.d)?;
    p.lr = args.get_f64("lr", p.lr)?;
    p.n_devices = args.get_usize("devices", p.n_devices)?;
    p.n_honest = args.get_usize("honest", p.n_honest)?;
    let art = args.get_str("artifacts", "artifacts");
    args.reject_unknown()?;

    let mut rt = Runtime::load(&art)?;
    let meta = &rt.manifest().entries["transformer_grad"].meta;
    println!(
        "e2e: {}-param transformer (vocab {}, seq {}, batch {}), N={} devices \
         (H={}, d={}), CWTM-NNM vs sign-flip",
        meta["params"], meta["vocab"], meta["seq"], meta["batch"],
        p.n_devices, p.n_honest, p.d
    );
    let trace = run_default(&mut rt, &p)?;
    println!("{}", trace.summary());
    let first = trace.loss.first().copied().unwrap_or(f64::NAN);
    println!(
        "loss: {first:.4} -> {:.4} over {} iters ({:.1}s, {} PJRT executes)",
        trace.final_loss,
        p.iters,
        trace.wall_s,
        p.iters * p.n_devices * p.d + p.iters / p.log_every.max(1)
    );
    std::fs::create_dir_all("results")?;
    trace.save_csv("results/e2e_transformer.csv")?;
    println!("trace written to results/e2e_transformer.csv");
    assert!(
        trace.final_loss < first,
        "training must reduce loss despite the Byzantine devices"
    );
    Ok(())
}
