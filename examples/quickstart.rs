//! Quickstart: train under a sign-flip Byzantine attack with and without
//! cyclic gradient coding, on the paper's §VII linear-regression workload.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts required (native oracle); see `e2e_transformer` for the
//! full AOT/PJRT path.

use lad::aggregation::Cwtm;
use lad::attack::SignFlip;
use lad::compress::Identity;
use lad::config::TrainConfig;
use lad::data::linreg::LinRegDataset;
use lad::grad::NativeLinReg;
use lad::server::trainer::Trainer;
use lad::util::rng::Rng;

fn main() -> lad::Result<()> {
    // 100 devices, 20 Byzantine, heterogeneous subsets (σ_H = 0.3)
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 100;
    cfg.n_honest = 80;
    cfg.dim = 100;
    cfg.iters = 2000;
    cfg.lr = 3e-5;
    cfg.sigma_h = 0.3;
    cfg.log_every = 200;

    let mut rng = Rng::new(7);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let attack = SignFlip { coeff: -2.0 };
    let cwtm = Cwtm::new(0.1);

    println!("== baseline: CWTM without coding (d = 1) ==");
    cfg.d = 1;
    let mut oracle = NativeLinReg::new(ds.clone());
    let mut x0 = vec![0.0f32; cfg.dim];
    let base = Trainer::new(&cfg, &cwtm, &attack, &Identity).run(
        &mut oracle,
        &mut x0,
        "cwtm(d=1)",
        &mut Rng::new(99),
    )?;
    println!("{}", base.summary());

    println!("\n== LAD: CWTM + cyclic gradient coding (d = 10) ==");
    cfg.d = 10;
    let mut oracle = NativeLinReg::new(ds.clone());
    let mut x0 = vec![0.0f32; cfg.dim];
    let lad = Trainer::new(&cfg, &cwtm, &attack, &Identity).run(
        &mut oracle,
        &mut x0,
        "lad-cwtm(d=10)",
        &mut Rng::new(99),
    )?;
    println!("{}", lad.summary());

    let gain = base.final_loss / lad.final_loss;
    println!("\ncyclic coding reduced final loss by {gain:.2}x at 10x compute load");
    assert!(gain > 1.0, "LAD should beat the non-redundant baseline");
    Ok(())
}
