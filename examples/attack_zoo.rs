//! Attack zoo: every implemented Byzantine behaviour against every robust
//! aggregation rule, with and without cyclic coding — the robustness matrix
//! behind the paper's meta-algorithm claim ("LAD can adopt any κ-robust
//! rule").
//!
//!     cargo run --release --example attack_zoo

use lad::config::{AggregatorKind, AttackKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant, Variant};
use lad::util::rng::Rng;

fn main() -> lad::Result<()> {
    let attacks = [
        AttackKind::SignFlip { coeff: -2.0 },
        AttackKind::Alie,
        AttackKind::Ipm { eps: 0.5 },
        AttackKind::Zero,
        AttackKind::Gaussian { std: 100.0 },
        AttackKind::RandomSpike { scale: 1000.0 },
        AttackKind::Mimic,
    ];
    let aggs = [
        AggregatorKind::Mean,
        AggregatorKind::Cwtm,
        AggregatorKind::Median,
        AggregatorKind::GeometricMedian,
        AggregatorKind::MultiKrum,
        AggregatorKind::Faba,
        AggregatorKind::Mcc,
    ];
    let mut rng = Rng::new(3);
    let ds = LinRegDataset::generate(50, 50, 0.3, &mut rng);

    for d in [1usize, 8] {
        println!("\n=== d = {d} ({}) — final loss ===", if d == 1 { "no coding" } else { "LAD" });
        print!("{:<12}", "attack\\agg");
        for a in &aggs {
            print!("{:>12}", a.name());
        }
        println!();
        for atk in &attacks {
            print!("{:<12}", atk.name());
            for agg in &aggs {
                let mut cfg = TrainConfig::default();
                cfg.n_devices = 50;
                cfg.n_honest = 40;
                cfg.d = d;
                cfg.dim = 50;
                cfg.iters = 800;
                cfg.lr = 5e-5;
                cfg.sigma_h = 0.3;
                cfg.aggregator = *agg;
                cfg.attack = *atk;
                cfg.log_every = 0;
                let tr = run_variant(
                    &ds,
                    &Variant { label: "x".into(), cfg, draco_r: None },
                    17,
                )?;
                print!("{:>12.3e}", tr.final_loss);
            }
            println!();
        }
    }
    println!("\nrows: attacks; columns: aggregation rules; lower is better.");
    println!("note how coding (d=8) tightens every robust rule's column.");
    Ok(())
}
