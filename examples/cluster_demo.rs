//! Cluster demo: run the REAL distributed topology (leader + one worker
//! thread per device, message passing over channels — Fig. 1 of the paper)
//! and verify it reaches the same result as the fast central simulation.
//!
//!     cargo run --release --example cluster_demo

use lad::aggregation::Cwtm;
use lad::attack::SignFlip;
use lad::compress::Identity;
use lad::config::TrainConfig;
use lad::data::linreg::LinRegDataset;
use lad::grad::NativeLinReg;
use lad::server::cluster::run_cluster;
use lad::server::trainer::Trainer;
use lad::util::rng::Rng;

fn main() -> lad::Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 32;
    cfg.n_honest = 25;
    cfg.d = 4;
    cfg.dim = 40;
    cfg.iters = 400;
    cfg.lr = 5e-5;
    cfg.sigma_h = 0.3;
    cfg.log_every = 100;

    let mut rng = Rng::new(5);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let cwtm = Cwtm::new(0.1);
    let attack = SignFlip { coeff: -2.0 };

    println!("== threaded cluster: {} worker threads + leader ==", cfg.n_devices);
    let mut x_cluster = vec![0.0f32; cfg.dim];
    let tr_cluster = run_cluster(
        &cfg, &ds, &cwtm, &attack, &Identity, &mut x_cluster, "cluster", &mut Rng::new(77),
    )?;
    println!("{}", tr_cluster.summary());

    println!("\n== central fast-path simulation (same seed) ==");
    let mut oracle = NativeLinReg::new(ds.clone());
    let mut x_central = vec![0.0f32; cfg.dim];
    let tr_central = Trainer::new(&cfg, &cwtm, &attack, &Identity).run(
        &mut oracle,
        &mut x_central,
        "central",
        &mut Rng::new(77),
    )?;
    println!("{}", tr_central.summary());

    let rel = (tr_cluster.final_loss - tr_central.final_loss).abs()
        / tr_central.final_loss.max(1e-12);
    println!("\nfinal-loss relative difference: {rel:.2e}");
    assert!(
        rel < 1e-3,
        "message-passing path must match the central simulation"
    );
    println!("cluster and central paths agree — the fast path is faithful.");
    Ok(())
}
