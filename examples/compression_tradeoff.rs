//! Com-LAD communication/accuracy trade-off: sweep the rand-K sparsity Q̂
//! and report final loss vs total uplink bits — the empirical counterpart
//! of Fig. 2's δ trade-off (δ = Q/Q̂ − 1).
//!
//!     cargo run --release --example compression_tradeoff

use lad::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::common::{run_variant, Variant};
use lad::theory::TheoryParams;
use lad::util::csv::CsvWriter;
use lad::util::rng::Rng;

fn main() -> lad::Result<()> {
    let q = 100usize;
    let ks = [100usize, 50, 30, 15, 5];
    let mut rng = Rng::new(2);
    let ds = LinRegDataset::generate(100, q, 0.3, &mut rng);
    let mut w = CsvWriter::create(
        "results/compression_tradeoff.csv",
        &["q_hat", "delta", "final_loss", "gbits", "theory_eps"],
    )?;
    println!(
        "{:>6} {:>8} {:>14} {:>10} {:>12}",
        "q_hat", "delta", "final_loss", "Gbits", "eps(eq.33)"
    );
    for &k in &ks {
        let mut cfg = TrainConfig::default();
        cfg.n_devices = 100;
        cfg.n_honest = 70;
        cfg.d = 3;
        cfg.dim = q;
        cfg.iters = 3000;
        cfg.lr = 1e-5;
        cfg.sigma_h = 0.3;
        cfg.aggregator = AggregatorKind::Cwtm;
        cfg.nnm = true;
        cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
        cfg.compression =
            if k == q { CompressionKind::None } else { CompressionKind::RandK { k } };
        cfg.log_every = 0;
        let delta = (q as f64 / k as f64) - 1.0;
        let tr = run_variant(
            &ds,
            &Variant { label: format!("q{k}"), cfg, draco_r: None },
            11,
        )?;
        let eps = TheoryParams::new(100, 70, 3)
            .with_kappa(1.5)
            .with_delta(delta)
            .error_term_bigo();
        let gbits = tr.total_bits() as f64 / 1e9;
        println!(
            "{k:>6} {delta:>8.2} {:>14.4e} {gbits:>10.3} {eps:>12.4e}",
            tr.final_loss
        );
        w.row(&[k as f64, delta, tr.final_loss, gbits, eps])?;
    }
    w.flush()?;
    println!("\nsmaller Q_hat => fewer bits but larger delta and loss floor");
    println!("written results/compression_tradeoff.csv");
    Ok(())
}
