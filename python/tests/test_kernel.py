"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and value scales; this is the CORE correctness
signal for the AOT hot path (the same HLO the Rust coordinator executes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import coded_grad as k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), jnp.float32)


def test_tile_divides():
    for n in [1, 2, 7, 100, 128, 130, 256]:
        t = k._tile(n)
        assert n % t == 0
        assert 1 <= t <= 128


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 24),
    q=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
def test_grad_matrix_matches_ref(n, q, seed, scale):
    x = _rand((q,), seed, scale)
    z = _rand((n, q), seed + 1, scale)
    y = _rand((n,), seed + 2, scale)
    got = k.grad_matrix(x, z, y)
    want = ref.grad_matrix_ref(x, z, y)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale**2)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 20),
    kk=st.integers(1, 20),
    q=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_coded_matmul_matches_ref(n, kk, q, seed):
    a = _rand((n, kk), seed)
    g = _rand((kk, q), seed + 1)
    got = k.coded_matmul(a, g)
    want = ref.matmul_ref(a, g)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 16),
    q=st.integers(1, 16),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_full_coded_grad_pipeline(n, q, d, seed):
    """End-to-end eq. (5): cyclic mask with 1/d weights, like the trainer."""
    d = min(d, n)
    x = _rand((q,), seed, 1.0)
    z = _rand((n, q), seed + 1, 10.0)
    y = _rand((n,), seed + 2, 10.0)
    # cyclic assignment mask A[i, (i+j) % n] = 1/d
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(d):
            a[i, (i + j) % n] = 1.0 / d
    a = jnp.asarray(a)
    got = k.coded_grad(x, z, y, a)
    want = ref.coded_grad_ref(x, z, y, a)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-3)


def test_paper_scale_shapes():
    """The exact N=Q=100 shape the artifacts ship with."""
    n = q = 100
    x = _rand((q,), 0)
    z = _rand((n, q), 1, 10.0)
    y = _rand((n,), 2, 10.0)
    a = jnp.abs(_rand((n, n), 3)) / n
    got = k.coded_grad(x, z, y, a)
    want = ref.coded_grad_ref(x, z, y, a)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)
    assert got.shape == (n, q)


def test_dtype_preserved():
    x = _rand((4,), 0)
    z = _rand((6, 4), 1)
    y = _rand((6,), 2)
    assert k.grad_matrix(x, z, y).dtype == jnp.float32


def test_vmem_estimate_sane():
    # paper scale fits very comfortably in a 16 MiB VMEM
    assert k.vmem_estimate_bytes(100, 100) < 1 << 20


@pytest.mark.parametrize("n,q", [(4, 4), (8, 2)])
def test_coded_grad_zero_mask_is_zero(n, q):
    x = _rand((q,), 5)
    z = _rand((n, q), 6)
    y = _rand((n,), 7)
    a = jnp.zeros((n, n), jnp.float32)
    out = k.coded_grad(x, z, y, a)
    np.testing.assert_allclose(out, np.zeros((n, q)), atol=1e-7)
