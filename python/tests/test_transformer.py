"""L2 transformer: shapes, gradient correctness, learnability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import transformer as tf

jax.config.update("jax_platform_name", "cpu")

CFG = tf.TransformerConfig(vocab=16, d_model=32, n_layers=2, n_heads=2, seq_len=12)


def _theta():
    return tf.init_flat(CFG, jax.random.PRNGKey(0))


def _windows(batch=3, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, CFG.vocab, size=(batch, CFG.seq_len + 1)), jnp.int32
    )


def test_param_layout_consistent():
    theta = _theta()
    assert theta.shape == (CFG.n_params,)
    p = tf.unflatten(CFG, theta)
    assert p["tok_emb"].shape == (16, 32)
    assert p["l1.down_w"].shape == (128, 32)
    # round-trip: concatenating the unflattened parts reproduces theta
    flat = jnp.concatenate([p[n].reshape(-1) for n, _ in CFG.param_layout()])
    np.testing.assert_array_equal(flat, theta)


def test_forward_shapes_and_finiteness():
    logits = tf.forward(CFG, _theta(), _windows()[:, :-1])
    assert logits.shape == (3, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform():
    loss = tf.loss_fn(CFG, _theta(), _windows())
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_grad_matches_finite_difference():
    grad_fn = tf.make_grad_fn(CFG)
    theta = _theta()
    w = _windows(batch=2)
    loss, grad = grad_fn(theta, w)
    rng = np.random.default_rng(3)
    idx = rng.integers(0, CFG.n_params, size=5)
    eps = 1e-3
    for i in idx:
        e = jnp.zeros_like(theta).at[i].set(eps)
        fp = tf.loss_fn(CFG, theta + e, w)
        fm = tf.loss_fn(CFG, theta - e, w)
        fd = float((fp - fm) / (2 * eps))
        g = float(grad[i])
        assert abs(fd - g) < 5e-2 * max(abs(fd), abs(g), 1e-2), (i, fd, g)


def test_causality():
    """Changing a future token must not affect earlier logits."""
    theta = _theta()
    w = _windows(batch=1)
    inputs = w[:, :-1]
    logits1 = tf.forward(CFG, theta, inputs)
    perturbed = inputs.at[0, -1].set((inputs[0, -1] + 1) % CFG.vocab)
    logits2 = tf.forward(CFG, theta, perturbed)
    np.testing.assert_allclose(
        logits1[0, :-1], logits2[0, :-1], rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(logits1[0, -1], logits2[0, -1])


def test_few_sgd_steps_reduce_loss():
    """On a deterministic cyclic stream the LM must learn quickly."""
    grad_fn = tf.make_grad_fn(CFG)
    theta = _theta()
    stream = np.arange(400) % CFG.vocab  # perfectly predictable cycle
    rng = np.random.default_rng(0)

    def batch():
        starts = rng.integers(0, len(stream) - CFG.seq_len - 1, size=4)
        return jnp.asarray(
            np.stack([stream[s : s + CFG.seq_len + 1] for s in starts]),
            jnp.int32,
        )

    first = None
    for step in range(30):
        loss, grad = grad_fn(theta, batch())
        if first is None:
            first = float(loss)
        theta = theta - 0.5 * grad
    assert float(loss) < first * 0.7, (first, float(loss))
