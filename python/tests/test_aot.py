"""AOT pipeline: HLO text export + manifest round-trip at small scale."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_small")
    proc = subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out", str(out),
            "--n", "8", "--q", "6",
            "--vocab", "16", "--d-model", "16", "--layers", "1",
            "--heads", "2", "--seq", "8", "--batch", "2",
        ],
        cwd=REPO / "python",
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stderr
    return out


def test_manifest_schema(small_artifacts):
    manifest = json.loads((small_artifacts / "manifest.json").read_text())
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    for name in ["coded_grad", "linreg_grads", "linreg_loss",
                 "transformer_grad", "transformer_loss"]:
        assert name in arts, name
        entry = arts[name]
        assert (small_artifacts / entry["file"]).exists()
        assert entry["inputs"] and "outputs" in entry
    assert arts["coded_grad"]["meta"] == {"n": 8, "q": 6}
    assert arts["coded_grad"]["inputs"][3]["shape"] == [8, 8]
    assert arts["transformer_grad"]["inputs"][1]["dtype"] == "i32"


def test_hlo_text_is_parseable_text(small_artifacts):
    body = (small_artifacts / "coded_grad.hlo.txt").read_text()
    assert body.startswith("HloModule"), body[:50]
    assert "ROOT" in body


def test_transformer_param_count_in_meta(small_artifacts):
    from compile import transformer as tf

    manifest = json.loads((small_artifacts / "manifest.json").read_text())
    meta = manifest["artifacts"]["transformer_grad"]["meta"]
    cfg = tf.TransformerConfig(
        vocab=meta["vocab"], d_model=meta["d_model"],
        n_layers=meta["layers"], n_heads=meta["heads"], seq_len=meta["seq"],
    )
    assert meta["params"] == cfg.n_params
    assert manifest["artifacts"]["transformer_grad"]["inputs"][0]["shape"] == [
        cfg.n_params
    ]
