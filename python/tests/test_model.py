"""L2 linreg graphs: shapes and parity with the oracle/numerics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _fixture(n=10, q=6, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=q), jnp.float32)
    z = jnp.asarray(rng.normal(size=(n, q), scale=10.0), jnp.float32)
    y = jnp.asarray(rng.normal(size=n, scale=10.0), jnp.float32)
    a = jnp.asarray(rng.uniform(size=(n, n)) / n, jnp.float32)
    return x, z, y, a


def test_loss_matches_half_sq_residuals():
    x, z, y, _ = _fixture()
    (loss,) = model.linreg_loss(x, z, y)
    r = np.asarray(z) @ np.asarray(x) - np.asarray(y)
    np.testing.assert_allclose(float(loss), 0.5 * np.sum(r * r), rtol=1e-5)


def test_grads_match_ref_and_numeric():
    x, z, y, _ = _fixture()
    (g,) = model.linreg_grads(x, z, y)
    np.testing.assert_allclose(g, ref.grad_matrix_ref(x, z, y), rtol=1e-4)
    # numeric: d loss / dx = sum of rows of G
    eps = 1e-3
    full = np.asarray(g).sum(axis=0)
    for j in [0, 3, 5]:
        e = jnp.zeros_like(x).at[j].set(eps)
        fp = float(model.linreg_loss(x + e, z, y)[0])
        fm = float(model.linreg_loss(x - e, z, y)[0])
        fd = (fp - fm) / (2 * eps)
        assert abs(fd - full[j]) < 2e-2 * max(abs(fd), 1.0), (j, fd, full[j])


def test_coded_grad_graph_matches_ref():
    x, z, y, a = _fixture()
    (coded,) = model.linreg_coded_grad(x, z, y, a)
    np.testing.assert_allclose(coded, ref.coded_grad_ref(x, z, y, a), rtol=1e-4)


def test_self_check_passes():
    assert model.check_against_ref() < 1e-5
