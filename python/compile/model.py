"""Layer-2 JAX compute graphs for the linear-regression workload (§VII).

Each exported function is jitted and AOT-lowered by aot.py; the `coded_grad`
pipeline calls the Layer-1 Pallas kernels so they lower into the same HLO
module the Rust coordinator executes.
"""

import jax
import jax.numpy as jnp

from .kernels import coded_grad as kernels
from .kernels import ref


@jax.jit
def linreg_loss(x, z, y):
    """F(x) = Σ_k ½(⟨z_k,x⟩ − y_k)² — scalar training loss."""
    return (ref.linreg_loss_ref(x, z, y),)


@jax.jit
def linreg_grads(x, z, y):
    """Per-subset gradient matrix G[k] = ∇f_k(x) via the Pallas row kernel."""
    return (kernels.grad_matrix(x, z, y),)


@jax.jit
def linreg_coded_grad(x, z, y, a):
    """Every device's coded vector (eq. 5): A @ G with both Pallas kernels.

    `a` is the per-iteration assignment mask with rows scaled by 1/d_i —
    built by the Rust coordinator from (Ŝ, T^t, p^t).
    """
    return (kernels.coded_grad(x, z, y, a),)


def check_against_ref(n=16, q=8, seed=0):
    """Quick self-check used by aot.py before exporting (belt & braces —
    the full sweep lives in python/tests/test_kernel.py)."""
    key = jax.random.PRNGKey(seed)
    kx, kz, ky, ka = jax.random.split(key, 4)
    x = jax.random.normal(kx, (q,), jnp.float32)
    z = jax.random.normal(kz, (n, q), jnp.float32) * 10.0
    y = jax.random.normal(ky, (n,), jnp.float32)
    a = jax.random.uniform(ka, (n, n), jnp.float32)
    got = linreg_coded_grad(x, z, y, a)[0]
    want = ref.coded_grad_ref(x, z, y, a)
    err = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
    if err > 1e-5:
        raise AssertionError(f"pallas coded_grad deviates from ref: {err}")
    return err
