"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the L1 kernels are validated against (pytest +
hypothesis) and exactly the math the Rust native oracle implements:

    r      = Z @ x - y                      (residuals)
    G      = r[:, None] * Z                 (per-subset gradients, eq. 4)
    coded  = A @ G                          (eq. 5; A carries the 1/d row
                                             weights of the cyclic mask)
"""

import jax.numpy as jnp


def residuals_ref(x, z, y):
    """r_k = <z_k, x> - y_k."""
    return z @ x - y


def grad_matrix_ref(x, z, y):
    """G[k] = (⟨z_k,x⟩ − y_k)·z_k — the per-subset gradient matrix."""
    r = residuals_ref(x, z, y)
    return r[:, None] * z


def coded_grad_ref(x, z, y, a):
    """coded[i] = Σ_k A[i,k]·∇f_k(x) (A rows pre-scaled by 1/d_i)."""
    return a @ grad_matrix_ref(x, z, y)


def linreg_loss_ref(x, z, y):
    """F(x) = Σ_k ½(⟨z_k,x⟩ − y_k)²."""
    r = residuals_ref(x, z, y)
    return 0.5 * jnp.sum(r * r)


def matmul_ref(a, b):
    """Plain matmul oracle for the tiled Pallas matmul kernel."""
    return a @ b
