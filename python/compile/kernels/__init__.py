"""Layer-1 Pallas kernels + pure-jnp oracles."""

from . import coded_grad, ref  # noqa: F401
