"""Layer-1 Pallas kernels: the coded-gradient hot path.

Two kernels compose into eq. (5):

  * ``grad_matrix``  — G = (Z·x − y) ⊙_rows Z   (residual + outer scale),
    tiled over row blocks so Z streams HBM→VMEM once.
  * ``coded_matmul`` — coded = A·G, a classic MXU-shaped tiled matmul over
    (row, col) output blocks with the full K dimension resident (K = N ≤ a
    VMEM tile for the paper's sizes).

TPU adaptation (DESIGN.md §Hardware-Adaptation): block shapes are chosen as
the largest divisors ≤ 128 so the MXU systolic array sees near-square tiles;
on this CPU testbed the kernels run under ``interpret=True`` (the Mosaic
custom-call is not executable on the CPU PJRT plugin), so we validate
structure + numerics here and estimate MXU utilization analytically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(n: int, target: int = 128) -> int:
    """Largest divisor of n that is <= target (block shapes must tile)."""
    for t in range(min(n, target), 0, -1):
        if n % t == 0:
            return t
    return 1


def grad_matrix(x, z, y, *, interpret: bool = True):
    """G[k] = (⟨z_k, x⟩ − y_k) · z_k via a row-tiled Pallas kernel."""
    n, q = z.shape
    bn = _tile(n)

    def kernel(x_ref, z_ref, y_ref, out_ref):
        zt = z_ref[...]                      # (bn, q) tile in VMEM
        r = zt @ x_ref[...] - y_ref[...]     # per-tile residuals
        out_ref[...] = r[:, None] * zt

    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((q,), lambda i: (0,)),        # x: replicated
            pl.BlockSpec((bn, q), lambda i: (i, 0)),   # Z row tile
            pl.BlockSpec((bn,), lambda i: (i,)),       # y row tile
        ],
        out_specs=pl.BlockSpec((bn, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, q), z.dtype),
        interpret=interpret,
    )(x, z, y)


def coded_matmul(a, g, *, interpret: bool = True):
    """coded = A @ G via an output-tiled Pallas matmul (full-K blocks)."""
    n, k = a.shape
    k2, q = g.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = _tile(n)
    bq = _tile(q)

    def kernel(a_ref, g_ref, out_ref):
        out_ref[...] = a_ref[...] @ g_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(n // bm, q // bq),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),   # A row stripe
            pl.BlockSpec((k, bq), lambda i, j: (0, j)),   # G col stripe
        ],
        out_specs=pl.BlockSpec((bm, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, q), g.dtype),
        interpret=interpret,
    )(a, g)


@functools.partial(jax.jit, static_argnames=("interpret",))
def coded_grad(x, z, y, a, *, interpret: bool = True):
    """Fused eq.-(5) pipeline: coded = A @ ((Z·x − y) ⊙_rows Z)."""
    return coded_matmul(a, grad_matrix(x, z, y, interpret=interpret),
                        interpret=interpret)


def vmem_estimate_bytes(n: int, q: int) -> int:
    """Worst-case VMEM residency of one coded_matmul grid step (f32)."""
    bm, bq = _tile(n), _tile(q)
    return 4 * (bm * n + n * bq + bm * bq)


def mxu_utilization_estimate(n: int, q: int, lane: int = 128) -> float:
    """Fraction of the systolic array busy for the A·G tiles (K = n)."""
    bm, bq = _tile(n), _tile(q)
    return min(bm / lane, 1.0) * min(bq / lane, 1.0) * min(n / lane, 1.0) ** 0
