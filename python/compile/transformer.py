"""Layer-2 JAX transformer LM with a FLAT parameter vector.

The flat layout keeps the Rust interface trivial: the coordinator holds one
Vec<f32> of parameters, and the AOT artifact `transformer_grad` maps
(theta[P], tokens[B, T+1] int32) → (loss[], grad[P]). Decoder-only,
pre-LayerNorm, causal attention, GELU MLP, tied embeddings.

Parameters (per layer): ln1(2dm) attn qkv(dm,3dm)+bias(3dm) proj(dm,dm)+
bias(dm) ln2(2dm) mlp up(dm,4dm)+bias(4dm) down(4dm,dm)+bias(dm);
plus tok_emb(vocab,dm), pos_emb(T,dm), final ln(2dm). Output head is tied
to tok_emb.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 64
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def layer_sizes(self):
        dm = self.d_model
        return [
            ("ln1_scale", (dm,)),
            ("ln1_bias", (dm,)),
            ("qkv_w", (dm, 3 * dm)),
            ("qkv_b", (3 * dm,)),
            ("proj_w", (dm, dm)),
            ("proj_b", (dm,)),
            ("ln2_scale", (dm,)),
            ("ln2_bias", (dm,)),
            ("up_w", (dm, 4 * dm)),
            ("up_b", (4 * dm,)),
            ("down_w", (4 * dm, dm)),
            ("down_b", (dm,)),
        ]

    def param_layout(self):
        """[(name, shape)] in flat-vector order."""
        layout = [
            ("tok_emb", (self.vocab, self.d_model)),
            ("pos_emb", (self.seq_len, self.d_model)),
        ]
        for layer in range(self.n_layers):
            for name, shape in self.layer_sizes():
                layout.append((f"l{layer}.{name}", shape))
        layout.append(("lnf_scale", (self.d_model,)))
        layout.append(("lnf_bias", (self.d_model,)))
        return layout

    @property
    def n_params(self) -> int:
        total = 0
        for _, shape in self.param_layout():
            size = 1
            for s in shape:
                size *= s
            total += size
        return total


def unflatten(cfg: TransformerConfig, theta):
    """Flat vector → dict of named arrays."""
    params = {}
    off = 0
    for name, shape in cfg.param_layout():
        size = 1
        for s in shape:
            size *= s
        params[name] = theta[off : off + size].reshape(shape)
        off += size
    return params


def init_flat(cfg: TransformerConfig, key) -> jnp.ndarray:
    """Initialize the flat parameter vector (scaled-normal / ones for LN)."""
    chunks = []
    for name, shape in cfg.param_layout():
        key, sub = jax.random.split(key)
        size = 1
        for s in shape:
            size *= s
        if "scale" in name:
            chunks.append(jnp.ones((size,), jnp.float32))
        elif "bias" in name:
            chunks.append(jnp.zeros((size,), jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else size
            std = 0.02 if "emb" in name else 1.0 / jnp.sqrt(fan_in)
            chunks.append(
                jax.random.normal(sub, (size,), jnp.float32) * std
            )
    return jnp.concatenate(chunks)


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(cfg: TransformerConfig, p, prefix, h):
    b, t, dm = h.shape
    nh, dh = cfg.n_heads, cfg.d_head
    qkv = h @ p[f"{prefix}.qkv_w"] + p[f"{prefix}.qkv_b"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(x):
        return x.reshape(b, t, nh, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scores = q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(dh).astype(h.dtype)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, dm)
    return out @ p[f"{prefix}.proj_w"] + p[f"{prefix}.proj_b"]


def forward(cfg: TransformerConfig, theta, tokens):
    """Logits [B, T, vocab] for input tokens [B, T]."""
    p = unflatten(cfg, theta)
    h = p["tok_emb"][tokens] + p["pos_emb"][None, : tokens.shape[1]]
    for layer in range(cfg.n_layers):
        pre = f"l{layer}"
        a = _layer_norm(h, p[f"{pre}.ln1_scale"], p[f"{pre}.ln1_bias"])
        h = h + _attention(cfg, p, pre, a)
        m = _layer_norm(h, p[f"{pre}.ln2_scale"], p[f"{pre}.ln2_bias"])
        m = jax.nn.gelu(m @ p[f"{pre}.up_w"] + p[f"{pre}.up_b"])
        h = h + m @ p[f"{pre}.down_w"] + p[f"{pre}.down_b"]
    h = _layer_norm(h, p["lnf_scale"], p["lnf_bias"])
    return h @ p["tok_emb"].T  # tied head


def loss_fn(cfg: TransformerConfig, theta, windows):
    """Mean cross-entropy; windows [B, T+1] i32 (inputs | shifted targets)."""
    inputs = windows[:, :-1]
    targets = windows[:, 1:]
    logits = forward(cfg, theta, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def make_grad_fn(cfg: TransformerConfig):
    """(theta, windows) → (loss[], grad[P]) — the AOT training-step graph."""

    def f(theta, windows):
        loss, grad = jax.value_and_grad(lambda th: loss_fn(cfg, th, windows))(theta)
        return loss, grad

    return jax.jit(f)


def make_loss_fn(cfg: TransformerConfig):
    def f(theta, windows):
        return (loss_fn(cfg, theta, windows),)

    return jax.jit(f)
