"""Build-time compile package: L2 JAX models, L1 Pallas kernels, AOT export.

Nothing in here runs on the request path — `make artifacts` lowers the
jitted functions to HLO text once, and the Rust coordinator executes them
via PJRT.
"""
