"""AOT export: lower the L2 graphs (with their L1 Pallas kernels) to HLO
TEXT + a manifest the Rust runtime parses.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
behind the published `xla` 0.1.6 crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts \
            [--n 100 --q 100] \
            [--vocab 64 --d-model 128 --layers 2 --heads 4 --seq 64 --batch 8]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, transformer


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side unwraps a tuple uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _export(entries, out_dir, name, fn, example_args, inputs, outputs, meta):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    entries[name] = {
        "file": fname,
        "inputs": inputs,
        "outputs": outputs,
        "meta": meta,
    }
    print(f"  {name}: {len(text)} chars -> {fname}")


def export_linreg(entries, out_dir, n, q):
    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((q,), f32)
    z = jax.ShapeDtypeStruct((n, q), f32)
    y = jax.ShapeDtypeStruct((n,), f32)
    a = jax.ShapeDtypeStruct((n, n), f32)
    meta = {"n": n, "q": q}
    # self-check the Pallas kernels against the jnp oracle before exporting
    err = model.check_against_ref(n=min(n, 16), q=min(q, 8))
    print(f"  pallas-vs-ref self-check: max rel err {err:.2e}")
    _export(
        entries, out_dir, "coded_grad",
        lambda *args: model.linreg_coded_grad(*args),
        (x, z, y, a),
        [_spec((q,)), _spec((n, q)), _spec((n,)), _spec((n, n))],
        [_spec((n, q))],
        meta,
    )
    _export(
        entries, out_dir, "linreg_grads",
        lambda *args: model.linreg_grads(*args),
        (x, z, y),
        [_spec((q,)), _spec((n, q)), _spec((n,))],
        [_spec((n, q))],
        meta,
    )
    _export(
        entries, out_dir, "linreg_loss",
        lambda *args: model.linreg_loss(*args),
        (x, z, y),
        [_spec((q,)), _spec((n, q)), _spec((n,))],
        [_spec(())],
        meta,
    )


def export_transformer(entries, out_dir, cfg: transformer.TransformerConfig, batch: int):
    p = cfg.n_params
    theta = jax.ShapeDtypeStruct((p,), jnp.float32)
    windows = jax.ShapeDtypeStruct((batch, cfg.seq_len + 1), jnp.int32)
    meta = {
        "params": p,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "layers": cfg.n_layers,
        "heads": cfg.n_heads,
        "seq": cfg.seq_len,
        "batch": batch,
    }
    grad_fn = transformer.make_grad_fn(cfg)
    loss_fn = transformer.make_loss_fn(cfg)

    def init_fn(seed):
        return (transformer.init_flat(cfg, jax.random.PRNGKey(seed)),)

    _export(
        entries, out_dir, "transformer_init",
        init_fn, (jax.ShapeDtypeStruct((), jnp.int32),),
        [_spec((), "i32")],
        [_spec((p,))],
        meta,
    )
    _export(
        entries, out_dir, "transformer_grad",
        grad_fn, (theta, windows),
        [_spec((p,)), _spec((batch, cfg.seq_len + 1), "i32")],
        [_spec(()), _spec((p,))],
        meta,
    )
    _export(
        entries, out_dir, "transformer_loss",
        loss_fn, (theta, windows),
        [_spec((p,)), _spec((batch, cfg.seq_len + 1), "i32")],
        [_spec(())],
        meta,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--n", type=int, default=100, help="linreg devices/subsets N")
    ap.add_argument("--q", type=int, default=100, help="linreg model dim Q")
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--skip-transformer", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    entries = {}
    print("exporting linreg artifacts (L1 Pallas + L2 jax)...")
    export_linreg(entries, args.out, args.n, args.q)
    if not args.skip_transformer:
        cfg = transformer.TransformerConfig(
            vocab=args.vocab,
            d_model=args.d_model,
            n_layers=args.layers,
            n_heads=args.heads,
            seq_len=args.seq,
        )
        print(f"exporting transformer artifacts ({cfg.n_params/1e6:.2f}M params)...")
        export_transformer(entries, args.out, cfg, args.batch)

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} artifacts -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
