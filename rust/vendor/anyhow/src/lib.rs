//! Vendored, dependency-free shim covering the slice of the `anyhow` API
//! this workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros.
//!
//! The offline sandbox has no crates.io access, so instead of the real
//! `anyhow` (which the seed code was written against) the workspace builds
//! this path dependency. Semantics intentionally match where observable:
//!
//! * `Display` prints the outermost message only;
//! * the alternate form (`{err:#}`) prints the whole context chain,
//!   outermost first, `": "`-separated;
//! * `Debug` prints the message plus a `Caused by:` list (what `.unwrap()`
//!   and `fn main() -> Result<()>` show);
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   preserving its source chain as text.
//!
//! Like the real `anyhow::Error`, [`Error`] does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default type parameter shape as
/// the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error with an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(src) = cur.source.as_deref() {
            cur = src;
        }
        cur
    }
}

/// Iterator over an error's context chain (see [`Error::chain`]).
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(first) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(first);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        fn build(msg: String, src: Option<&(dyn std::error::Error + 'static)>) -> Error {
            Error {
                msg,
                source: src.map(|s| Box::new(build(s.to_string(), s.source()))),
            }
        }
        build(e.to_string(), e.source())
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value (or `None`) with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value (or `None`) with a lazily evaluated context
    /// message.
    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }
    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_is_outer_message_only() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
    }

    #[test]
    fn alternate_prints_chain() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("top");
        let d = format!("{e:?}");
        assert!(d.starts_with("top"), "{d}");
        assert!(d.contains("Caused by:") && d.contains("root"), "{d}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("missing thing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: missing thing");
        let o: Option<u32> = None;
        let e = o.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        assert_eq!(Some(5u32).context("fine").unwrap(), 5);
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 7);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(format!("{}", f(12).unwrap_err()).contains("x too big: 12"));
        assert!(format!("{}", f(7).unwrap_err()).contains("x != 7"));
        assert!(format!("{}", f(3).unwrap_err()).contains("right out"));
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn chain_and_root_cause() {
        let e = Error::msg("a").context("b").context("c");
        let msgs: Vec<String> = e.chain().map(|x| format!("{x}")).collect();
        assert_eq!(msgs, vec!["c", "b", "a"]);
        assert_eq!(format!("{}", e.root_cause()), "a");
    }
}
