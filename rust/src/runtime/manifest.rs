//! Artifact manifest (`artifacts/manifest.json`), written by
//! `python/compile/aot.py` and parsed with the built-in JSON module.
//!
//! Schema:
//! ```json
//! { "version": 1,
//!   "artifacts": {
//!     "coded_grad": {
//!       "file": "coded_grad.hlo.txt",
//!       "inputs":  [ {"shape": [100], "dtype": "f32"}, ... ],
//!       "outputs": [ {"shape": [100, 100], "dtype": "f32"} ],
//!       "meta": {"n": 100, "q": 100}
//!     } } }
//! ```

use crate::util::json::{self, Json};
use crate::Result;
use anyhow::{anyhow, bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

/// Element type of a tensor (extend as artifacts need).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" | "float32" => DType::F32,
            "i32" | "int32" => DType::I32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }
}

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<i64>,
    pub dtype: DType,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|x| x.as_f64().map(|f| f as i64).ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<i64>>>()?;
        let dtype = DType::parse(
            v.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// free-form integer metadata (e.g. n, q, layers)
    pub meta: BTreeMap<String, i64>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: i64,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let body = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&body)
    }

    pub fn parse(body: &str) -> Result<Self> {
        let root = json::parse(body).map_err(|e| anyhow!("manifest json: {e}"))?;
        let version = root.get("version").and_then(Json::as_f64).unwrap_or(1.0) as i64;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts object"))?;
        let mut entries = BTreeMap::new();
        for (name, v) in arts {
            let file = v
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name}: missing file"))?
                .to_string();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                v.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name}: missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let mut meta = BTreeMap::new();
            if let Some(m) = v.get("meta").and_then(Json::as_obj) {
                for (k, mv) in m {
                    if let Some(x) = mv.as_f64() {
                        meta.insert(k.clone(), x as i64);
                    }
                }
            }
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    file,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    meta,
                },
            );
        }
        Ok(Manifest { version, entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "version": 1,
      "artifacts": {
        "coded_grad": {
          "file": "coded_grad.hlo.txt",
          "inputs": [
            {"shape": [100], "dtype": "f32"},
            {"shape": [100, 100], "dtype": "f32"},
            {"shape": [100], "dtype": "f32"},
            {"shape": [100, 100], "dtype": "f32"}
          ],
          "outputs": [{"shape": [100, 100], "dtype": "f32"}],
          "meta": {"n": 100, "q": 100}
        },
        "toy": {
          "file": "toy.hlo.txt",
          "inputs": [{"shape": [4], "dtype": "i32"}],
          "outputs": [{"shape": [], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.version, 1);
        let e = &m.entries["coded_grad"];
        assert_eq!(e.file, "coded_grad.hlo.txt");
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[1].shape, vec![100, 100]);
        assert_eq!(e.meta["n"], 100);
        assert_eq!(m.entries["toy"].inputs[0].dtype, DType::I32);
        assert_eq!(m.entries["toy"].outputs[0].shape, Vec::<i64>::new());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts": {"x": {}}}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let doc = r#"{"artifacts": {"x": {"file": "f", "inputs": [{"shape": [1], "dtype": "f16"}], "outputs": []}}}"#;
        assert!(Manifest::parse(doc).is_err());
    }
}
