//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt` + manifest) and
//! execute them from the coordinator.
//!
//! The interchange format is **HLO text** — jax ≥ 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md). Executables are
//! compiled once per artifact and cached; every call after the first is a
//! pure PJRT execute.
//!
//! # Feature gating
//!
//! The actual PJRT execution paths depend on the environment-provided `xla`
//! extension crate and are compiled only with `--features pjrt` (after
//! adding the `xla` dependency — see the README's "PJRT runtime" section).
//! The default build ships a **stub** [`Runtime`] with the same API: it
//! still loads and validates manifests (so configuration errors surface
//! identically), but [`Runtime::exec_f32`] returns a clear error instead of
//! executing. Everything that doesn't touch artifacts — the native oracle,
//! all figure drivers, the cluster — is unaffected.

pub mod manifest;

#[cfg(feature = "pjrt")]
use crate::util::timer::Timer;
use crate::Result;
#[cfg(feature = "pjrt")]
use anyhow::anyhow;
use anyhow::{bail, Context};
use manifest::{DType, Manifest};
#[cfg(feature = "pjrt")]
use manifest::TensorSpec;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A typed input tensor (row-major).
pub enum TensorIn<'a> {
    F32(&'a [f32], &'a [i64]),
    I32(&'a [i32], &'a [i64]),
}

impl<'a> TensorIn<'a> {
    fn elem_count(&self) -> usize {
        match self {
            TensorIn::F32(d, _) => d.len(),
            TensorIn::I32(d, _) => d.len(),
        }
    }
    fn dims(&self) -> &[i64] {
        match self {
            TensorIn::F32(_, s) | TensorIn::I32(_, s) => s,
        }
    }
    fn dtype(&self) -> DType {
        match self {
            TensorIn::F32(..) => DType::F32,
            TensorIn::I32(..) => DType::I32,
        }
    }
    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            TensorIn::F32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
            TensorIn::I32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
        };
        Ok(lit)
    }
}

/// Execution statistics (for the perf pass).
#[derive(Debug, Clone, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub executes: usize,
    pub compile_s: f64,
    pub execute_s: f64,
}

/// PJRT CPU runtime with a compiled-executable cache (stub without the
/// `pjrt` feature — see the module docs).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    #[cfg(feature = "pjrt")]
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    pub stats: RuntimeStats,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} — run `make artifacts`"))?;
        #[cfg(feature = "pjrt")]
        {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime {
                client,
                dir,
                manifest,
                cache: HashMap::new(),
                stats: RuntimeStats::default(),
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Ok(Runtime { dir, manifest, stats: RuntimeStats::default() })
        }
    }

    /// Default artifact dir: $LAD_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Self> {
        let dir =
            std::env::var("LAD_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "stub (rebuild with --features pjrt to execute artifacts)".to_string()
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has(&self, name: &str) -> bool {
        self.manifest.entries.contains_key(name)
    }

    #[cfg(feature = "pjrt")]
    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        let path = self.dir.join(&entry.file);
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name:?}: {e:?}"))?;
        self.stats.compiles += 1;
        self.stats.compile_s += t.elapsed_s();
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Validate inputs against the manifest spec.
    fn check_inputs(&self, name: &str, inputs: &[TensorIn]) -> Result<()> {
        let entry = &self.manifest.entries[name];
        if entry.inputs.len() != inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (i, (spec, got)) in entry.inputs.iter().zip(inputs).enumerate() {
            if spec.dtype != got.dtype() {
                bail!("{name} input {i}: dtype {:?} != manifest {:?}", got.dtype(), spec.dtype);
            }
            if spec.shape.as_slice() != got.dims() {
                bail!(
                    "{name} input {i}: shape {:?} != manifest {:?}",
                    got.dims(),
                    spec.shape
                );
            }
            let want: i64 = spec.shape.iter().product();
            if want as usize != got.elem_count() {
                bail!(
                    "{name} input {i}: buffer has {} elems, shape wants {want}",
                    got.elem_count()
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact; returns each output flattened to f32.
    /// (All our artifact outputs are f32 or scalar f32.)
    #[cfg(feature = "pjrt")]
    pub fn exec_f32(&mut self, name: &str, inputs: &[TensorIn]) -> Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        self.check_inputs(name, inputs)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let exe = self.cache.get(name).unwrap();
        let t = Timer::start();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name:?}: {e:?}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name:?}: {e:?}"))?;
        self.stats.executes += 1;
        self.stats.execute_s += t.elapsed_s();
        // artifacts are lowered with return_tuple=True
        let parts = root.to_tuple().map_err(|e| anyhow!("tuple {name:?}: {e:?}"))?;
        let entry = &self.manifest.entries[name];
        if entry.outputs.len() != parts.len() {
            bail!("{name}: manifest says {} outputs, got {}", entry.outputs.len(), parts.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for (spec, lit) in entry.outputs.iter().zip(parts) {
            out.push(literal_to_f32(&lit, spec)?);
        }
        Ok(out)
    }

    /// Stub `exec_f32`: validates the request against the manifest exactly
    /// like the real runtime, then reports that execution is unavailable.
    #[cfg(not(feature = "pjrt"))]
    pub fn exec_f32(&mut self, name: &str, inputs: &[TensorIn]) -> Result<Vec<Vec<f32>>> {
        if !self.manifest.entries.contains_key(name) {
            bail!("artifact {name:?} not in manifest");
        }
        self.check_inputs(name, inputs)?;
        bail!(
            "cannot execute artifact {name:?} from {:?}: built without the `pjrt` \
             feature (see README \"PJRT runtime\")",
            self.dir
        )
    }
}

#[cfg(feature = "pjrt")]
fn literal_to_f32(lit: &xla::Literal, spec: &TensorSpec) -> Result<Vec<f32>> {
    let v = match spec.dtype {
        DType::F32 => lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?,
        DType::I32 => lit
            .to_vec::<i32>()
            .map_err(|e| anyhow!("to_vec i32: {e:?}"))?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
    };
    let want: i64 = spec.shape.iter().product::<i64>().max(1);
    if v.len() != want as usize {
        bail!("output has {} elems, manifest shape {:?}", v.len(), spec.shape);
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_in_accessors() {
        let d = [1.0f32, 2.0, 3.0, 4.0];
        let t = TensorIn::F32(&d, &[2, 2]);
        assert_eq!(t.elem_count(), 4);
        assert_eq!(t.dims(), &[2, 2]);
        assert_eq!(t.dtype(), DType::F32);
        let i = [1i32, 2];
        let t2 = TensorIn::I32(&i, &[2]);
        assert_eq!(t2.dtype(), DType::I32);
    }

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let err = match Runtime::load("/nonexistent/dir") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "{msg}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_loads_manifests_but_refuses_to_execute() {
        // build a minimal artifact dir with a manifest but no executor
        let dir = std::env::temp_dir().join("lad_stub_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": {"toy": {
                "file": "toy.hlo.txt",
                "inputs": [{"shape": [2], "dtype": "f32"}],
                "outputs": [{"shape": [], "dtype": "f32"}]
            }}}"#,
        )
        .unwrap();
        let mut rt = Runtime::load(&dir).unwrap();
        assert!(rt.has("toy"));
        assert!(rt.platform().contains("stub"));
        // input validation still happens before the stub error
        let wrong = rt.exec_f32("toy", &[]).unwrap_err();
        assert!(format!("{wrong}").contains("inputs"), "{wrong}");
        // correct shapes reach the feature-gate error
        let x = [1.0f32, 2.0];
        let err = rt.exec_f32("toy", &[TensorIn::F32(&x, &[2])]).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
        let missing = rt.exec_f32("nope", &[]).unwrap_err();
        assert!(format!("{missing}").contains("not in manifest"), "{missing}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
