//! Unbiased random sparsification (Wangni et al. [16]; §VII-B).
//!
//! Keep K uniformly chosen coordinates scaled by Q/K, zero the rest:
//! E[C(g)] = g and E‖C(g) − g‖² = (Q/K − 1)‖g‖², i.e. δ = Q/K − 1.
//! Wire format: K × (index + f32 value); indices cost ⌈log₂ Q⌉ bits.

use super::{CompressedMsg, Compressor, WireEnc};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct RandK {
    k: usize,
}

impl RandK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        RandK { k }
    }
    pub fn k(&self) -> usize {
        self.k
    }
}

impl Compressor for RandK {
    fn compress(&self, g: &[f32], rng: &mut Rng) -> CompressedMsg {
        let q = g.len();
        let k = self.k.min(q);
        let scale = q as f32 / k as f32;
        let mut out = vec![0.0f32; q];
        for idx in rng.choose_k(q, k) {
            out[idx] = g[idx] * scale;
        }
        let idx_bits = (usize::BITS - (q - 1).leading_zeros()) as usize;
        CompressedMsg { vec: out, bits: k * (32 + idx_bits), enc: WireEnc::Sparse }
    }

    fn delta(&self, dim: usize) -> Option<f64> {
        Some((dim as f64 / self.k.min(dim) as f64) - 1.0)
    }

    fn name(&self) -> String {
        format!("rand-{}", self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::measure_bias_delta;

    #[test]
    fn keeps_exactly_k_scaled_entries() {
        let mut rng = Rng::new(1);
        let g: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        let c = RandK::new(3).compress(&g, &mut rng);
        let nz: Vec<usize> =
            (0..10).filter(|&j| c.vec[j] != 0.0).collect();
        assert_eq!(nz.len(), 3);
        for &j in &nz {
            assert!((c.vec[j] - g[j] * (10.0 / 3.0)).abs() < 1e-4);
        }
    }

    #[test]
    fn unbiased_and_delta_matches_theory() {
        let mut rng = Rng::new(2);
        let g: Vec<f32> = (0..50).map(|i| ((i * 7) % 13) as f32 - 6.0).collect();
        let comp = RandK::new(10);
        let (bias, delta_hat) = measure_bias_delta(&comp, &g, 30_000, &mut rng);
        assert!(bias < 0.02, "bias {bias}");
        let want = comp.delta(50).unwrap(); // 50/10 - 1 = 4
        assert!((delta_hat - want).abs() < 0.15 * want, "δ̂={delta_hat} δ={want}");
    }

    #[test]
    fn bit_accounting() {
        let mut rng = Rng::new(3);
        let g = vec![1.0f32; 100];
        let c = RandK::new(30).compress(&g, &mut rng);
        // ⌈log2 100⌉ = 7 bits per index
        assert_eq!(c.bits, 30 * (32 + 7));
        assert!(c.bits < 100 * 32); // cheaper than dense
    }

    #[test]
    fn k_geq_q_degenerates_to_identity() {
        let mut rng = Rng::new(4);
        let g = vec![2.0f32, -3.0];
        let c = RandK::new(10).compress(&g, &mut rng);
        assert_eq!(c.vec, g);
        assert_eq!(RandK::new(10).delta(2), Some(0.0));
    }
}
