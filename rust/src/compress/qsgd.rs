//! QSGD stochastic quantization (Alistarh et al., NeurIPS'17 [27]).
//!
//! Each coordinate is quantized to s levels of |g_j|/‖g‖·s, rounding up or
//! down stochastically so that E[C(g)] = g. δ ≤ min(Q/s², √Q/s).
//! Wire format: 32-bit norm + per coordinate (sign + ⌈log₂(s+1)⌉ level bits).
//! The ‖g‖ pass is the tier-dispatched `util::math::norm` (4-lane f64
//! contract — identical bits on every tier, so quantized messages are
//! CPU-independent).

use super::{CompressedMsg, Compressor, WireEnc};
use crate::util::math::norm;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct Qsgd {
    levels: u32,
}

impl Qsgd {
    pub fn new(levels: u32) -> Self {
        assert!(levels >= 1);
        Qsgd { levels }
    }
}

impl Compressor for Qsgd {
    fn compress(&self, g: &[f32], rng: &mut Rng) -> CompressedMsg {
        let q = g.len();
        let s = self.levels as f32;
        let gnorm = norm(g) as f32;
        if gnorm == 0.0 {
            return CompressedMsg {
                vec: vec![0.0; q],
                bits: 32 + q,
                enc: WireEnc::Quantized { levels: self.levels, norm: 0.0 },
            };
        }
        let mut out = vec![0.0f32; q];
        for j in 0..q {
            let a = g[j].abs() / gnorm * s; // in [0, s]
            let lo = a.floor();
            let level = lo + f32::from(rng.f32() < a - lo);
            out[j] = g[j].signum() * level * gnorm / s;
        }
        let level_bits = (32 - self.levels.leading_zeros()) as usize; // ⌈log2(s+1)⌉
        CompressedMsg {
            vec: out,
            bits: 32 + q * (1 + level_bits),
            enc: WireEnc::Quantized { levels: self.levels, norm: gnorm },
        }
    }

    fn delta(&self, dim: usize) -> Option<f64> {
        let s = self.levels as f64;
        let q = dim as f64;
        Some((q / (s * s)).min(q.sqrt() / s))
    }

    fn name(&self) -> String {
        format!("qsgd-{}", self.levels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::measure_bias_delta;

    #[test]
    fn zero_vector_is_fixed_point() {
        let mut rng = Rng::new(1);
        let c = Qsgd::new(4).compress(&[0.0; 8], &mut rng);
        assert_eq!(c.vec, vec![0.0; 8]);
    }

    #[test]
    fn unbiased() {
        let mut rng = Rng::new(2);
        let g: Vec<f32> = (0..20).map(|i| (i as f32 - 10.0) * 0.3).collect();
        let (bias, _) = measure_bias_delta(&Qsgd::new(4), &g, 30_000, &mut rng);
        assert!(bias < 0.02, "bias {bias}");
    }

    #[test]
    fn delta_bound_holds_empirically() {
        let mut rng = Rng::new(3);
        let g: Vec<f32> = (0..30).map(|i| ((i % 7) as f32) - 3.0).collect();
        let comp = Qsgd::new(4);
        let (_, delta_hat) = measure_bias_delta(&comp, &g, 10_000, &mut rng);
        let bound = comp.delta(30).unwrap();
        assert!(delta_hat <= bound * 1.1, "δ̂={delta_hat} bound={bound}");
    }

    #[test]
    fn more_levels_less_error() {
        let mut rng = Rng::new(4);
        let g: Vec<f32> = (0..40).map(|i| (i as f32 * 0.13).sin()).collect();
        let (_, d2) = measure_bias_delta(&Qsgd::new(2), &g, 4_000, &mut rng);
        let (_, d16) = measure_bias_delta(&Qsgd::new(16), &g, 4_000, &mut rng);
        assert!(d16 < d2);
    }

    #[test]
    fn preserves_sign_and_magnitude_scale() {
        let mut rng = Rng::new(5);
        let g = vec![3.0f32, -4.0];
        let c = Qsgd::new(64).compress(&g, &mut rng);
        assert!(c.vec[0] >= 0.0 && c.vec[1] <= 0.0);
        assert!((c.vec[0] - 3.0).abs() < 0.3);
    }
}
