//! Biased top-K sparsification (Shi et al. [15]) — ablation only.
//!
//! Keeps the K largest-magnitude coordinates unscaled. Violates the
//! unbiasedness requirement (9) of Com-LAD; included to demonstrate
//! empirically why Definition 2 demands unbiased operators.

use super::{CompressedMsg, Compressor, WireEnc};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct TopK {
    k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        TopK { k }
    }
}

impl Compressor for TopK {
    fn compress(&self, g: &[f32], _rng: &mut Rng) -> CompressedMsg {
        let q = g.len();
        let k = self.k.min(q);
        let mut idx: Vec<usize> = (0..q).collect();
        if k < q {
            // total_cmp: same order as partial_cmp on the non-negative abs
            // values, but NaN-proof (no unwrap on adversarial gradients)
            idx.select_nth_unstable_by(k - 1, |&a, &b| g[b].abs().total_cmp(&g[a].abs()));
        }
        let mut out = vec![0.0f32; q];
        for &j in &idx[..k] {
            out[j] = g[j];
        }
        let idx_bits = (usize::BITS - (q.max(2) - 1).leading_zeros()) as usize;
        CompressedMsg { vec: out, bits: k * (32 + idx_bits), enc: WireEnc::Sparse }
    }

    fn delta(&self, _dim: usize) -> Option<f64> {
        None // biased: no δ in the sense of Definition 2
    }

    fn name(&self) -> String {
        format!("top-{}", self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest_magnitudes() {
        let mut rng = Rng::new(1);
        let g = vec![0.1f32, -5.0, 0.2, 4.0, -0.3];
        let c = TopK::new(2).compress(&g, &mut rng);
        assert_eq!(c.vec, vec![0.0, -5.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn is_biased() {
        // deterministic => E[C(g)] = C(g) ≠ g whenever K < nnz(g)
        let mut rng = Rng::new(2);
        let g = vec![1.0f32, 2.0, 3.0];
        let c = TopK::new(1).compress(&g, &mut rng);
        assert_ne!(c.vec, g);
        assert!(TopK::new(1).delta(3).is_none());
    }

    #[test]
    fn lower_error_than_rand_k_for_same_k() {
        use crate::util::math::dist_sq;
        let mut rng = Rng::new(3);
        let g: Vec<f32> = (0..64).map(|i| if i < 4 { 10.0 } else { 0.01 }).collect();
        let t = TopK::new(4).compress(&g, &mut rng);
        let r = super::super::RandK::new(4).compress(&g, &mut rng);
        assert!(dist_sq(&t.vec, &g) < dist_sq(&r.vec, &g));
    }
}
