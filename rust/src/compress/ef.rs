//! Error-feedback (EF) compression memory stage.
//!
//! Implements the residual-memory scheme of Rammal et al., *"Communication
//! compression for Byzantine robust learning: new efficient algorithms and
//! improved rates"* (arXiv 2310.09804): each device carries a residual
//! vector eᵢ across iterations, transmits C(eᵢ + gᵢ), and stores the
//! compression error back:
//!
//! ```text
//! aᵢᵗ = eᵢᵗ + gᵢᵗ            (the EF input, computed with axpy)
//! tᵢᵗ = C(aᵢᵗ)               (what crosses the wire — base-operator bits)
//! eᵢᵗ⁺¹ = aᵢᵗ − tᵢᵗ          (elementwise f32 subtraction, stored)
//! ```
//!
//! The decomposition is exact by construction: the stored residual is the
//! bitwise elementwise difference `aᵢ − tᵢ`, so `tᵢ` plus the stored
//! residual recovers `eᵢ + gᵢ` up to one IEEE-754 rounding of the final
//! re-addition, and on every coordinate a sparsifier zeroes (`tᵢ[j] = 0`)
//! the residual keeps `aᵢ[j]` bit-exactly. EF turns the *biased* top-K
//! into a contractive scheme and keeps the unbiased operators' wire cost
//! unchanged — the transmitted message is a plain base-operator output, so
//! `net::wire::Payload` encodings apply verbatim.
//!
//! Determinism contract: the EF input is formed with the runtime-dispatched
//! [`crate::util::math::axpy`] kernel (bit-identical across SIMD tiers) and
//! the residual update is an elementwise scalar subtraction, so central,
//! device-side (worker-held state, see `net::worker`) and any thread count
//! produce bit-identical traces. State lifecycle: one residual per device,
//! zero-initialized per run; a device retired by the net leader has its
//! residual [`EfState::reset`] to zero (and a worker process restarted into
//! a new run always starts from zero), so a rejoining device can never
//! replay stale memory.

use super::{compress_batch, CompressedMsg, Compressor};
use crate::config::CompressionKind;
use crate::util::math::axpy;
use crate::util::parallel::Pool;
use crate::util::rng::Rng;

/// The stateless face of an EF kind: delegates compression to the wrapped
/// base operator (the caller owns the residual memory via [`EfState`]) and
/// reports the `ef-` prefixed operator name. `compress::from_kind` returns
/// this for the `Ef*` kinds so bit accounting, `delta` and wire encodings
/// are exactly the base operator's.
pub struct Ef {
    base: Box<dyn Compressor>,
}

impl Ef {
    /// Wrap the stateless base operator of `kind` (its [`ef_base`] for EF
    /// kinds, `kind` itself otherwise).
    ///
    /// [`ef_base`]: CompressionKind::ef_base
    pub fn new(kind: CompressionKind) -> Self {
        Ef { base: super::from_kind(kind.ef_base().unwrap_or(kind)) }
    }
}

impl Compressor for Ef {
    /// Compress an already-formed EF input (residual + gradient). Without
    /// an [`EfState`] in front this is exactly the base operator.
    fn compress(&self, g: &[f32], rng: &mut Rng) -> CompressedMsg {
        self.base.compress(g, rng)
    }
    /// The base operator's per-step δ (eq. 10). The EF *iteration* enjoys
    /// a tighter effective error (see `theory::TheoryParams::error_term_ef_bigo`).
    fn delta(&self, dim: usize) -> Option<f64> {
        self.base.delta(dim)
    }
    fn name(&self) -> String {
        format!("ef-{}", self.base.name())
    }
}

/// Per-device error-feedback residual memory, carried across iterations.
#[derive(Debug, Clone, PartialEq)]
pub struct EfState {
    residuals: Vec<Vec<f32>>,
}

impl EfState {
    /// `n` devices × `dim` coordinates, all residuals zero.
    pub fn new(n: usize, dim: usize) -> Self {
        EfState { residuals: vec![vec![0.0f32; dim]; n] }
    }

    /// Residual memory for `kind` if it is an EF kind, else `None` — the
    /// one-liner the trainer/leader/worker use to decide whether the EF
    /// stage is active.
    pub fn for_kind(kind: CompressionKind, n: usize, dim: usize) -> Option<EfState> {
        kind.is_ef().then(|| EfState::new(n, dim))
    }

    pub fn n_devices(&self) -> usize {
        self.residuals.len()
    }

    /// Device `i`'s carried residual.
    pub fn residual(&self, device: usize) -> &[f32] {
        &self.residuals[device]
    }

    /// Zero device `i`'s residual — called when the net leader retires a
    /// device, so a slot that were ever rejoined starts from fresh memory.
    pub fn reset(&mut self, device: usize) {
        self.residuals[device].iter_mut().for_each(|x| *x = 0.0);
    }

    /// Clone every residual row — the leader-side mirror a checkpoint
    /// stores so a warm restart resumes EF memory bit-identically.
    pub fn snapshot(&self) -> Vec<Vec<f32>> {
        self.residuals.clone()
    }

    /// Replace all residual rows from a checkpoint snapshot. The snapshot
    /// must match this state's shape — resuming into a different run
    /// geometry is a bug, not a recoverable condition.
    pub fn restore(&mut self, rows: Vec<Vec<f32>>) {
        assert_eq!(rows.len(), self.residuals.len(), "EF snapshot device count mismatch");
        for (cur, new) in self.residuals.iter_mut().zip(rows) {
            assert_eq!(new.len(), cur.len(), "EF snapshot dim mismatch");
            *cur = new;
        }
    }

    /// The EF input aᵢ = eᵢ + gᵢ (residual clone + `axpy(1.0, g, ·)`,
    /// running on the active kernel tier).
    pub fn input(&self, device: usize, g: &[f32]) -> Vec<f32> {
        let mut a = self.residuals[device].clone();
        axpy(1.0, g, &mut a);
        a
    }

    /// Store the compression error eᵢ ← aᵢ − tᵢ (elementwise f32).
    pub fn absorb(&mut self, device: usize, input: &[f32], transmitted: &[f32]) {
        let e = &mut self.residuals[device];
        debug_assert_eq!(e.len(), input.len());
        for j in 0..e.len() {
            e[j] = input[j] - transmitted[j];
        }
    }

    /// One full EF step for a single device: form the input, compress it
    /// with the device's private stream, absorb the error, return the
    /// transmitted message. This is the worker-side (and per-device
    /// leader-side) path; [`compress_batch_ef`] is the batched equivalent
    /// and produces bit-identical messages.
    pub fn step(
        &mut self,
        device: usize,
        g: &[f32],
        comp: &dyn Compressor,
        rng: &mut Rng,
    ) -> CompressedMsg {
        let input = self.input(device, g);
        let c = comp.compress(&input, rng);
        self.absorb(device, &input, &c.vec);
        c
    }
}

/// The EF uplink step for a whole device family: form every EF input,
/// compress the batch on the pool (thread-count invariant — each device
/// owns its stream and its residual row), absorb every error. Message `i`
/// uses residual `i` and `rngs[i]`; bit accounting is the base operator's.
pub fn compress_batch_ef(
    comp: &dyn Compressor,
    state: &mut EfState,
    msgs: &[&[f32]],
    rngs: &mut [Rng],
    pool: &Pool,
) -> (Vec<Vec<f32>>, u64) {
    assert_eq!(msgs.len(), state.n_devices(), "one residual per message");
    let inputs: Vec<Vec<f32>> =
        msgs.iter().enumerate().map(|(i, g)| state.input(i, g)).collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let (out, bits) = compress_batch(comp, &refs, rngs, pool);
    for i in 0..msgs.len() {
        state.absorb(i, &inputs[i], &out[i]);
    }
    (out, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, RandK, TopK};

    #[test]
    fn identity_keeps_residual_exactly_zero() {
        let mut st = EfState::new(1, 8);
        let mut rng = Rng::new(3);
        for step in 0..5 {
            let g: Vec<f32> = (0..8).map(|j| (j as f32 + 1.0) * 0.25 - step as f32).collect();
            let c = st.step(0, &g, &Identity, &mut rng);
            assert_eq!(c.vec, g, "identity EF transmits the gradient itself");
            assert!(st.residual(0).iter().all(|&e| e == 0.0), "residual drifted");
        }
    }

    #[test]
    fn residual_carries_the_untransmitted_mass() {
        // top-1 on a 3-vector: the two dropped coordinates accumulate
        let mut st = EfState::new(1, 3);
        let mut rng = Rng::new(1);
        let g = vec![10.0f32, 1.0, 2.0];
        let c = st.step(0, &g, &TopK::new(1), &mut rng);
        assert_eq!(c.vec, vec![10.0, 0.0, 0.0]);
        assert_eq!(st.residual(0), &[0.0, 1.0, 2.0]);
        // second step compresses residual + gradient
        let c = st.step(0, &g, &TopK::new(1), &mut rng);
        assert_eq!(c.vec, vec![10.0, 0.0, 0.0]);
        assert_eq!(st.residual(0), &[0.0, 2.0, 4.0]);
    }

    #[test]
    fn reset_zeroes_one_device_only() {
        let mut st = EfState::new(2, 2);
        let mut rng = Rng::new(2);
        for dev in 0..2 {
            st.step(dev, &[1.0, 2.0], &TopK::new(1), &mut rng);
        }
        assert!(st.residual(0).iter().any(|&e| e != 0.0));
        st.reset(0);
        assert_eq!(st.residual(0), &[0.0, 0.0]);
        assert!(st.residual(1).iter().any(|&e| e != 0.0), "other device untouched");
    }

    #[test]
    fn batch_matches_per_device_steps_bitwise() {
        let mut gen = Rng::new(77);
        let msgs_owned: Vec<Vec<f32>> = (0..6).map(|_| gen.gauss_vec(40)).collect();
        let msgs: Vec<&[f32]> = msgs_owned.iter().map(|m| m.as_slice()).collect();
        let comp = RandK::new(7);
        let parent = Rng::new(99);
        let mut st_a = EfState::new(6, 40);
        let mut st_b = EfState::new(6, 40);
        for round in 0..3 {
            let mut rngs = parent.split(6);
            let (batch, bits) =
                compress_batch_ef(&comp, &mut st_a, &msgs, &mut rngs, &Pool::new(4));
            let mut rngs = parent.split(6);
            let singles: Vec<Vec<f32>> = (0..6)
                .map(|i| st_b.step(i, msgs[i], &comp, &mut rngs[i]).vec)
                .collect();
            assert_eq!(batch, singles, "round {round}");
            assert_eq!(st_a, st_b, "round {round}: residuals diverged");
            assert!(bits > 0);
        }
    }

    #[test]
    fn snapshot_restore_round_trips_bitwise() {
        let mut st = EfState::new(3, 5);
        let mut rng = Rng::new(6);
        let mut gen = Rng::new(7);
        for dev in 0..3 {
            let g = gen.gauss_vec(5);
            st.step(dev, &g, &TopK::new(2), &mut rng);
        }
        let snap = st.snapshot();
        let mut fresh = EfState::new(3, 5);
        fresh.restore(snap.clone());
        assert_eq!(st, fresh, "restored residuals differ bitwise");
        // a retired-then-rejoined device's zeroed residual survives too
        st.reset(1);
        let snap = st.snapshot();
        assert!(snap[1].iter().all(|&e| e.to_bits() == 0));
        fresh.restore(snap);
        assert_eq!(st, fresh);
    }

    #[test]
    fn ef_wrapper_names_and_delegates() {
        let ef = Ef::new(CompressionKind::EfRandK { k: 5 });
        assert_eq!(ef.name(), "ef-rand-5");
        assert_eq!(ef.delta(20), RandK::new(5).delta(20));
        let mut a = Rng::new(8);
        let mut b = Rng::new(8);
        let g: Vec<f32> = (0..20).map(|j| j as f32 * 0.5 - 3.0).collect();
        assert_eq!(ef.compress(&g, &mut a), RandK::new(5).compress(&g, &mut b));
        assert!(EfState::for_kind(CompressionKind::EfQsgd { levels: 4 }, 3, 7).is_some());
        assert!(EfState::for_kind(CompressionKind::Qsgd { levels: 4 }, 3, 7).is_none());
    }
}
