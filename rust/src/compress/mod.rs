//! Communication compression operators (Definition 2) with bit accounting.
//!
//! Com-LAD requires *unbiased* operators: E[C(g)] = g and
//! E‖C(g) − g‖² ≤ δ‖g‖². Provided: rand-K sparsification (paper's choice,
//! δ = Q/K − 1), QSGD stochastic quantization, and — for the ablation —
//! biased top-K. Every operator reports the exact wire size of its encoded
//! message so experiments can plot loss vs bits.

pub mod qsgd;
pub mod rand_k;
pub mod top_k;

use crate::config::CompressionKind;
use crate::util::rng::Rng;

/// A compressed message: the dense reconstruction the server aggregates,
/// plus the exact number of bits the encoding would occupy on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedMsg {
    pub vec: Vec<f32>,
    pub bits: usize,
}

/// A compression operator C : R^Q → R^Q.
pub trait Compressor: Send + Sync {
    fn compress(&self, g: &[f32], rng: &mut Rng) -> CompressedMsg;
    /// Theoretical δ in eq. (10), if the operator is unbiased.
    fn delta(&self, dim: usize) -> Option<f64>;
    fn name(&self) -> String;
}

/// Identity (δ = 0): dense f32 transmission.
#[derive(Debug, Clone, Copy, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn compress(&self, g: &[f32], _rng: &mut Rng) -> CompressedMsg {
        CompressedMsg { vec: g.to_vec(), bits: 32 * g.len() }
    }
    fn delta(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }
    fn name(&self) -> String {
        "none".into()
    }
}

pub use qsgd::Qsgd;
pub use rand_k::RandK;
pub use top_k::TopK;

/// Build from a config kind.
pub fn from_kind(kind: CompressionKind) -> Box<dyn Compressor> {
    match kind {
        CompressionKind::None => Box::new(Identity),
        CompressionKind::RandK { k } => Box::new(RandK::new(k)),
        CompressionKind::TopK { k } => Box::new(TopK::new(k)),
        CompressionKind::Qsgd { levels } => Box::new(Qsgd::new(levels)),
    }
}

/// Empirically verify unbiasedness and measure δ̂ (used by tests and the
/// compression ablation bench): returns (max |E[C(g)]−g| per coordinate /
/// ‖g‖, E‖C(g)−g‖² / ‖g‖²).
pub fn measure_bias_delta(
    comp: &dyn Compressor,
    g: &[f32],
    trials: usize,
    rng: &mut Rng,
) -> (f64, f64) {
    let q = g.len();
    let mut mean = vec![0.0f64; q];
    let mut err2 = 0.0f64;
    for _ in 0..trials {
        let c = comp.compress(g, rng);
        for j in 0..q {
            mean[j] += c.vec[j] as f64;
        }
        err2 += crate::util::math::dist_sq(&c.vec, g);
    }
    let norm2 = crate::util::math::norm_sq(g).max(1e-30);
    let bias = (0..q)
        .map(|j| (mean[j] / trials as f64 - g[j] as f64).abs())
        .fold(0.0f64, f64::max)
        / norm2.sqrt();
    (bias, err2 / trials as f64 / norm2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_lossless() {
        let mut rng = Rng::new(1);
        let g = vec![1.0f32, -2.0, 3.0];
        let c = Identity.compress(&g, &mut rng);
        assert_eq!(c.vec, g);
        assert_eq!(c.bits, 96);
    }

    #[test]
    fn from_kind_builds_all() {
        let mut rng = Rng::new(2);
        let g = vec![0.5f32; 40];
        for kind in [
            CompressionKind::None,
            CompressionKind::RandK { k: 10 },
            CompressionKind::TopK { k: 10 },
            CompressionKind::Qsgd { levels: 8 },
        ] {
            let c = from_kind(kind);
            let out = c.compress(&g, &mut rng);
            assert_eq!(out.vec.len(), 40, "{}", c.name());
            assert!(out.bits > 0);
        }
    }
}
