//! Communication compression operators (Definition 2) with bit accounting.
//!
//! Com-LAD requires *unbiased* operators: E[C(g)] = g and
//! E‖C(g) − g‖² ≤ δ‖g‖² (eq. 9–10) — the constant δ enters the error term
//! of Theorem 1 through κ₁..κ₄, which is why the biased top-K is ablation
//! only. Every operator reports the exact wire size of its encoded message
//! so experiments can plot loss vs bits.
//!
//! | Operator     | δ (eq. 10)            | Wire bits per message       |
//! |--------------|-----------------------|-----------------------------|
//! | [`Identity`] | 0                     | 32·Q                        |
//! | [`RandK`]    | Q/K − 1               | K·(32 + ⌈log₂ Q⌉)           |
//! | [`Qsgd`]     | ≤ min(Q/s², √Q/s)     | 32 + Q·(1 + ⌈log₂(s+1)⌉)    |
//! | [`TopK`]     | biased (none)         | K·(32 + ⌈log₂ Q⌉)           |
//! | [`ef::Ef`]   | base per step (EF memory) | base operator's bits    |
//!
//! The [`ef`] module adds the error-feedback memory stage (Rammal et al.,
//! arXiv 2310.09804): a per-device residual carried across iterations,
//! compressing `residual + gradient` with any base operator above and
//! storing the compression error back ([`ef::EfState`] +
//! [`ef::compress_batch_ef`]). Wire cost and payload encodings are the
//! base operator's — only the input changes.
//!
//! Batch uplink compression (one private RNG stream per device, thread-count
//! invariant) is provided by [`compress_batch`] — the step both the fast
//! trainer and the cluster leader execute per iteration. Norm computations
//! inside the operators (QSGD's ‖g‖, the δ̂ estimator's distances) run on
//! the runtime-dispatched `util::math` kernel tier, bit-identical across
//! tiers, so compressed messages never depend on the host CPU.

pub mod ef;
pub mod qsgd;
pub mod rand_k;
pub mod top_k;

use crate::config::CompressionKind;
use crate::util::parallel::Pool;
use crate::util::rng::Rng;

/// How a compressed message is packed on the wire by `net::wire` — the
/// per-variant encoding that makes measured bytes track the analytic bit
/// accounting. Every operator tags its output with the encoding that
/// reconstructs its dense vector exactly; `net::wire::Payload` verifies
/// the round trip bitwise and falls back to `Dense` on any mismatch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireEnc {
    /// Dense little-endian f32s — [`Identity`] (and the exactness
    /// fallback for every other operator).
    Dense,
    /// Nonzero (index, value) pairs — [`RandK`] / [`TopK`]
    /// sparsification.
    Sparse,
    /// ‖g‖ plus one (sign bit, level index) pair per coordinate —
    /// [`Qsgd`] stochastic quantization with `levels` levels.
    Quantized { levels: u32, norm: f32 },
}

/// A compressed message: the dense reconstruction the server aggregates,
/// the exact number of bits the encoding would occupy on the wire, and
/// the wire encoding that realizes that cost (see [`WireEnc`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedMsg {
    pub vec: Vec<f32>,
    pub bits: usize,
    pub enc: WireEnc,
}

/// A compression operator C : R^Q → R^Q.
pub trait Compressor: Send + Sync {
    fn compress(&self, g: &[f32], rng: &mut Rng) -> CompressedMsg;
    /// Theoretical δ in eq. (10), if the operator is unbiased.
    fn delta(&self, dim: usize) -> Option<f64>;
    fn name(&self) -> String;
}

/// Identity (δ = 0): dense f32 transmission.
#[derive(Debug, Clone, Copy, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn compress(&self, g: &[f32], _rng: &mut Rng) -> CompressedMsg {
        CompressedMsg { vec: g.to_vec(), bits: 32 * g.len(), enc: WireEnc::Dense }
    }
    fn delta(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }
    fn name(&self) -> String {
        "none".into()
    }
}

pub use ef::{compress_batch_ef, Ef, EfState};
pub use qsgd::Qsgd;
pub use rand_k::RandK;
pub use top_k::TopK;

/// Build from a config kind. EF kinds get the [`Ef`] wrapper — the same
/// stateless `Compressor` face over the base operator, with the `ef-`
/// name; the residual memory lives in the caller-held [`EfState`]
/// (`EfState::for_kind`), which the trainer, net leader and net worker
/// each maintain for their devices.
pub fn from_kind(kind: CompressionKind) -> Box<dyn Compressor> {
    match kind {
        CompressionKind::None => Box::new(Identity),
        CompressionKind::RandK { k } => Box::new(RandK::new(k)),
        CompressionKind::TopK { k } => Box::new(TopK::new(k)),
        CompressionKind::Qsgd { levels } => Box::new(Qsgd::new(levels)),
        CompressionKind::EfRandK { .. }
        | CompressionKind::EfTopK { .. }
        | CompressionKind::EfQsgd { .. } => Box::new(Ef::new(kind)),
    }
}

/// Below this many total elements (messages × dim), per-device compression
/// runs on the calling thread — dispatch overhead would dominate. Purely a
/// performance gate: each message owns its RNG stream, so serial and
/// parallel execution are bit-identical regardless.
const PAR_MIN_ELEMS: usize = 4096;

/// Compress one message per pre-split RNG stream (device order), in
/// parallel on the shared worker pool, returning the dense reconstructions
/// and the total wire bits.
///
/// This is the uplink step of Algorithms 1–2 as both the fast trainer and
/// the threaded cluster leader execute it. Determinism contract: `rngs[i]`
/// is device i's private stream (see [`Rng::split`]); because no stream is
/// shared, any thread count — including 1 — consumes identical randomness
/// and produces identical messages.
pub fn compress_batch(
    comp: &dyn Compressor,
    msgs: &[&[f32]],
    rngs: &mut [Rng],
    pool: &Pool,
) -> (Vec<Vec<f32>>, u64) {
    assert_eq!(msgs.len(), rngs.len(), "one RNG stream per message");
    let q = msgs.first().map(|m| m.len()).unwrap_or(0);
    let serial = Pool::serial();
    let pool = if msgs.len() * q >= PAR_MIN_ELEMS { pool } else { &serial };
    let compressed = pool.par_map_mut(rngs, |i, rng| comp.compress(msgs[i], rng));
    let bits = compressed.iter().map(|c| c.bits as u64).sum();
    (compressed.into_iter().map(|c| c.vec).collect(), bits)
}

/// Empirically verify unbiasedness and measure δ̂ (used by tests and the
/// compression ablation bench): returns (max |E[C(g)]−g| per coordinate /
/// ‖g‖, E‖C(g)−g‖² / ‖g‖²).
pub fn measure_bias_delta(
    comp: &dyn Compressor,
    g: &[f32],
    trials: usize,
    rng: &mut Rng,
) -> (f64, f64) {
    let q = g.len();
    let mut mean = vec![0.0f64; q];
    let mut err2 = 0.0f64;
    for _ in 0..trials {
        let c = comp.compress(g, rng);
        for j in 0..q {
            mean[j] += c.vec[j] as f64;
        }
        err2 += crate::util::math::dist_sq(&c.vec, g);
    }
    let norm2 = crate::util::math::norm_sq(g).max(1e-30);
    let bias = (0..q)
        .map(|j| (mean[j] / trials as f64 - g[j] as f64).abs())
        .fold(0.0f64, f64::max)
        / norm2.sqrt();
    (bias, err2 / trials as f64 / norm2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_lossless() {
        let mut rng = Rng::new(1);
        let g = vec![1.0f32, -2.0, 3.0];
        let c = Identity.compress(&g, &mut rng);
        assert_eq!(c.vec, g);
        assert_eq!(c.bits, 96);
    }

    #[test]
    fn compress_batch_is_thread_count_invariant() {
        use crate::util::rng::Rng;
        // sized above the gate so the parallel path engages
        let mut gen = Rng::new(9);
        let msgs_owned: Vec<Vec<f32>> = (0..64).map(|_| gen.gauss_vec(128)).collect();
        let msgs: Vec<&[f32]> = msgs_owned.iter().map(|m| m.as_slice()).collect();
        let comp = RandK::new(17);
        let parent = Rng::new(1234);
        let mut rngs_serial = parent.split(msgs.len());
        let (a, bits_a) = compress_batch(&comp, &msgs, &mut rngs_serial, &Pool::serial());
        let mut rngs_par = parent.split(msgs.len());
        let (b, bits_b) = compress_batch(&comp, &msgs, &mut rngs_par, &Pool::new(8));
        assert_eq!(a, b, "messages diverged across thread counts");
        assert_eq!(bits_a, bits_b);
        // and the streams advanced identically
        for (x, y) in rngs_serial.iter().zip(&rngs_par) {
            let (mut x, mut y) = (x.clone(), y.clone());
            assert_eq!(x.next_u64(), y.next_u64());
        }
        // scoped fallback agrees too
        let mut rngs_scoped = parent.split(msgs.len());
        let (c, bits_c) = compress_batch(
            &comp,
            &msgs,
            &mut rngs_scoped,
            &Pool::scoped(crate::util::parallel::Parallelism::new(4)),
        );
        assert_eq!(a, c);
        assert_eq!(bits_a, bits_c);
    }

    #[test]
    fn from_kind_builds_all() {
        let mut rng = Rng::new(2);
        let g = vec![0.5f32; 40];
        for kind in [
            CompressionKind::None,
            CompressionKind::RandK { k: 10 },
            CompressionKind::TopK { k: 10 },
            CompressionKind::Qsgd { levels: 8 },
            CompressionKind::EfRandK { k: 10 },
            CompressionKind::EfTopK { k: 10 },
            CompressionKind::EfQsgd { levels: 8 },
        ] {
            let c = from_kind(kind);
            let out = c.compress(&g, &mut rng);
            assert_eq!(out.vec.len(), 40, "{}", c.name());
            assert!(out.bits > 0);
            assert_eq!(c.name().starts_with("ef-"), kind.is_ef());
        }
    }
}
