//! DRACO baseline (Chen et al., ICML'18, [13]) — fractional-repetition
//! gradient coding with exact majority-vote decoding.
//!
//! Devices are partitioned into groups; every device in group g computes the
//! *same* message: (1/N) Σ_{k ∈ chunk_g} ∇f_k. The server decodes each group
//! by majority vote (distance clustering, so honest f32 jitter is tolerated)
//! and sums the group representatives, recovering μ = (1/N)∇F exactly as
//! long as every group has an honest majority. Per-device computational load
//! is |chunk_g| ≈ r gradients — the "41 gradients" figure the paper quotes
//! for N=100, b=20 (r = 2b+1 = 41).

use crate::util::math::{axpy, dist_sq, Mat};

/// Decode failure: some group had no majority cluster.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    NoMajority { group: usize },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::NoMajority { group } => {
                write!(f, "group {group} has no strict majority agreement")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// Fractional-repetition scheme: device → group, group → subset chunk.
#[derive(Debug, Clone)]
pub struct DracoScheme {
    n: usize,
    /// group id per device
    group_of: Vec<usize>,
    /// subset indices per group (balanced contiguous chunks)
    chunks: Vec<Vec<usize>>,
}

impl DracoScheme {
    /// Partition `n` devices into ⌊n/r⌋ groups of **at least** `r` devices
    /// each; the `n` subsets are partitioned into equally many chunks.
    /// With `r = 2b+1` every group keeps an honest majority under any
    /// placement of `b` Byzantine devices (group sizes ≥ 2b+1).
    pub fn new(n: usize, r: usize) -> Self {
        assert!(r >= 1 && r <= n);
        let n_groups = (n / r).max(1);
        // balanced device partition: groups sized ⌊n/G⌋ or ⌈n/G⌉
        let mut group_of = vec![0usize; n];
        let mut chunks = vec![Vec::new(); n_groups];
        let base = n / n_groups;
        let extra = n % n_groups;
        let mut dev = 0;
        let mut sub = 0;
        for g in 0..n_groups {
            let size = base + usize::from(g < extra);
            for _ in 0..size {
                group_of[dev] = g;
                dev += 1;
            }
            // chunk g owns the same count of subsets
            for _ in 0..size {
                chunks[g].push(sub);
                sub += 1;
            }
        }
        debug_assert_eq!(dev, n);
        debug_assert_eq!(sub, n);
        DracoScheme { n, group_of, chunks }
    }

    pub fn n(&self) -> usize {
        self.n
    }
    pub fn n_groups(&self) -> usize {
        self.chunks.len()
    }
    pub fn group_of(&self, device: usize) -> usize {
        self.group_of[device]
    }
    pub fn chunk(&self, group: usize) -> &[usize] {
        &self.chunks[group]
    }

    /// Computational load (gradients per iteration) of a device.
    pub fn load(&self, device: usize) -> usize {
        self.chunks[self.group_of[device]].len()
    }

    /// Minimum per-group Byzantine tolerance: ⌈(size−1)/2⌉ faults break the
    /// smallest group's majority; this returns the largest `b` such that any
    /// placement of `b` Byzantine devices still decodes.
    pub fn guaranteed_tolerance(&self) -> usize {
        self.chunks.iter().map(|c| c.len()).min().map(|m| (m - 1) / 2).unwrap_or(0)
    }

    /// The honest message of a device: (1/N) Σ_{k ∈ chunk} ∇f_k.
    pub fn honest_message(&self, device: usize, grads: &Mat) -> Vec<f32> {
        let mut out = vec![0.0f32; grads.cols];
        for &k in self.chunk(self.group_of[device]) {
            axpy(1.0, grads.row(k), &mut out);
        }
        crate::util::math::scale(&mut out, 1.0 / self.n as f32);
        out
    }

    /// Majority-vote decode: returns μ = (1/N) Σ_k ∇f_k from the N device
    /// messages (honest + Byzantine, indexed by device id).
    pub fn decode(&self, msgs: &[Vec<f32>], tol: f64) -> Result<Vec<f32>, DecodeError> {
        assert_eq!(msgs.len(), self.n);
        let q = msgs[0].len();
        let mut total = vec![0.0f32; q];
        for g in 0..self.n_groups() {
            let members: Vec<usize> =
                (0..self.n).filter(|&i| self.group_of[i] == g).collect();
            let rep = majority_representative(&members, msgs, tol)
                .ok_or(DecodeError::NoMajority { group: g })?;
            axpy(1.0, &msgs[rep], &mut total);
        }
        Ok(total)
    }
}

/// Pick a member whose message agrees (within `tol` L2 distance) with a
/// strict majority of the group; None if no such member exists.
fn majority_representative(members: &[usize], msgs: &[Vec<f32>], tol: f64) -> Option<usize> {
    let need = members.len() / 2 + 1;
    for &i in members {
        let agree = members
            .iter()
            .filter(|&&j| dist_sq(&msgs[i], &msgs[j]) <= tol * tol)
            .count();
        if agree >= need {
            return Some(i);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::Mat;
    use crate::util::rng::Rng;

    fn grad_matrix(n: usize, q: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_rows(&(0..n).map(|_| rng.gauss_vec(q)).collect::<Vec<_>>())
    }

    fn mu(g: &Mat) -> Vec<f32> {
        (0..g.cols)
            .map(|j| (0..g.rows).map(|k| g.row(k)[j]).sum::<f32>() / g.rows as f32)
            .collect()
    }

    #[test]
    fn partition_is_balanced_and_complete() {
        let s = DracoScheme::new(100, 41);
        assert_eq!(s.n_groups(), 2);
        let sizes: Vec<usize> = (0..2).map(|g| s.chunk(g).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 100);
        assert!(sizes.iter().all(|&x| x == 50));
        // every subset appears exactly once
        let mut seen = vec![false; 100];
        for g in 0..2 {
            for &k in s.chunk(g) {
                assert!(!seen[k]);
                seen[k] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn groups_never_smaller_than_r() {
        for (n, r) in [(100usize, 41usize), (20, 9), (21, 7), (9, 3), (10, 10)] {
            let s = DracoScheme::new(n, r);
            let mut sizes = vec![0usize; s.n_groups()];
            for i in 0..n {
                sizes[s.group_of(i)] += 1;
            }
            assert!(sizes.iter().all(|&x| x >= r), "N={n} r={r}: {sizes:?}");
        }
    }

    #[test]
    fn exact_recovery_no_byzantine() {
        let g = grad_matrix(20, 6, 1);
        let s = DracoScheme::new(20, 5);
        let msgs: Vec<Vec<f32>> = (0..20).map(|i| s.honest_message(i, &g)).collect();
        let decoded = s.decode(&msgs, 1e-6).unwrap();
        let want = mu(&g);
        for j in 0..6 {
            assert!((decoded[j] - want[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn exact_recovery_under_tolerated_byzantine() {
        let g = grad_matrix(21, 6, 2);
        let s = DracoScheme::new(21, 7); // 3 groups of 7 => tolerates 3/group
        let mut msgs: Vec<Vec<f32>> = (0..21).map(|i| s.honest_message(i, &g)).collect();
        // corrupt 3 devices in group 0 and 2 in group 1 (both < majority)
        for &i in &[0usize, 1, 2, 7, 8] {
            msgs[i].iter_mut().for_each(|x| *x = -2.0 * *x + 10.0);
        }
        let decoded = s.decode(&msgs, 1e-6).unwrap();
        let want = mu(&g);
        for j in 0..6 {
            assert!((decoded[j] - want[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn decode_fails_without_majority() {
        let g = grad_matrix(9, 4, 3);
        let s = DracoScheme::new(9, 3);
        let mut msgs: Vec<Vec<f32>> = (0..9).map(|i| s.honest_message(i, &g)).collect();
        // corrupt 2 of 3 in group 0 with IDENTICAL lies => lie wins nothing:
        // strict majority requires 2 agreeing; the two liars agree...
        // so craft DIFFERENT lies to kill any majority
        msgs[0].iter_mut().for_each(|x| *x += 100.0);
        msgs[1].iter_mut().for_each(|x| *x -= 100.0);
        assert_eq!(s.decode(&msgs, 1e-6), Err(DecodeError::NoMajority { group: 0 }));
    }

    #[test]
    fn colluding_majority_defeats_draco_as_expected() {
        // sanity: DRACO's guarantee needs honest majority per group
        let g = grad_matrix(9, 4, 4);
        let s = DracoScheme::new(9, 3);
        let mut msgs: Vec<Vec<f32>> = (0..9).map(|i| s.honest_message(i, &g)).collect();
        let lie: Vec<f32> = vec![7.0; 4];
        msgs[0] = lie.clone();
        msgs[1] = lie.clone();
        let decoded = s.decode(&msgs, 1e-6).unwrap();
        // decoded group-0 contribution is the lie, not the truth
        assert!((decoded[0] - (lie[0] + 0.0)).abs() < 20.0); // just: no panic, wrong value
        let want = mu(&g);
        assert!((decoded[0] - want[0]).abs() > 1.0);
    }

    #[test]
    fn tolerance_reporting() {
        // N=100, r=41 => 2 groups of 50 => tolerates 24 anywhere
        assert_eq!(DracoScheme::new(100, 41).guaranteed_tolerance(), 24);
        assert_eq!(DracoScheme::new(20, 5).guaranteed_tolerance(), 2);
    }

    #[test]
    fn load_is_order_of_paper_quote() {
        // paper quotes 41 gradients/device for the ideal r | N layout; our
        // floor-partition at N=100, r=41 gives 50 — same order of compute,
        // with a STRONGER worst-case tolerance (24 vs 20). Recorded in
        // EXPERIMENTS.md.
        let s = DracoScheme::new(100, 41);
        for i in 0..100 {
            assert_eq!(s.load(i), 50);
        }
    }
}
