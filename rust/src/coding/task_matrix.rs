//! Computation-task matrices S ∈ {0,1}^{N×N} with exactly d ones per row.
//!
//! Row i lists the d subset *slots* a device executing task i must compute
//! (the slot → subset mapping is the per-iteration permutation p^t, see
//! [`crate::coding::assignment`]). The paper's Ŝ is [`TaskMatrix::cyclic`]:
//! row i is the cyclic shift of `[1,…,1,0,…,0]` (d ones), which Lemma 1
//! proves is the variance-minimizing choice (balanced columns θ_j = d).

use crate::util::rng::Rng;

/// Sparse row representation of a task matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskMatrix {
    n: usize,
    d: usize,
    /// rows[i] = sorted subset-slot indices k with s(i,k) = 1.
    rows: Vec<Vec<usize>>,
}

impl TaskMatrix {
    /// The paper's cyclic matrix Ŝ: row i covers slots {i, i+1, …, i+d−1 mod N}.
    pub fn cyclic(n: usize, d: usize) -> Self {
        assert!(d >= 1 && d <= n, "need 1 <= d <= n");
        let rows = (0..n)
            .map(|i| {
                let mut r: Vec<usize> = (0..d).map(|j| (i + j) % n).collect();
                r.sort_unstable();
                r
            })
            .collect();
        TaskMatrix { n, d, rows }
    }

    /// Fractional-repetition layout: devices in group g = ⌊i/d⌋ all cover the
    /// same slot block {g·d, …, g·d+d−1} (wrapping into the tail block when
    /// d ∤ n). Used by the DRACO baseline and the Lemma-1 ablation.
    pub fn fractional_repetition(n: usize, d: usize) -> Self {
        assert!(d >= 1 && d <= n);
        let rows = (0..n)
            .map(|i| {
                let g = i / d;
                let start = (g * d) % n;
                let mut r: Vec<usize> = (0..d).map(|j| (start + j) % n).collect();
                r.sort_unstable();
                r.dedup();
                r
            })
            .collect();
        TaskMatrix { n, d, rows }
    }

    /// Random d-subset per row (unbalanced columns ⇒ strictly worse Lemma-1
    /// variance in expectation; ablation baseline).
    pub fn random(n: usize, d: usize, rng: &mut Rng) -> Self {
        assert!(d >= 1 && d <= n);
        let rows = (0..n)
            .map(|_| {
                let mut r = rng.choose_k(n, d);
                r.sort_unstable();
                r
            })
            .collect();
        TaskMatrix { n, d, rows }
    }

    pub fn n(&self) -> usize {
        self.n
    }
    pub fn d(&self) -> usize {
        self.d
    }

    /// Slot indices covered by task `i`.
    pub fn row(&self, i: usize) -> &[usize] {
        &self.rows[i]
    }

    /// Column sums θ_j (how many tasks cover slot j). For the cyclic matrix
    /// all θ_j = d — the balanced layout attaining Lemma 1's infimum.
    pub fn column_counts(&self) -> Vec<usize> {
        let mut theta = vec![0usize; self.n];
        for r in &self.rows {
            for &k in r {
                theta[k] += 1;
            }
        }
        theta
    }

    /// The Lemma-1 objective for THIS matrix, in closed form:
    /// E‖(1/(dH)) h S − (1/N) 1‖² = (Σθ_j² ·(H−1)/(N−1) + dN − dNH/N·…)
    /// — evaluated from eq. (40)–(41) of the appendix, valid for any S with
    /// d ones per row:
    ///   = 1/(d²H) [ d + (H−1)/(N(N−1)) (Σθ² − dN) ] − 1/N … (see tests).
    pub fn lemma1_objective(&self, h: usize) -> f64 {
        let n = self.n as f64;
        let d = self.d as f64;
        let hh = h as f64;
        let sum_theta_sq: f64 =
            self.column_counts().iter().map(|&t| (t * t) as f64).sum();
        // From (38)-(41): E = (1/(d²H²)) [ H d + H(H−1)/(N(N−1)) (Σθ² − dN) ] − 1/N
        (1.0 / (d * d * hh * hh))
            * (hh * d + hh * (hh - 1.0) / (n * (n - 1.0)) * (sum_theta_sq - d * n))
            - 1.0 / n
    }

    /// Monte-Carlo estimate of the Lemma-1 objective (validates the closed
    /// form and the cyclic optimality in tests).
    pub fn lemma1_monte_carlo(&self, h: usize, trials: usize, rng: &mut Rng) -> f64 {
        let n = self.n;
        let mut acc = 0.0f64;
        let mut col = vec![0.0f64; n];
        for _ in 0..trials {
            col.iter_mut().for_each(|c| *c = 0.0);
            for &i in rng.choose_k(n, h).iter() {
                for &k in &self.rows[i] {
                    col[k] += 1.0;
                }
            }
            let scale = 1.0 / (self.d as f64 * h as f64);
            let mut ss = 0.0;
            for &c in &col {
                let v = c * scale - 1.0 / n as f64;
                ss += v * v;
            }
            acc += ss;
        }
        acc / trials as f64
    }
}

/// Closed-form infimum from Lemma 1: (N−H)(N−d) / (dH(N−1)N), attained by
/// the cyclic (column-balanced) matrix.
pub fn lemma1_infimum(n: usize, h: usize, d: usize) -> f64 {
    let (n, h, d) = (n as f64, h as f64, d as f64);
    (n - h) * (n - d) / (d * h * (n - 1.0) * n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cyclic_structure() {
        let s = TaskMatrix::cyclic(5, 2);
        assert_eq!(s.row(0), &[0, 1]);
        assert_eq!(s.row(4), &[0, 4]); // wraps
        assert_eq!(s.column_counts(), vec![2; 5]);
    }

    #[test]
    fn cyclic_d_equals_n_is_full() {
        let s = TaskMatrix::cyclic(4, 4);
        for i in 0..4 {
            assert_eq!(s.row(i), &[0, 1, 2, 3]);
        }
    }

    #[test]
    fn fractional_repetition_groups_share_rows() {
        let s = TaskMatrix::fractional_repetition(6, 3);
        assert_eq!(s.row(0), s.row(1));
        assert_eq!(s.row(0), s.row(2));
        assert_eq!(s.row(3), s.row(5));
        assert_ne!(s.row(0), s.row(3));
    }

    #[test]
    fn random_rows_have_d_distinct() {
        let mut rng = Rng::new(1);
        let s = TaskMatrix::random(20, 7, &mut rng);
        for i in 0..20 {
            assert_eq!(s.row(i).len(), 7);
            let mut r = s.row(i).to_vec();
            r.dedup();
            assert_eq!(r.len(), 7);
        }
    }

    #[test]
    fn closed_form_matches_infimum_for_cyclic() {
        for (n, h, d) in [(10, 7, 3), (100, 80, 10), (100, 65, 5), (7, 4, 2)] {
            let s = TaskMatrix::cyclic(n, d);
            let cf = s.lemma1_objective(h);
            let inf = lemma1_infimum(n, h, d);
            assert!(
                (cf - inf).abs() < 1e-12,
                "closed form {cf} vs infimum {inf} for N={n},H={h},d={d}"
            );
        }
    }

    #[test]
    fn monte_carlo_matches_closed_form_cyclic() {
        let mut rng = Rng::new(42);
        let s = TaskMatrix::cyclic(20, 4);
        let mc = s.lemma1_monte_carlo(15, 20_000, &mut rng);
        let cf = s.lemma1_objective(15);
        assert!((mc - cf).abs() < 0.1 * cf.max(1e-6), "mc={mc} cf={cf}");
    }

    #[test]
    fn monte_carlo_matches_closed_form_random_matrix() {
        // the closed form (38)–(41) holds for ANY d-regular-row matrix
        let mut rng = Rng::new(7);
        let s = TaskMatrix::random(15, 4, &mut rng);
        let mc = s.lemma1_monte_carlo(10, 30_000, &mut rng);
        let cf = s.lemma1_objective(10);
        assert!((mc - cf).abs() < 0.15 * cf.max(1e-6), "mc={mc} cf={cf}");
    }

    #[test]
    fn cyclic_beats_or_ties_everything() {
        // Lemma 1: cyclic attains the infimum over all d-row matrices
        let mut rng = Rng::new(9);
        let (n, h, d) = (12, 8, 3);
        let cyc = TaskMatrix::cyclic(n, d).lemma1_objective(h);
        for seed in 0..20 {
            let mut r = Rng::new(seed);
            let rand = TaskMatrix::random(n, d, &mut r).lemma1_objective(h);
            assert!(cyc <= rand + 1e-12, "cyclic {cyc} > random {rand}");
        }
        let fr = TaskMatrix::fractional_repetition(n, d).lemma1_objective(h);
        assert!(cyc <= fr + 1e-12);
        let _ = &mut rng;
    }

    #[test]
    fn infimum_vanishes_at_d_equals_n_or_h_equals_n() {
        assert_eq!(lemma1_infimum(50, 30, 50), 0.0);
        assert_eq!(lemma1_infimum(50, 50, 10), 0.0);
    }
}
