//! Gradient-coding layer: the paper's cyclic computation-task matrix Ŝ
//! (§IV, Lemma 1), per-iteration random assignment (Algorithm 1, lines 3–6),
//! the coded-vector encoder (eq. 5), and the DRACO fractional-repetition
//! baseline (§VII-A, [13]).

pub mod assignment;
pub mod draco;
pub mod encoder;
pub mod task_matrix;

pub use assignment::Assignment;
pub use draco::{DracoScheme, DecodeError};
pub use encoder::{encode_coded, encode_coded_into};
pub use task_matrix::TaskMatrix;
