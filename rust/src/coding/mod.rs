//! Gradient-coding layer: the paper's cyclic computation-task matrix Ŝ
//! (§IV, Lemma 1), per-iteration random assignment (Algorithm 1, lines 3–6),
//! the coded-vector encoder (eq. 5), and the DRACO fractional-repetition
//! baseline (§VII-A, [13]).
//!
//! How the pieces compose, per iteration t:
//!
//! 1. [`TaskMatrix::cyclic`] fixes Ŝ once per run — row i covers slots
//!    {i, …, i+d−1 mod N}, the column-balanced layout attaining Lemma 1's
//!    variance infimum (N−H)(N−d) / (dH(N−1)N).
//! 2. [`Assignment::draw`] samples the two uniform permutations (T^t, p^t)
//!    that randomize which device runs which task and which dataset subset
//!    hides behind each slot — the source of LAD's unbiasedness (eq. 44).
//! 3. [`encode_coded_into`] produces g_i = (1/d) Σ_{k∈row} ∇f_{p_k}(x), a
//!    d-row gather + axpy over the per-subset gradient matrix: O(dQ) per
//!    device, O(NdQ) per iteration — the L3 hot path that
//!    `util::parallel` distributes across devices.
//! 4. [`DracoScheme`] is the exact-recovery baseline: fractional-repetition
//!    groups + majority-vote decode, O(r²Q) per group, recovering
//!    (1/N)∇F exactly while every group keeps an honest majority.

pub mod assignment;
pub mod draco;
pub mod encoder;
pub mod task_matrix;

pub use assignment::Assignment;
pub use draco::{DracoScheme, DecodeError};
pub use encoder::{encode_coded, encode_coded_into};
pub use task_matrix::TaskMatrix;
