//! Per-iteration random assignment (Algorithm 1, lines 3–6).
//!
//! Each iteration the server draws two independent uniform permutations:
//! task indices T^t (device i executes task row T_i of Ŝ) and the slot→subset
//! map p^t (slot k refers to subset p_k). Both are broadcast; devices then
//! compute {∇f_{p_k}(x^t) : ŝ(T_i, k) = 1}.

use crate::util::rng::Rng;

/// One iteration's assignment.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// T^t — tasks[i] is the Ŝ-row assigned to device i.
    pub tasks: Vec<usize>,
    /// p^t — p[k] is the dataset subset behind slot k.
    pub p: Vec<usize>,
}

impl Assignment {
    /// Draw a fresh assignment for `n` devices/subsets.
    pub fn draw(n: usize, rng: &mut Rng) -> Self {
        Assignment { tasks: rng.permutation(n), p: rng.permutation(n) }
    }

    /// Identity assignment (tests / DRACO, which fixes its grouping).
    pub fn identity(n: usize) -> Self {
        Assignment { tasks: (0..n).collect(), p: (0..n).collect() }
    }

    /// Subsets device `i` must compute, given the task matrix row.
    pub fn subsets_for<'a>(&'a self, row: &'a [usize]) -> impl Iterator<Item = usize> + 'a {
        row.iter().map(move |&k| self.p[k])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::task_matrix::TaskMatrix;

    #[test]
    fn draw_produces_permutations() {
        let mut rng = Rng::new(4);
        let a = Assignment::draw(50, &mut rng);
        let mut t = a.tasks.clone();
        let mut p = a.p.clone();
        t.sort_unstable();
        p.sort_unstable();
        assert_eq!(t, (0..50).collect::<Vec<_>>());
        assert_eq!(p, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn independent_draws_differ() {
        let mut rng = Rng::new(4);
        let a = Assignment::draw(50, &mut rng);
        let b = Assignment::draw(50, &mut rng);
        assert_ne!(a.tasks, b.tasks);
        assert_ne!(a.p, b.p);
    }

    #[test]
    fn subsets_for_maps_through_p() {
        let s = TaskMatrix::cyclic(4, 2);
        let a = Assignment { tasks: vec![2, 3, 0, 1], p: vec![3, 2, 1, 0] };
        // device 0 runs task 2 => slots {2,3} => subsets {p[2],p[3]} = {1,0}
        let subs: Vec<usize> = a.subsets_for(s.row(a.tasks[0])).collect();
        assert_eq!(subs, vec![1, 0]);
    }

    #[test]
    fn every_subset_covered_exactly_d_times() {
        // with the cyclic matrix and any permutation pair, each subset is
        // computed by exactly d devices — the redundancy LAD leverages
        let mut rng = Rng::new(8);
        let n = 30;
        let d = 7;
        let s = TaskMatrix::cyclic(n, d);
        let a = Assignment::draw(n, &mut rng);
        let mut count = vec![0usize; n];
        for i in 0..n {
            for sub in a.subsets_for(s.row(a.tasks[i])) {
                count[sub] += 1;
            }
        }
        assert_eq!(count, vec![d; n]);
    }
}
