//! Coded-vector encoder — eq. (5):
//! g_i^t = (1/d) Σ_{k : ŝ(T_i,k)=1} ∇f_{p_k}(x^t).
//!
//! The per-subset gradient matrix G (row k = ∇f_k) is produced by a gradient
//! oracle (native Rust or the PJRT artifact); encoding is a d-row gather +
//! axpy, which is the L3 hot path at d = O(N). The axpy/scale calls run on
//! the widest kernel tier the `util::math` dispatcher detected (scalar /
//! SSE2 / AVX2+FMA — bit-identical across tiers, so coded vectors never
//! depend on the host CPU).

use crate::coding::assignment::Assignment;
use crate::util::math::{axpy, scale, Mat};

/// Encode device `i`'s coded vector into `out` (len Q), given the per-subset
/// gradient matrix `grads` (N×Q, row k = ∇f_k), the task row for this device
/// and the iteration's assignment.
pub fn encode_coded_into(grads: &Mat, row: &[usize], assign: &Assignment, out: &mut [f32]) {
    debug_assert_eq!(out.len(), grads.cols);
    out.fill(0.0);
    for &k in row {
        axpy(1.0, grads.row(assign.p[k]), out);
    }
    scale(out, 1.0 / row.len() as f32);
}

/// Allocating variant of [`encode_coded_into`].
pub fn encode_coded(grads: &Mat, row: &[usize], assign: &Assignment) -> Vec<f32> {
    let mut out = vec![0.0f32; grads.cols];
    encode_coded_into(grads, row, assign, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::task_matrix::TaskMatrix;
    use crate::util::rng::Rng;

    fn grads_3x2() -> Mat {
        Mat::from_rows(&[vec![1.0, 10.0], vec![2.0, 20.0], vec![4.0, 40.0]])
    }

    #[test]
    fn averages_selected_rows() {
        let g = grads_3x2();
        let assign = Assignment::identity(3);
        let out = encode_coded(&g, &[0, 2], &assign);
        assert_eq!(out, vec![2.5, 25.0]);
    }

    #[test]
    fn permutation_reroutes_subsets() {
        let g = grads_3x2();
        let assign = Assignment { tasks: vec![0, 1, 2], p: vec![2, 0, 1] };
        // slots {0,1} -> subsets {2,0}
        let out = encode_coded(&g, &[0, 1], &assign);
        assert_eq!(out, vec![2.5, 25.0]);
    }

    #[test]
    fn d_equals_n_gives_exact_mean_gradient() {
        // the d = N limit of LAD: every device sends μ = (1/N)∇F exactly
        let mut rng = Rng::new(5);
        let n = 8;
        let q = 5;
        let rows: Vec<Vec<f32>> = (0..n).map(|_| rng.gauss_vec(q)).collect();
        let g = Mat::from_rows(&rows);
        let s = TaskMatrix::cyclic(n, n);
        let assign = Assignment::draw(n, &mut rng);
        let mu: Vec<f32> = (0..q)
            .map(|j| (0..n).map(|k| g.row(k)[j]).sum::<f32>() / n as f32)
            .collect();
        for i in 0..n {
            let out = encode_coded(&g, s.row(assign.tasks[i]), &assign);
            for j in 0..q {
                assert!((out[j] - mu[j]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn unbiasedness_over_assignments() {
        // E[g_i] = μ over random assignments (eq. 44)
        let mut rng = Rng::new(6);
        let n = 10;
        let q = 4;
        let rows: Vec<Vec<f32>> = (0..n).map(|_| rng.gauss_vec(q)).collect();
        let g = Mat::from_rows(&rows);
        let s = TaskMatrix::cyclic(n, 3);
        let mu: Vec<f64> = (0..q)
            .map(|j| (0..n).map(|k| g.row(k)[j] as f64).sum::<f64>() / n as f64)
            .collect();
        let trials = 20_000;
        let mut acc = vec![0.0f64; q];
        for _ in 0..trials {
            let assign = Assignment::draw(n, &mut rng);
            let out = encode_coded(&g, s.row(assign.tasks[0]), &assign);
            for j in 0..q {
                acc[j] += out[j] as f64;
            }
        }
        for j in 0..q {
            assert!(
                (acc[j] / trials as f64 - mu[j]).abs() < 0.05,
                "coordinate {j}: {} vs {}",
                acc[j] / trials as f64,
                mu[j]
            );
        }
    }
}
