//! # LAD / Com-LAD — Byzantine-robust, communication-efficient distributed training
//!
//! Rust coordinator (Layer 3) for the reproduction of *"Byzantine-Robust and
//! Communication-Efficient Distributed Training: Compressive and Cyclic
//! Gradient Coding"*.
//!
//! The crate provides:
//!
//! * [`coding`] — cyclic gradient-coding task matrices (the paper's Ŝ),
//!   per-iteration random assignment, the coded-vector encoder (eq. 5) and a
//!   DRACO fractional-repetition baseline decoder.
//! * [`aggregation`] — a zoo of κ-robust aggregation rules (CWTM, median,
//!   geometric median, Krum, MCC, FABA, TGN, momentum-filter) plus NNM
//!   pre-aggregation.
//! * [`attack`] — Byzantine behaviours (sign-flip, ALIE, IPM, …).
//! * [`compress`] — unbiased compression operators (rand-K, QSGD) with exact
//!   bit accounting, biased top-K for ablations, and an error-feedback
//!   memory stage (`ef-rand-k` / `ef-top-k` / `ef-qsgd`) carrying each
//!   device's compression residual across iterations.
//! * [`grad`] — gradient oracles: a native Rust linear-regression oracle and
//!   the PJRT-backed oracle that executes the AOT-lowered JAX/Pallas
//!   artifacts produced by `python/compile/aot.py`.
//! * [`server`] — the training loop (Algorithms 1 and 2), metrics, and a
//!   threaded leader/worker cluster simulation.
//! * [`net`] — the multi-node transport layer: a versioned binary wire
//!   codec with per-compressor payload encodings, CRC32 framing, and a
//!   `Transport` trait (in-process channels / TCP / Unix-domain sockets)
//!   behind the leader and worker event loops, so the Fig. 1 topology runs
//!   across real processes (`lad node-leader` / `lad node-worker`) with
//!   measured — not just analytic — communication bytes.
//! * [`obs`] — the structured observability layer: a typed event journal
//!   (lock-sharded JSONL sink), a named counter/gauge/histogram registry
//!   (power-of-2 ns buckets), nestable [`span!`] profiling guards with a
//!   Chrome-trace exporter, and a live leader status endpoint — all
//!   wall-clock-only telemetry, bit-identical traces with the recorder
//!   on or off (fuzz-pinned).
//! * [`theory`] — closed-form error terms (κ₁..κ₄, ξ₁..ξ₄, ε) from the
//!   convergence analysis, used by the Fig. 2/3 reproductions.
//! * [`experiments`] — drivers that regenerate every figure in the paper.
//! * [`sweep`] — the declarative scenario-sweep engine: TOML grid specs
//!   expanded into content-addressed jobs, a resumable journaled queue
//!   over one two-level thread budget, and a JSONL/CSV result sink; the
//!   figure drivers delegate execution to it, and `lad sweep` runs
//!   arbitrary attack × rule × compressor × participation grids.
//! * [`util::parallel`] — the zero-dependency parallel engine (persistent
//!   `Pool` + scoped-spawn fallback) behind the device loop, the shared
//!   Gram distance kernel of the O(N²Q) aggregation rules
//!   ([`aggregation::gram`]) and the figure sweeps; bit-identical results
//!   for any thread count (`TrainConfig::threads`) and for the scalar vs
//!   SIMD math backends (`--features simd`).
//!
//! Python/JAX/Pallas run only at build time (`make artifacts`); at run time
//! the coordinator loads `artifacts/*.hlo.txt` through [`runtime`] (stubbed
//! unless built with `--features pjrt`).
//!
//! The crate is **zero-external-dependency**: the only `[dependencies]`
//! entry is the vendored `anyhow` shim under `rust/vendor/anyhow`, so the
//! whole workspace builds offline.

pub mod aggregation;
pub mod attack;
pub mod bench_support;
pub mod cli;
pub mod coding;
pub mod compress;
pub mod config;
pub mod data;
pub mod experiments;
pub mod grad;
pub mod net;
pub mod obs;
pub mod proptest_lite;
pub mod runtime;
pub mod server;
pub mod sweep;
pub mod theory;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
