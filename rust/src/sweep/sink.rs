//! Append-only JSONL result sink for the sweep queue.
//!
//! Two files live in a sweep's output directory:
//!
//! * `manifest.jsonl` — the crash-safe **journal**: one compact JSON
//!   record per *completed* job, appended (and flushed) the moment the
//!   job finishes, in completion order. `--resume` reads it back, skips
//!   every journaled job, and compacts the file (atomically) first, so a
//!   torn final line from a kill mid-append is dropped rather than glued
//!   onto the next appended record.
//! * `results.jsonl` — the deterministic **sink**: the same records,
//!   rewritten in spec (job) order once every job of the spec is
//!   journaled. Resumed and uninterrupted sweeps emit bit-identical
//!   `results.jsonl` because the journal lines are copied verbatim —
//!   a record is serialized exactly once, when its job completes.
//!
//! Records echo the full resolved configuration plus every
//! *deterministic* trace field (final loss, sampled loss curve, analytic
//! bit accounting, measured wire bytes, anomaly count). Wall-clock time
//! is deliberately excluded — it would break the bit-identity contract.
//! Non-finite floats (a diverged run's `NaN`/`inf` loss) are encoded as
//! strings, since JSON has no literal for them.
//!
//! `results.csv` is the pivot for plotting: one row per job — id, label,
//! one column per grid axis, and the headline metrics. `report.csv` is
//! the cross-seed summary on top of it: one row per non-`seed` grid
//! coordinate with the mean ± population std of the final loss over the
//! `seed` axis, plus the coordinate's aggregation-rule kernel latency
//! quantiles when the sweep ran under an obs context (see
//! [`write_report`]).

use crate::config::CompressionKind;
use crate::obs::Obs;
use crate::server::TrainTrace;
use crate::sweep::spec::Job;
use crate::util::parallel::Pool;
use crate::util::json::{self, Json};
use crate::Result;
use anyhow::{ensure, Context};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// JSON number that survives non-finite values (encoded as strings).
fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Str(format!("{x}"))
    }
}

/// Build the one-line JSON record for a completed job.
pub fn job_record(job: &Job, tr: &TrainTrace) -> Json {
    let cfg = &job.cfg;
    let mut config = BTreeMap::new();
    config.insert("n_devices".to_string(), Json::Num(cfg.n_devices as f64));
    config.insert("n_honest".to_string(), Json::Num(cfg.n_honest as f64));
    config.insert("d".to_string(), Json::Num(cfg.d as f64));
    config.insert("dim".to_string(), Json::Num(cfg.dim as f64));
    config.insert("iters".to_string(), Json::Num(cfg.iters as f64));
    config.insert("lr".to_string(), num(cfg.lr));
    config.insert("sigma_h".to_string(), num(cfg.sigma_h));
    config.insert("aggregator".to_string(), Json::Str(cfg.aggregator.name().to_string()));
    config.insert("nnm".to_string(), Json::Bool(cfg.nnm));
    config.insert("trim_frac".to_string(), num(cfg.trim_frac));
    config.insert("attack".to_string(), Json::Str(cfg.attack.name().to_string()));
    config.insert("compression".to_string(), Json::Str(cfg.compression.name().to_string()));
    match cfg.compression {
        CompressionKind::RandK { k }
        | CompressionKind::TopK { k }
        | CompressionKind::EfRandK { k }
        | CompressionKind::EfTopK { k } => {
            config.insert("compression_k".to_string(), Json::Num(k as f64));
        }
        CompressionKind::Qsgd { levels } | CompressionKind::EfQsgd { levels } => {
            config.insert("compression_levels".to_string(), Json::Num(levels as f64));
        }
        CompressionKind::None => {}
    }
    config.insert("log_every".to_string(), Json::Num(cfg.log_every as f64));
    // seeds are echoed as decimal strings: a u64 above 2^53 would be
    // silently rounded through the f64-backed Json::Num, corrupting the
    // exact-reproduction contract of the config echo
    config.insert("data_seed".to_string(), Json::Str(job.data_seed.to_string()));
    config.insert("run_seed".to_string(), Json::Str(job.run_seed.to_string()));
    config.insert("stall_prob".to_string(), num(job.stall_prob));
    config.insert(
        "gather_deadline_ms".to_string(),
        Json::Num(cfg.net.gather_deadline_ms as f64),
    );
    config.insert("device_compression".to_string(), Json::Bool(cfg.net.device_compression));
    if let Some(r) = job.draco_r {
        config.insert("draco_r".to_string(), Json::Num(r as f64));
    }

    let mut axes = BTreeMap::new();
    for (k, v) in &job.axes {
        axes.insert(k.to_string(), Json::Str(v.clone()));
    }

    let mut rec = BTreeMap::new();
    rec.insert("id".to_string(), Json::Str(job.id.clone()));
    rec.insert("label".to_string(), Json::Str(job.label.clone()));
    rec.insert("axes".to_string(), Json::Obj(axes));
    rec.insert("config".to_string(), Json::Obj(config));
    rec.insert("final_loss".to_string(), num(tr.final_loss));
    rec.insert("total_bits".to_string(), Json::Num(tr.total_bits() as f64));
    rec.insert("anomalies".to_string(), Json::Num(tr.anomalies as f64));
    rec.insert("wire_up_bytes".to_string(), Json::Num(tr.wire_up_bytes as f64));
    rec.insert("wire_down_bytes".to_string(), Json::Num(tr.wire_down_bytes as f64));
    rec.insert(
        "iters".to_string(),
        Json::Arr(tr.iters.iter().map(|&i| Json::Num(i as f64)).collect()),
    );
    rec.insert("loss".to_string(), Json::Arr(tr.loss.iter().map(|&x| num(x)).collect()));
    rec.insert(
        "update_norm".to_string(),
        Json::Arr(tr.grad_update_norm.iter().map(|&x| num(x)).collect()),
    );
    rec.insert(
        "bits".to_string(),
        Json::Arr(tr.bits.iter().map(|&b| Json::Num(b as f64)).collect()),
    );
    // wall-clock time is deliberately NOT recorded: records must be
    // bit-identical across reruns and resumes
    Json::Obj(rec)
}

/// Append-only, per-line-flushed journal writer.
pub struct ManifestWriter {
    out: BufWriter<File>,
}

impl ManifestWriter {
    /// Open (creating if needed) the journal for appending.
    pub fn append<P: AsRef<Path>>(path: P) -> Result<ManifestWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening sweep manifest {:?}", path.as_ref()))?;
        Ok(ManifestWriter { out: BufWriter::new(f) })
    }

    /// Append one record line and flush, so a killed sweep loses at most
    /// the in-flight job.
    pub fn append_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.out, "{line}")?;
        self.out.flush()?;
        Ok(())
    }
}

/// Read the journal back as `job id → verbatim record line`. A truncated
/// final line (the killed-mid-write case `--resume` exists for) is
/// ignored with a note; corruption anywhere else is an error.
pub fn read_manifest<P: AsRef<Path>>(path: P) -> Result<BTreeMap<String, String>> {
    let path = path.as_ref();
    if !path.exists() {
        return Ok(BTreeMap::new());
    }
    let body =
        std::fs::read_to_string(path).with_context(|| format!("reading manifest {path:?}"))?;
    let lines: Vec<&str> = body.lines().collect();
    let mut map = BTreeMap::new();
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        match json::parse(line) {
            Ok(rec) => {
                let id = rec
                    .get("id")
                    .and_then(Json::as_str)
                    .with_context(|| format!("manifest line {} has no \"id\"", i + 1))?;
                map.insert(id.to_string(), line.to_string());
            }
            Err(e) => {
                ensure!(
                    i + 1 == lines.len(),
                    "corrupt manifest line {} of {path:?}: {e}",
                    i + 1
                );
                eprintln!(
                    "sweep: ignoring truncated final manifest line {} ({e}) — \
                     the interrupted job will rerun",
                    i + 1
                );
            }
        }
    }
    Ok(map)
}

/// Atomic file write (tmp + rename): a kill mid-write can never leave a
/// truncated file that looks complete.
fn write_atomic(path: &Path, body: &str) -> Result<()> {
    // append (not replace) the extension so results.jsonl and results.csv
    // never share one temp name
    let mut name = path.file_name().map(|s| s.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    let tmp = path.with_file_name(name);
    std::fs::write(&tmp, body).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {path:?}"))?;
    Ok(())
}

/// Write `results.jsonl`: the journaled record of every job, in spec
/// order, copied verbatim (see the module docs for why this makes resumed
/// and uninterrupted sweeps bit-identical).
pub fn write_results(
    out_dir: &Path,
    jobs: &[Job],
    records: &BTreeMap<String, String>,
) -> Result<PathBuf> {
    let path = out_dir.join("results.jsonl");
    let mut body = String::new();
    for job in jobs {
        let line = records
            .get(&job.id)
            .with_context(|| format!("job {} ({}) missing from the journal", job.id, job.label))?;
        body.push_str(line);
        body.push('\n');
    }
    write_atomic(&path, &body)?;
    Ok(path)
}

/// True when the journaled records' `iters` arrays (each job's loss-curve
/// x grid) do not all match. The sweep-pivot analogue of
/// [`crate::experiments::common::ExperimentOutput::x_grids_disagree`]: a
/// plot overlaying the pivot's per-job curves on one x axis would then
/// silently compare samples taken at different iterations (e.g. an
/// `ef-vs-coding` run whose arms were edited to log on different grids).
pub fn pivot_x_grids_disagree(grids: &[Json]) -> bool {
    match grids.split_first() {
        None => false,
        Some((first, rest)) => rest.iter().any(|g| g != first),
    }
}

/// Write `results.csv`: one row per job — id, label, one column per grid
/// axis (canonical order), and the headline metrics — the pivot the
/// plotting scripts consume. Warns (like `save_csv` does for the figure
/// CSVs) when the jobs' loss curves sample different iteration grids.
pub fn write_pivot_csv(
    out_dir: &Path,
    jobs: &[Job],
    records: &BTreeMap<String, String>,
) -> Result<PathBuf> {
    let path = out_dir.join("results.csv");
    let axis_keys: Vec<&'static str> =
        jobs.first().map(|j| j.axes.iter().map(|(k, _)| *k).collect()).unwrap_or_default();
    let mut body = String::new();
    body.push_str("id,label");
    for k in &axis_keys {
        body.push(',');
        body.push_str(k);
    }
    body.push_str(",final_loss,total_bits,anomalies\n");
    let mut grids: Vec<Json> = Vec::with_capacity(jobs.len());
    for job in jobs {
        let line = records
            .get(&job.id)
            .with_context(|| format!("job {} missing from the journal", job.id))?;
        let rec = json::parse(line).map_err(|e| anyhow::anyhow!("re-parsing record: {e}"))?;
        if let Some(g) = rec.get("iters") {
            grids.push(g.clone());
        }
        let metric = |key: &str| -> String {
            match rec.get(key) {
                Some(Json::Num(x)) => format!("{x}"),
                Some(Json::Str(s)) => s.clone(), // non-finite encoded as string
                _ => String::new(),
            }
        };
        body.push_str(&crate::util::csv::escape(&job.id));
        body.push(',');
        body.push_str(&crate::util::csv::escape(&job.label));
        for (_, v) in &job.axes {
            body.push(',');
            body.push_str(&crate::util::csv::escape(v));
        }
        body.push(',');
        body.push_str(&metric("final_loss"));
        body.push(',');
        body.push_str(&metric("total_bits"));
        body.push(',');
        body.push_str(&metric("anomalies"));
        body.push('\n');
    }
    if pivot_x_grids_disagree(&grids) {
        eprintln!(
            "warning: {}: job loss curves sample different iteration grids — \
             overlaying results.csv curves on one x axis mixes different \
             iterations across jobs",
            path.display()
        );
    }
    write_atomic(&path, &body)?;
    Ok(path)
}

/// The `aggregate_kernel/<rule>` latency quantile cells for one report
/// row: `p50,p95,p99` in nanoseconds when the sweep ran with an enabled
/// obs context and the rule's kernel histogram holds samples; three
/// empty cells otherwise (obs off, or an arm — e.g. DRACO decoding —
/// that never entered the robust-aggregation kernel). Kernel timings
/// are wall clock, so an obs-on report is NOT bit-stable across reruns;
/// the determinism CI runs its compared sweeps obs-off, where the cells
/// are empty on both sides.
fn kernel_quantile_cells(obs: &Obs, rule: &str) -> String {
    let hist = obs
        .metrics()
        .and_then(|m| m.histogram_get(&format!("aggregate_kernel/{rule}")))
        .filter(|h| h.count() > 0);
    match hist {
        Some(h) => {
            format!("{},{},{}", h.quantile(0.50), h.quantile(0.95), h.quantile(0.99))
        }
        None => ",,".to_string(),
    }
}

/// Write `report.csv`: the cross-seed summary. One row per non-`seed`
/// grid coordinate, in spec order — the coordinate's axis values, the
/// number of runs aggregated, and the mean ± population std of
/// `final_loss` over the `seed` axis. A spec without a `seed` axis
/// degenerates to one row per coordinate with `runs = 1` and `std = 0`;
/// a spec whose only axis is `seed` produces a single all-runs row.
/// Non-finite losses poison their group's mean/std to `NaN`, which is the
/// honest answer for a diverged arm. The trailing `kernel_p{50,95,99}_ns`
/// columns carry the coordinate's aggregation-rule kernel latency
/// quantiles when the sweep ran under an obs context (see
/// [`kernel_quantile_cells`]); they are empty in a plain run.
pub fn write_report(
    out_dir: &Path,
    jobs: &[Job],
    records: &BTreeMap<String, String>,
    obs: &Obs,
) -> Result<PathBuf> {
    let path = out_dir.join("report.csv");
    let axis_keys: Vec<&'static str> = jobs
        .first()
        .map(|j| j.axes.iter().map(|(k, _)| *k).filter(|&k| k != "seed").collect())
        .unwrap_or_default();
    // group key (non-seed axis values, spec order) → (losses, composed
    // aggregation-rule name — the kernel histogram key), first-seen order
    let mut order: Vec<(Vec<String>, Vec<f64>, String)> = Vec::new();
    let mut index: BTreeMap<Vec<String>, usize> = BTreeMap::new();
    for job in jobs {
        let line = records
            .get(&job.id)
            .with_context(|| format!("job {} missing from the journal", job.id))?;
        let rec = json::parse(line).map_err(|e| anyhow::anyhow!("re-parsing record: {e}"))?;
        let loss = match rec.get("final_loss") {
            Some(Json::Num(x)) => *x,
            Some(Json::Str(s)) => s.parse().unwrap_or(f64::NAN), // non-finite echo
            _ => f64::NAN,
        };
        let key: Vec<String> =
            job.axes.iter().filter(|(k, _)| *k != "seed").map(|(_, v)| v.clone()).collect();
        match index.get(&key) {
            Some(&i) => order[i].1.push(loss),
            None => {
                index.insert(key.clone(), order.len());
                // a serial pool: only the composed name is needed, and
                // the construction must not spin up worker threads
                let rule =
                    crate::aggregation::from_config_pooled(&job.cfg, &Pool::serial()).name();
                order.push((key, vec![loss], rule));
            }
        }
    }
    let mut body = String::new();
    for k in &axis_keys {
        body.push_str(k);
        body.push(',');
    }
    body.push_str("runs,final_loss_mean,final_loss_std,");
    body.push_str("kernel_p50_ns,kernel_p95_ns,kernel_p99_ns\n");
    for (key, losses, rule) in &order {
        for v in key {
            body.push_str(&crate::util::csv::escape(v));
            body.push(',');
        }
        let n = losses.len() as f64;
        let mean = losses.iter().sum::<f64>() / n;
        let std = (losses.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n).sqrt();
        let cells = kernel_quantile_cells(obs, rule);
        body.push_str(&format!("{},{mean},{std},{cells}\n", losses.len()));
    }
    write_atomic(&path, &body)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::experiments::common::Variant;
    use crate::sweep::spec::Job;

    fn job() -> Job {
        Job::from_variant(
            &Variant { label: "unit".into(), cfg: TrainConfig::default(), draco_r: None },
            1,
            2,
        )
    }

    fn trace() -> TrainTrace {
        let mut t = TrainTrace::new("unit");
        t.record(0, 3.0, 0.5, 64);
        t.record(10, 1.5, 0.25, 128);
        t.final_loss = 1.5;
        t.wall_s = 123.0; // must NOT leak into the record
        t
    }

    #[test]
    fn record_round_trips_and_excludes_wall_clock() {
        let rec = job_record(&job(), &trace());
        let line = rec.to_string();
        assert!(!line.contains("wall"), "wall-clock leaked into the record: {line}");
        let back = json::parse(&line).unwrap();
        assert_eq!(back, rec, "record must survive a parse round trip");
        // re-serialization is byte-stable — the resume bit-identity hinge
        assert_eq!(back.to_string(), line);
        assert_eq!(back.get("final_loss").unwrap().as_f64(), Some(1.5));
        assert_eq!(back.get("id").unwrap().as_str(), Some(job().id.as_str()));
    }

    #[test]
    fn non_finite_metrics_stay_parseable() {
        let mut t = trace();
        t.final_loss = f64::NAN;
        t.loss[1] = f64::INFINITY;
        let line = job_record(&job(), &t).to_string();
        let back = json::parse(&line).unwrap();
        assert_eq!(back.get("final_loss").unwrap().as_str(), Some("NaN"));
        assert_eq!(back.to_string(), line);
    }

    #[test]
    fn pivot_flags_disagreeing_iteration_grids_and_still_exports() {
        let aligned = Json::Arr(vec![Json::Num(0.0), Json::Num(10.0)]);
        let shifted = Json::Arr(vec![Json::Num(0.0), Json::Num(20.0)]);
        assert!(pivot_x_grids_disagree(&[aligned.clone(), shifted]));
        assert!(!pivot_x_grids_disagree(&[aligned.clone(), aligned]));
        assert!(!pivot_x_grids_disagree(&[]));

        // two jobs logging on different grids: the pivot warns but writes
        let v1 = Variant { label: "a".into(), cfg: TrainConfig::default(), draco_r: None };
        let mut v2 = v1.clone();
        v2.cfg.iters += 100; // distinct job id
        let (j1, j2) = (Job::from_variant(&v1, 1, 2), Job::from_variant(&v2, 1, 2));
        assert_ne!(j1.id, j2.id);
        let mut t2 = TrainTrace::new("b");
        t2.record(0, 3.0, 0.5, 64);
        t2.record(20, 1.0, 0.25, 128);
        t2.final_loss = 1.0;
        let mut records = BTreeMap::new();
        records.insert(j1.id.clone(), job_record(&j1, &trace()).to_string());
        records.insert(j2.id.clone(), job_record(&j2, &t2).to_string());
        let dir = std::env::temp_dir().join(format!("lad_pivot_grid_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_pivot_csv(&dir, &[j1, j2], &records).unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap().lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn report_groups_across_the_seed_axis() {
        // two aggregator coordinates × two seeds
        let mut jobs = Vec::new();
        let mut records = BTreeMap::new();
        let mut want_mean = Vec::new();
        for (a, agg) in ["krum", "cwtm"].iter().enumerate() {
            for (s, seed) in ["1", "2"].iter().enumerate() {
                let mut v = Variant {
                    label: format!("{agg}-s{seed}"),
                    cfg: TrainConfig::default(),
                    draco_r: None,
                };
                v.cfg.iters += a * 100 + s; // distinct job ids
                let mut j = Job::from_variant(&v, 1 + s as u64, 2 + s as u64);
                j.axes = vec![("aggregator", agg.to_string()), ("seed", seed.to_string())];
                let mut t = trace();
                t.final_loss = (a * 10 + s) as f64; // group means: 0.5, 10.5
                records.insert(j.id.clone(), job_record(&j, &t).to_string());
                jobs.push(j);
            }
            want_mean.push(a as f64 * 10.0 + 0.5);
        }
        let dir = std::env::temp_dir().join(format!("lad_report_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_report(&dir, &jobs, &records, &Obs::off()).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(
            lines[0],
            "aggregator,runs,final_loss_mean,final_loss_std,\
             kernel_p50_ns,kernel_p95_ns,kernel_p99_ns"
        );
        assert_eq!(lines.len(), 3, "{body}");
        // spec order preserved, 2 runs per coordinate, population std of
        // {x, x+1} is 0.5; obs off → empty kernel quantile cells
        assert_eq!(lines[1], format!("krum,2,{},0.5,,,", want_mean[0]));
        assert_eq!(lines[2], format!("cwtm,2,{},0.5,,,", want_mean[1]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn report_exports_kernel_quantiles_under_an_obs_context() {
        // one coordinate; the job's composed rule under the default
        // config is cwtm(0.1) — pre-populate its kernel histogram as the
        // trainer's aggregate loop would
        let j = job();
        let rule = crate::aggregation::from_config_pooled(&j.cfg, &Pool::serial()).name();
        let obs = Obs::recording(Box::new(crate::obs::NullRecorder));
        let hist =
            obs.metrics().unwrap().histogram(&format!("aggregate_kernel/{rule}"));
        for ns in [1000u64, 2000, 3000, 4000] {
            hist.observe(ns);
        }
        let mut records = BTreeMap::new();
        records.insert(j.id.clone(), job_record(&j, &trace()).to_string());
        let dir =
            std::env::temp_dir().join(format!("lad_report_obs_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_report(&dir, std::slice::from_ref(&j), &records, &obs).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        let p50 = hist.quantile(0.50);
        let p95 = hist.quantile(0.95);
        let p99 = hist.quantile(0.99);
        assert!(p50 > 0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert_eq!(lines[1], format!("1,1.5,0,{p50},{p95},{p99}"), "{body}");
        // a rule whose kernel never ran keeps empty cells (and probing
        // must not register an empty histogram in the snapshot)
        assert_eq!(kernel_quantile_cells(&obs, "never-ran"), ",,");
        assert!(obs
            .metrics()
            .unwrap()
            .histogram_get("aggregate_kernel/never-ran")
            .is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_journal_round_trips_and_tolerates_a_torn_tail() {
        let dir = std::env::temp_dir().join(format!("lad_sink_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.jsonl");
        let rec = job_record(&job(), &trace()).to_string();
        {
            let mut w = ManifestWriter::append(&path).unwrap();
            w.append_line(&rec).unwrap();
        }
        // simulate a kill mid-append: a torn, unparseable final line
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"id\": \"deadbeef\", \"final_lo").unwrap();
        }
        let map = read_manifest(&path).unwrap();
        assert_eq!(map.len(), 1, "torn tail ignored, good line kept");
        assert_eq!(map.values().next().unwrap(), &rec);
        // corruption NOT at the tail is an error
        std::fs::write(&path, format!("garbage\n{rec}\n")).unwrap();
        assert!(read_manifest(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
