//! Declarative scenario-sweep engine: TOML-driven grid expansion, a
//! resumable job queue, and a JSONL result sink.
//!
//! The paper's claims are comparative — LAD / Com-LAD against the robust
//! aggregation baselines across attacks, Byzantine counts and compression
//! budgets — and this module is the machine that runs such comparisons
//! from one declarative spec instead of a bespoke driver per figure:
//!
//! * [`spec`] — the TOML scenario spec: `[grid]` lists over the
//!   experiment axes (attack, rule, compressor, Byzantine count `f`,
//!   coding load `d`, heterogeneity, stall probability, gather deadline,
//!   seeds), `[fixed]`/`[net]` scalar overrides, Cartesian expansion in a
//!   canonical axis order, and a content-addressed id per job.
//! * [`queue`] — execution over one [`crate::util::parallel::Pool::budgeted`]
//!   two-level thread budget, journaling every completed job so `--resume`
//!   skips finished work; resumed and uninterrupted sweeps emit
//!   bit-identical results.
//! * [`sink`] — the append-only JSONL journal/results pair plus a CSV
//!   pivot for plotting. Records echo the full config and every
//!   deterministic trace field (wall-clock is excluded by design).
//! * [`scenarios`] — flagship presets: the partial-participation sweep
//!   (stall probability × gather deadline × rule through the `net`
//!   leader's retirement path), the attack-zoo robustness grid, and the
//!   `ef-vs-coding` head-to-head (cyclic gradient coding vs error-feedback
//!   compression vs momentum-filter aggregation from one rule × compressor
//!   grid — the `ef-*` compressor and `momentum-filter` rule axes).
//!
//! The figure drivers (`fig4`/`fig5`/`fig6`/`byz-sweep`) build their
//! variant lists as job batches and delegate execution to [`queue::execute`],
//! so the engine has in-tree consumers whose CSVs are pinned bit-identical
//! to the pre-engine drivers. CLI: `lad sweep --spec FILE [--resume]
//! [--out DIR] [--limit N]` or `lad sweep --preset NAME`.

pub mod queue;
pub mod scenarios;
pub mod sink;
pub mod spec;

pub use queue::{execute, execute_obs, run_job, run_sweep, run_sweep_obs, SweepOutcome};
pub use spec::{jobs_from_variants, Grid, Job, SweepSpec};
