//! Flagship in-tree scenarios, expressed as sweep specs.
//!
//! * [`partial_participation`] — the ROADMAP crash-fault sweep: stall
//!   probability × gather deadline × aggregation rule (× Byzantine
//!   count), driven through the `net::Leader` retirement path, so the
//!   numbers quantify how much participation slack each κ-robust rule
//!   actually absorbs next to the paper's Byzantine sweeps.
//! * [`attack_zoo`] — the robustness grid: attack × rule × compressor,
//!   the comparative core of the paper's §VII generalized beyond the
//!   hand-picked figure settings.
//! * [`ef_vs_coding`] — the head-to-head the literature lacks: cyclic
//!   gradient coding (LAD / Com-LAD under CWTM) against error-feedback
//!   compression (Rammal et al., arXiv 2310.09804) and momentum-filter
//!   aggregation (arXiv 2409.08640), all from one rule × compressor grid.
//!
//! All return plain [`SweepSpec`]s: run them via
//! `lad sweep --preset <name>`, or use them as templates for a custom
//! TOML spec (`examples/sweep_quickstart.toml`, `examples/ef_vs_coding.toml`).

use crate::config::{AggregatorKind, AttackKind, CompressionKind, TrainConfig};
use crate::sweep::spec::{Grid, SweepSpec};
use crate::Result;
use anyhow::bail;

/// Resolve a preset by CLI name.
pub fn preset(name: &str) -> Result<SweepSpec> {
    Ok(match name {
        "partial-participation" | "partial" => partial_participation(),
        "attack-zoo" | "attacks" => attack_zoo(),
        "ef-vs-coding" | "ef" => ef_vs_coding(),
        "elasticity" | "elastic" => elasticity(),
        other => bail!(
            "unknown preset {other:?} \
             (partial-participation | attack-zoo | ef-vs-coding | elasticity)"
        ),
    })
}

/// Shared small-but-honest base setting: large enough that the robust
/// rules have signal, small enough that a full grid runs in minutes.
fn small_base() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = 24;
    cfg.n_honest = 24;
    cfg.d = 4;
    cfg.dim = 24;
    cfg.iters = 150;
    cfg.lr = 1e-4;
    cfg.sigma_h = 0.3;
    cfg.trim_frac = 0.15;
    cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
    cfg.log_every = 25;
    cfg.seed = 2026;
    cfg
}

/// Stall probability × gather deadline × rule (× Byzantine count): every
/// job runs the in-process cluster over the real wire protocol with a
/// gather deadline, workers skipping uploads with the given probability,
/// and the leader retiring chronic stragglers (`net::MISS_RETIRE_STREAK`).
pub fn partial_participation() -> SweepSpec {
    let spec = SweepSpec::new("partial_participation", small_base());
    SweepSpec {
        grid: Grid {
            rule: vec![
                AggregatorKind::Cwtm,
                AggregatorKind::Krum,
                AggregatorKind::GeometricMedian,
            ],
            f: vec![0, 4],
            stall_prob: vec![0.0, 0.1, 0.3],
            // generous vs the microsecond in-process uploads: the miss set
            // is the seeded stall set, so runs are reproducible (deadline
            // jobs additionally run one at a time — see queue docs)
            gather_deadline_ms: vec![150],
            ..Grid::default()
        },
        ..spec
    }
}

/// Attack × rule × compressor: the robustness comparison grid. Byzantine
/// count fixed at the Fig. 4 ratio (N−H = 5 of 24).
pub fn attack_zoo() -> SweepSpec {
    let mut base = small_base();
    base.n_honest = 19;
    base.iters = 300;
    let spec = SweepSpec::new("attack_zoo", base);
    SweepSpec {
        grid: Grid {
            attack: vec![
                AttackKind::SignFlip { coeff: -2.0 },
                AttackKind::Alie,
                AttackKind::Ipm { eps: 0.5 },
                AttackKind::Zero,
                AttackKind::Gaussian { std: 10.0 },
                AttackKind::Mimic,
            ],
            rule: vec![
                AggregatorKind::Cwtm,
                AggregatorKind::Krum,
                AggregatorKind::GeometricMedian,
                AggregatorKind::Median,
            ],
            compressor: vec![CompressionKind::None, CompressionKind::RandK { k: 8 }],
            ..Grid::default()
        },
        ..spec
    }
}

/// Rule × compressor, under the Fig. 4 Byzantine ratio and sign-flip:
/// the four algorithm arms of the heterogeneity-robustness comparison in
/// one grid — `cwtm × none` is LAD, `cwtm × qsgd` is Com-LAD,
/// `cwtm × ef-qsgd` is error-feedback compression under the paper's rule,
/// and the `momentum-filter` row is Compressed Momentum Filtering.
pub fn ef_vs_coding() -> SweepSpec {
    let mut base = small_base();
    base.n_honest = 19;
    let spec = SweepSpec::new("ef_vs_coding", base);
    SweepSpec {
        grid: Grid {
            rule: vec![AggregatorKind::Cwtm, AggregatorKind::MomentumFilter],
            compressor: vec![
                CompressionKind::None,
                CompressionKind::Qsgd { levels: 16 },
                CompressionKind::EfQsgd { levels: 16 },
            ],
            ..Grid::default()
        },
        ..spec
    }
}

/// Compressor × leader-kill iteration under sign-flip: every `kill > 0`
/// job trains to the kill point, checkpoints, dies without `Shutdown`,
/// and is warm-restarted — the recorded trace must match the `kill = 0`
/// sibling bit-for-bit (same seed, same grid row). Includes the
/// error-feedback compressor, so the checkpointed EF residual mirror is
/// exercised end-to-end. Worker churn is the companion drill: add a
/// `worker_churn` axis to a TOML spec (needs `net.gather_deadline_ms`).
pub fn elasticity() -> SweepSpec {
    let mut base = small_base();
    base.n_honest = 19;
    base.iters = 80;
    base.log_every = 20;
    let spec = SweepSpec::new("elasticity", base);
    SweepSpec {
        grid: Grid {
            compressor: vec![
                CompressionKind::None,
                CompressionKind::Qsgd { levels: 16 },
                CompressionKind::EfQsgd { levels: 16 },
            ],
            leader_kill_iter: vec![0, 25],
            ..Grid::default()
        },
        ..spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_expand_cleanly() {
        let pp = partial_participation();
        let jobs = pp.expand().unwrap();
        assert_eq!(jobs.len(), 3 * 2 * 3);
        // every stalling job carries the deadline the retirement path needs
        assert!(jobs.iter().all(|j| j.cfg.net.gather_deadline_ms > 0));
        assert!(jobs.iter().any(|j| j.stall_prob > 0.0));
        let zoo = attack_zoo();
        let jobs = zoo.expand().unwrap();
        assert_eq!(jobs.len(), 6 * 4 * 2);
        assert!(jobs.iter().all(|j| j.cfg.n_honest == 19));
        let ef = ef_vs_coding();
        let jobs = ef.expand().unwrap();
        assert_eq!(jobs.len(), 2 * 3, "rule x compressor");
        assert!(jobs.iter().all(|j| j.cfg.n_honest == 19));
        // the four named arms are all present
        let arms: std::collections::BTreeSet<(String, String)> = jobs
            .iter()
            .map(|j| {
                (j.cfg.aggregator.name().to_string(), j.cfg.compression.name().to_string())
            })
            .collect();
        assert!(arms.contains(&("cwtm".into(), "none".into())), "LAD arm");
        assert!(arms.contains(&("cwtm".into(), "qsgd".into())), "Com-LAD arm");
        assert!(arms.contains(&("cwtm".into(), "ef-qsgd".into())), "EF arm");
        assert!(
            arms.iter().any(|(r, _)| r == "momentum-filter"),
            "momentum-filter arm: {arms:?}"
        );
        let el = elasticity();
        let jobs = el.expand().unwrap();
        assert_eq!(jobs.len(), 3 * 2, "compressor x kill");
        assert!(jobs.iter().any(|j| j.leader_kill_iter == 25));
        assert!(jobs.iter().any(|j| j.leader_kill_iter == 0));
        assert!(preset("partial-participation").is_ok());
        assert!(preset("attack-zoo").is_ok());
        assert!(preset("ef-vs-coding").is_ok());
        assert!(preset("elasticity").is_ok());
        assert!(preset("nope").is_err());
    }
}
