//! Declarative scenario specs: a TOML grid over the experiment axes,
//! expanded into a deterministic, content-addressed job list.
//!
//! A spec has four sections:
//!
//! * `[sweep]` — engine metadata: `name` (output naming), `stall_prob`
//!   (a fixed per-iteration stall probability applied to every job),
//!   `q_hat` / `levels` (operator parameters for the `compressor` axis).
//! * `[fixed]` — scalar `TrainConfig` overrides applied to every job
//!   (same keys as a `lad train --config` file's `[train]` table).
//! * `[net]` — transport knobs (`gather_deadline_ms`,
//!   `compression_site`, …) applied to every job; a positive gather
//!   deadline routes jobs through the `net::Leader` retirement path.
//! * `[grid]` — the axes. Every key maps to a **list** of values and the
//!   job list is the Cartesian product, expanded in the canonical axis
//!   order [`AXIS_ORDER`] with the **last axis varying fastest**
//!   (row-major), so a spec always expands to the same jobs in the same
//!   order no matter how its file is formatted.
//!
//! Every job gets a content-addressed id: an FNV-1a digest of the fully
//! resolved configuration (grid coordinates *and* fixed overrides, seeds,
//! stall probability, deadlines). Ids are what the resumable queue
//! journals, so editing any knob of a spec invalidates exactly the jobs
//! whose behaviour it changes.

use crate::config::toml::{self, TomlValue};
use crate::config::{
    apply_net_table, apply_train_table, AggregatorKind, AttackKind, CompressionKind, OracleKind,
    TrainConfig,
};
use crate::experiments::common::Variant;
use crate::net::wire::fnv1a64;
use crate::net::MISS_RETIRE_STREAK;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::collections::BTreeSet;
use std::path::Path;

/// Salt between a job's data seed (dataset generation) and its run seed
/// (assignment/attack/compression randomness) — the same relation
/// `lad train` uses, so a one-job sweep reproduces a `train` run exactly.
pub const RUN_SEED_SALT: u64 = 0x7A17;

/// Canonical axis order (expansion order; last axis varies fastest).
pub const AXIS_ORDER: [&str; 12] = [
    "attack",
    "rule",
    "nnm",
    "compressor",
    "f",
    "d",
    "sigma_h",
    "stall_prob",
    "gather_deadline_ms",
    "leader_kill_iter",
    "worker_churn",
    "seed",
];

/// Hard ceiling on a spec's expanded size — a typo'd axis should fail
/// loudly, not allocate a hundred-million-job plan.
pub const MAX_JOBS: usize = 100_000;

/// One fully resolved unit of work: a training run the queue can execute,
/// journal and resume independently.
#[derive(Debug, Clone)]
pub struct Job {
    /// Content-addressed id (16 hex chars, FNV-1a of [`Job::canonical`]).
    pub id: String,
    /// Human-readable label: the grid coordinates (`attack=alie,rule=krum`).
    pub label: String,
    pub cfg: TrainConfig,
    /// DRACO decoding instead of robust aggregation (figure delegation
    /// only; not expressible from a TOML grid).
    pub draco_r: Option<usize>,
    /// Seed for dataset generation.
    pub data_seed: u64,
    /// Seed for the training run (assignment / attack / compression).
    pub run_seed: u64,
    /// Per-iteration probability that a worker skips its upload
    /// (crash-fault emulation; requires `net.gather_deadline_ms > 0`).
    pub stall_prob: f64,
    /// Kill the leader after this iteration and warm-restart it from the
    /// checkpoint (0 = off) — the elasticity drill of
    /// `server::cluster::run_cluster_kill_resume`.
    pub leader_kill_iter: u64,
    /// Worker-churn drill (0 = off): device 0 goes silent at this
    /// iteration, is retired after `net::MISS_RETIRE_STREAK` misses, and
    /// a replacement rejoins the slot at the earliest legal iteration.
    pub worker_churn: u64,
    /// Grid coordinates, in canonical axis order (echoed to the sink).
    pub axes: Vec<(&'static str, String)>,
}

impl Job {
    /// Wrap one figure [`Variant`] as a job (the fig4/5/6/byz-sweep
    /// delegation path: same dataset/run seeding as `run_figure_par`).
    pub fn from_variant(v: &Variant, data_seed: u64, run_seed: u64) -> Job {
        let mut job = Job {
            id: String::new(),
            label: v.label.clone(),
            cfg: v.cfg.clone(),
            draco_r: v.draco_r,
            data_seed,
            run_seed,
            stall_prob: 0.0,
            leader_kill_iter: 0,
            worker_churn: 0,
            axes: Vec::new(),
        };
        job.id = job_id(&job);
        job
    }

    /// The canonical description the content-addressed id hashes: every
    /// semantic knob of the run, floats as IEEE-754 bit patterns so the
    /// encoding is exact and stable. Scheduling-only knobs (`threads`,
    /// the transport address) are excluded — they never change a trace.
    pub fn canonical(&self) -> String {
        let fb = |x: f64| format!("{:016x}", x.to_bits());
        let f32b = |x: f32| format!("{:08x}", x.to_bits());
        let cfg = &self.cfg;
        let atk = match cfg.attack {
            AttackKind::None => "none".to_string(),
            AttackKind::SignFlip { coeff } => format!("sign-flip:{}", f32b(coeff)),
            AttackKind::Gaussian { std } => format!("gaussian:{}", f32b(std)),
            AttackKind::Zero => "zero".to_string(),
            AttackKind::Alie => "alie".to_string(),
            AttackKind::Ipm { eps } => format!("ipm:{}", f32b(eps)),
            AttackKind::Mimic => "mimic".to_string(),
            AttackKind::RandomSpike { scale } => format!("spike:{}", f32b(scale)),
        };
        let comp = match cfg.compression {
            CompressionKind::None => "none".to_string(),
            CompressionKind::RandK { k } => format!("rand-k:{k}"),
            CompressionKind::TopK { k } => format!("top-k:{k}"),
            CompressionKind::Qsgd { levels } => format!("qsgd:{levels}"),
            CompressionKind::EfRandK { k } => format!("ef-rand-k:{k}"),
            CompressionKind::EfTopK { k } => format!("ef-top-k:{k}"),
            CompressionKind::EfQsgd { levels } => format!("ef-qsgd:{levels}"),
        };
        let oracle = match cfg.oracle {
            OracleKind::NativeLinreg => "native",
            OracleKind::RuntimeLinreg => "runtime",
        };
        let mut s = format!(
            "v1;n={};h={};d={};q={};t={};lr={};sh={};agg={};nnm={};trim={};atk={};comp={};\
             oracle={};log={};data_seed={};run_seed={};stall={};deadline={};dcomp={};draco={}",
            cfg.n_devices,
            cfg.n_honest,
            cfg.d,
            cfg.dim,
            cfg.iters,
            fb(cfg.lr),
            fb(cfg.sigma_h),
            cfg.aggregator.name(),
            cfg.nnm,
            fb(cfg.trim_frac),
            atk,
            comp,
            oracle,
            cfg.log_every,
            self.data_seed,
            self.run_seed,
            fb(self.stall_prob),
            cfg.net.gather_deadline_ms,
            cfg.net.device_compression,
            self.draco_r.map(|r| r.to_string()).unwrap_or_else(|| "-".to_string()),
        );
        // elasticity drills append only when active, so every pre-elastic
        // job id (and the pinned digest below) is preserved verbatim
        if self.leader_kill_iter > 0 {
            s.push_str(&format!(";kill={}", self.leader_kill_iter));
        }
        if self.worker_churn > 0 {
            s.push_str(&format!(";churn={}", self.worker_churn));
        }
        s
    }
}

/// Content-addressed job id: 16 hex chars of FNV-1a over [`Job::canonical`].
pub fn job_id(job: &Job) -> String {
    format!("{:016x}", fnv1a64(job.canonical().as_bytes()))
}

/// Wrap a figure variant list as a job batch sharing one dataset/run seed
/// pair — the delegation path behind `run_figure_par`.
pub fn jobs_from_variants(variants: &[Variant], data_seed: u64, run_seed: u64) -> Vec<Job> {
    variants.iter().map(|v| Job::from_variant(v, data_seed, run_seed)).collect()
}

/// The `[grid]` axes of a spec. An empty vector means the axis is absent
/// (the `[fixed]` / default value applies to every job); a present axis
/// must be non-empty and duplicate-free.
#[derive(Debug, Clone, Default)]
pub struct Grid {
    pub attack: Vec<AttackKind>,
    pub rule: Vec<AggregatorKind>,
    pub nnm: Vec<bool>,
    pub compressor: Vec<CompressionKind>,
    /// Byzantine counts: each value `f` sets `n_honest = n_devices − f`.
    pub f: Vec<usize>,
    pub d: Vec<usize>,
    pub sigma_h: Vec<f64>,
    pub stall_prob: Vec<f64>,
    pub gather_deadline_ms: Vec<u64>,
    /// Leader-kill/warm-restart iterations (0 = no kill for that job).
    pub leader_kill_iter: Vec<u64>,
    /// Worker-churn departure iterations (0 = no churn for that job).
    pub worker_churn: Vec<u64>,
    /// Data seeds (`run_seed = seed ^ RUN_SEED_SALT` per job).
    pub seed: Vec<u64>,
}

/// A parsed scenario-sweep spec: base config + grid axes.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    /// `[fixed]` + `[net]` applied over `TrainConfig::default()`.
    pub base: TrainConfig,
    /// Fixed per-iteration stall probability (`[sweep] stall_prob`).
    pub base_stall: f64,
    pub grid: Grid,
}

impl SweepSpec {
    /// A spec with no axes: one job from the base config.
    pub fn new(name: impl Into<String>, base: TrainConfig) -> SweepSpec {
        SweepSpec { name: name.into(), base, base_stall: 0.0, grid: Grid::default() }
    }

    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<SweepSpec> {
        let body = std::fs::read_to_string(&path)
            .with_context(|| format!("reading sweep spec {:?}", path.as_ref()))?;
        Self::from_toml_str(&body)
    }

    /// Parse a spec from TOML text. Unknown tables, unknown keys, scalar
    /// grid values and empty axes are all hard errors — a typo must never
    /// silently shrink a sweep.
    pub fn from_toml_str(body: &str) -> Result<SweepSpec> {
        let doc = toml::parse(body).map_err(|e| anyhow::anyhow!("sweep spec parse error: {e}"))?;
        for table in doc.keys() {
            match table.as_str() {
                "" | "sweep" | "fixed" | "grid" | "net" => {}
                other => bail!("unknown sweep table [{other}] (expected sweep/fixed/grid/net)"),
            }
        }
        if let Some(kv) = doc.get("") {
            if let Some(key) = kv.keys().next() {
                bail!(
                    "top-level key {key:?} — sweep specs keep keys under [sweep]/[fixed]/[grid]"
                );
            }
        }
        let mut name = "sweep".to_string();
        let mut base_stall = 0.0f64;
        let mut q_hat = 30usize;
        let mut levels = 16u32;
        if let Some(kv) = doc.get("sweep") {
            for (key, v) in kv {
                match key.as_str() {
                    "name" => name = v.as_str().context("sweep.name must be a string")?.to_string(),
                    "stall_prob" => {
                        base_stall = v.as_f64().context("sweep.stall_prob must be a number")?
                    }
                    "q_hat" => {
                        q_hat = v.as_usize().context("sweep.q_hat must be a positive integer")?
                    }
                    "levels" => {
                        levels = v.as_usize().context("sweep.levels must be a positive integer")?
                            as u32
                    }
                    other => bail!("unknown [sweep] key {other:?}"),
                }
            }
        }
        let mut base = TrainConfig::default();
        if let Some(kv) = doc.get("fixed") {
            apply_train_table(&mut base, kv)?;
        }
        if let Some(kv) = doc.get("net") {
            apply_net_table(&mut base.net, kv)?;
        }
        let mut grid = Grid::default();
        if let Some(kv) = doc.get("grid") {
            for (key, v) in kv {
                let arr = match v {
                    TomlValue::Arr(items) => items,
                    _ => bail!("[grid] {key} must be a list (scalars belong in [fixed])"),
                };
                ensure!(!arr.is_empty(), "[grid] {key} is an empty list");
                match key.as_str() {
                    "attack" => {
                        grid.attack = arr
                            .iter()
                            .map(|x| AttackKind::parse(need_str(key, x)?))
                            .collect::<Result<_>>()?
                    }
                    "rule" | "aggregator" => {
                        grid.rule = arr
                            .iter()
                            .map(|x| AggregatorKind::parse(need_str(key, x)?))
                            .collect::<Result<_>>()?
                    }
                    "nnm" => {
                        grid.nnm = arr
                            .iter()
                            .map(|x| {
                                x.as_bool()
                                    .with_context(|| format!("[grid] {key} values must be bool"))
                            })
                            .collect::<Result<_>>()?
                    }
                    "compressor" | "compression" => {
                        grid.compressor = arr
                            .iter()
                            .map(|x| parse_compressor(need_str(key, x)?, q_hat, levels))
                            .collect::<Result<_>>()?
                    }
                    "f" | "byz" => grid.f = need_usizes(key, arr)?,
                    "d" | "load" => grid.d = need_usizes(key, arr)?,
                    "sigma_h" => grid.sigma_h = need_f64s(key, arr)?,
                    "stall_prob" => {
                        grid.stall_prob = need_f64s(key, arr)?;
                        for &p in &grid.stall_prob {
                            ensure!(
                                (0.0..=1.0).contains(&p),
                                "[grid] stall_prob value {p} outside [0, 1]"
                            );
                        }
                    }
                    "gather_deadline_ms" => {
                        grid.gather_deadline_ms =
                            need_usizes(key, arr)?.into_iter().map(|x| x as u64).collect()
                    }
                    "leader_kill_iter" => {
                        grid.leader_kill_iter =
                            need_usizes(key, arr)?.into_iter().map(|x| x as u64).collect()
                    }
                    "worker_churn" => {
                        grid.worker_churn =
                            need_usizes(key, arr)?.into_iter().map(|x| x as u64).collect()
                    }
                    "seed" => {
                        grid.seed = need_usizes(key, arr)?.into_iter().map(|x| x as u64).collect()
                    }
                    other => bail!(
                        "unknown [grid] axis {other:?} (expected one of {})",
                        AXIS_ORDER.join("/")
                    ),
                }
            }
        }
        let spec = SweepSpec { name, base, base_stall, grid };
        ensure!(
            (0.0..=1.0).contains(&spec.base_stall),
            "sweep.stall_prob {} outside [0, 1]",
            spec.base_stall
        );
        Ok(spec)
    }

    /// Expand the grid into the full job list: Cartesian product in
    /// canonical axis order ([`AXIS_ORDER`], last axis fastest), each job
    /// validated and content-addressed. Errors on duplicate axis values
    /// (they would collapse to one job id) and on any job that fails
    /// `TrainConfig::validate`.
    pub fn expand(&self) -> Result<Vec<Job>> {
        // non-config knobs an axis can set (everything else goes on cfg)
        struct Knobs {
            stall: f64,
            kill: u64,
            churn: u64,
        }
        // one (key, #values, apply) entry per *present* axis, canonical order
        type Apply<'a> = Box<dyn Fn(usize, &mut TrainConfig, &mut Knobs) -> String + 'a>;
        let mut axes: Vec<(&'static str, usize, Apply<'_>)> = Vec::new();
        let g = &self.grid;
        if !g.attack.is_empty() {
            axes.push((
                "attack",
                g.attack.len(),
                Box::new(|i, cfg: &mut TrainConfig, _: &mut Knobs| {
                    cfg.attack = g.attack[i];
                    g.attack[i].name().to_string()
                }),
            ));
        }
        if !g.rule.is_empty() {
            axes.push((
                "rule",
                g.rule.len(),
                Box::new(|i, cfg, _| {
                    cfg.aggregator = g.rule[i];
                    g.rule[i].name().to_string()
                }),
            ));
        }
        if !g.nnm.is_empty() {
            axes.push((
                "nnm",
                g.nnm.len(),
                Box::new(|i, cfg, _| {
                    cfg.nnm = g.nnm[i];
                    g.nnm[i].to_string()
                }),
            ));
        }
        if !g.compressor.is_empty() {
            axes.push((
                "compressor",
                g.compressor.len(),
                Box::new(|i, cfg, _| {
                    cfg.compression = g.compressor[i];
                    g.compressor[i].name().to_string()
                }),
            ));
        }
        if !g.f.is_empty() {
            axes.push((
                "f",
                g.f.len(),
                Box::new(|i, cfg, _| {
                    cfg.n_honest = cfg.n_devices.saturating_sub(g.f[i]);
                    g.f[i].to_string()
                }),
            ));
        }
        if !g.d.is_empty() {
            axes.push((
                "d",
                g.d.len(),
                Box::new(|i, cfg, _| {
                    cfg.d = g.d[i];
                    g.d[i].to_string()
                }),
            ));
        }
        if !g.sigma_h.is_empty() {
            axes.push((
                "sigma_h",
                g.sigma_h.len(),
                Box::new(|i, cfg, _| {
                    cfg.sigma_h = g.sigma_h[i];
                    g.sigma_h[i].to_string()
                }),
            ));
        }
        if !g.stall_prob.is_empty() {
            axes.push((
                "stall_prob",
                g.stall_prob.len(),
                Box::new(|i, _, k: &mut Knobs| {
                    k.stall = g.stall_prob[i];
                    g.stall_prob[i].to_string()
                }),
            ));
        }
        if !g.gather_deadline_ms.is_empty() {
            axes.push((
                "gather_deadline_ms",
                g.gather_deadline_ms.len(),
                Box::new(|i, cfg, _| {
                    cfg.net.gather_deadline_ms = g.gather_deadline_ms[i];
                    g.gather_deadline_ms[i].to_string()
                }),
            ));
        }
        if !g.leader_kill_iter.is_empty() {
            axes.push((
                "leader_kill_iter",
                g.leader_kill_iter.len(),
                Box::new(|i, _, k: &mut Knobs| {
                    k.kill = g.leader_kill_iter[i];
                    g.leader_kill_iter[i].to_string()
                }),
            ));
        }
        if !g.worker_churn.is_empty() {
            axes.push((
                "worker_churn",
                g.worker_churn.len(),
                Box::new(|i, _, k: &mut Knobs| {
                    k.churn = g.worker_churn[i];
                    g.worker_churn[i].to_string()
                }),
            ));
        }
        if !g.seed.is_empty() {
            axes.push((
                "seed",
                g.seed.len(),
                Box::new(|i, cfg, _| {
                    cfg.seed = g.seed[i];
                    g.seed[i].to_string()
                }),
            ));
        }

        let total: usize = axes.iter().map(|(_, len, _)| *len).product();
        ensure!(total <= MAX_JOBS, "sweep expands to {total} jobs (cap {MAX_JOBS})");
        let mut jobs = Vec::with_capacity(total);
        let mut seen = BTreeSet::new();
        let mut idx = vec![0usize; axes.len()];
        loop {
            let mut cfg = self.base.clone();
            let mut knobs = Knobs { stall: self.base_stall, kill: 0, churn: 0 };
            let mut echo: Vec<(&'static str, String)> = Vec::with_capacity(axes.len());
            for (a, (key, _, apply)) in axes.iter().enumerate() {
                echo.push((*key, apply(idx[a], &mut cfg, &mut knobs)));
            }
            let label = if echo.is_empty() {
                self.name.clone()
            } else {
                echo.iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(",")
            };
            cfg.validate().with_context(|| format!("sweep job {label}"))?;
            ensure!(
                knobs.stall == 0.0 || cfg.net.gather_deadline_ms > 0,
                "job {label}: stall_prob > 0 needs gather_deadline_ms > 0 \
                 (a leader without a deadline would wait on the stalled worker forever)"
            );
            ensure!(
                knobs.kill == 0 || knobs.kill + 1 < cfg.iters as u64,
                "job {label}: leader_kill_iter {} leaves no iterations to resume ({} total)",
                knobs.kill,
                cfg.iters
            );
            ensure!(
                !(knobs.kill > 0 && knobs.stall > 0.0),
                "job {label}: leader_kill_iter is incompatible with stall_prob \
                 (restarted workers would redraw their stall streams)"
            );
            ensure!(
                !(knobs.kill > 0 && knobs.churn > 0),
                "job {label}: leader_kill_iter and worker_churn are separate drills"
            );
            ensure!(
                knobs.churn == 0 || cfg.net.gather_deadline_ms > 0,
                "job {label}: worker_churn needs gather_deadline_ms > 0 \
                 (the silent victim would hang the leader)"
            );
            ensure!(
                knobs.churn == 0
                    || knobs.churn + MISS_RETIRE_STREAK as u64 + 1 < cfg.iters as u64,
                "job {label}: worker_churn {} leaves no room for retirement + rejoin \
                 ({} iterations)",
                knobs.churn,
                cfg.iters
            );
            ensure!(
                (knobs.stall == 0.0
                    && cfg.net.gather_deadline_ms == 0
                    && knobs.kill == 0
                    && knobs.churn == 0)
                    || cfg.oracle == OracleKind::NativeLinreg,
                "job {label}: partial-participation jobs need the native oracle"
            );
            let mut job = Job {
                id: String::new(),
                label,
                data_seed: cfg.seed,
                run_seed: cfg.seed ^ RUN_SEED_SALT,
                cfg,
                draco_r: None,
                stall_prob: knobs.stall,
                leader_kill_iter: knobs.kill,
                worker_churn: knobs.churn,
                axes: echo,
            };
            job.id = job_id(&job);
            ensure!(
                seen.insert(job.id.clone()),
                "duplicate job {} ({}) — an axis repeats a value or two axes collide",
                job.id,
                job.label
            );
            jobs.push(job);
            // odometer: last axis fastest
            let mut a = axes.len();
            loop {
                if a == 0 {
                    return Ok(jobs);
                }
                a -= 1;
                idx[a] += 1;
                if idx[a] < axes[a].1 {
                    break;
                }
                idx[a] = 0;
            }
        }
    }
}

fn parse_compressor(s: &str, q_hat: usize, levels: u32) -> Result<CompressionKind> {
    Ok(match s {
        "none" | "identity" => CompressionKind::None,
        "rand-k" | "randk" => CompressionKind::RandK { k: q_hat },
        "top-k" | "topk" => CompressionKind::TopK { k: q_hat },
        "qsgd" => CompressionKind::Qsgd { levels },
        "ef-rand-k" | "ef-randk" => CompressionKind::EfRandK { k: q_hat },
        "ef-top-k" | "ef-topk" => CompressionKind::EfTopK { k: q_hat },
        "ef-qsgd" => CompressionKind::EfQsgd { levels },
        other => bail!(
            "unknown compressor {other:?} (none|rand-k|top-k|qsgd|ef-rand-k|ef-top-k|ef-qsgd)"
        ),
    })
}

fn need_str<'a>(key: &str, v: &'a TomlValue) -> Result<&'a str> {
    v.as_str().with_context(|| format!("[grid] {key} values must be strings"))
}

fn need_usizes(key: &str, arr: &[TomlValue]) -> Result<Vec<usize>> {
    arr.iter()
        .map(|x| {
            x.as_usize()
                .with_context(|| format!("[grid] {key} values must be non-negative integers"))
        })
        .collect()
}

fn need_f64s(key: &str, arr: &[TomlValue]) -> Result<Vec<f64>> {
    arr.iter()
        .map(|x| x.as_f64().with_context(|| format!("[grid] {key} values must be numbers")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"
        [sweep]
        name = "unit"
        q_hat = 4

        [fixed]
        devices = 12
        honest = 9
        dim = 8
        d = 2
        iters = 20
        lr = 1e-4
        log_every = 0

        [grid]
        attack = ["sign-flip", "alie"]
        rule = ["cwtm", "krum"]
        compressor = ["none", "rand-k"]
    "#;

    #[test]
    fn expansion_is_row_major_in_canonical_axis_order() {
        let spec = SweepSpec::from_toml_str(TINY).unwrap();
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 8);
        let labels: Vec<&str> = jobs.iter().map(|j| j.label.as_str()).collect();
        // attack slowest, compressor fastest — regardless of file order
        assert_eq!(
            labels,
            vec![
                "attack=sign-flip,rule=cwtm,compressor=none",
                "attack=sign-flip,rule=cwtm,compressor=rand-k",
                "attack=sign-flip,rule=krum,compressor=none",
                "attack=sign-flip,rule=krum,compressor=rand-k",
                "attack=alie,rule=cwtm,compressor=none",
                "attack=alie,rule=cwtm,compressor=rand-k",
                "attack=alie,rule=krum,compressor=none",
                "attack=alie,rule=krum,compressor=rand-k",
            ]
        );
        // q_hat flowed into the compressor axis
        let rk = jobs.iter().find(|j| j.label.ends_with("rand-k")).unwrap();
        assert_eq!(rk.cfg.compression, CompressionKind::RandK { k: 4 });
    }

    #[test]
    fn job_ids_are_stable_and_distinct() {
        let a = SweepSpec::from_toml_str(TINY).unwrap().expand().unwrap();
        let b = SweepSpec::from_toml_str(TINY).unwrap().expand().unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id, "re-parsing a spec must reproduce every id");
            assert_eq!(x.id.len(), 16);
        }
        let ids: BTreeSet<&str> = a.iter().map(|j| j.id.as_str()).collect();
        assert_eq!(ids.len(), a.len(), "distinct jobs must get distinct ids");
        // reordering the [grid] keys in the file changes nothing
        let permuted = TINY.replace(
            "attack = [\"sign-flip\", \"alie\"]\n        rule = [\"cwtm\", \"krum\"]",
            "rule = [\"cwtm\", \"krum\"]\n        attack = [\"sign-flip\", \"alie\"]",
        );
        assert_ne!(permuted, TINY);
        let c = SweepSpec::from_toml_str(&permuted).unwrap().expand().unwrap();
        for (x, y) in a.iter().zip(&c) {
            assert_eq!(x.id, y.id, "axis order is canonical, not file order");
        }
        // and any semantic change moves every affected id
        let edited = TINY.replace("iters = 20", "iters = 21");
        let d = SweepSpec::from_toml_str(&edited).unwrap().expand().unwrap();
        for (x, y) in a.iter().zip(&d) {
            assert_ne!(x.id, y.id, "an iters change must re-address the jobs");
        }
    }

    #[test]
    fn job_id_pins_the_canonical_encoding() {
        // the default TrainConfig as a single job — the id is pinned so an
        // accidental change to the canonical serialization fails loudly
        let job = Job::from_variant(
            &Variant { label: "pin".into(), cfg: TrainConfig::default(), draco_r: None },
            7,
            11,
        );
        assert_eq!(
            job.canonical(),
            "v1;n=100;h=80;d=10;q=100;t=500;lr=3eb0c6f7a0b5ed8d;sh=3fd3333333333333;\
             agg=cwtm;nnm=false;trim=3fb999999999999a;atk=sign-flip:c0000000;comp=none;\
             oracle=native;log=50;data_seed=7;run_seed=11;stall=0000000000000000;\
             deadline=0;dcomp=false;draco=-"
        );
        // independently computed FNV-1a of the canonical string above
        assert_eq!(job.id, "6d71af87f6a38e78");
    }

    #[test]
    fn bad_specs_are_rejected() {
        // unknown table / key / axis
        assert!(SweepSpec::from_toml_str("[bogus]\nx = 1").is_err());
        assert!(SweepSpec::from_toml_str("[sweep]\nbogus = 1").is_err());
        assert!(SweepSpec::from_toml_str("[grid]\nwarp = [1]").is_err());
        // top-level keys are ambiguous — rejected
        assert!(SweepSpec::from_toml_str("name = \"x\"").is_err());
        // scalar where a list is required
        assert!(SweepSpec::from_toml_str("[grid]\nd = 3").is_err());
        // empty axis
        assert!(SweepSpec::from_toml_str("[grid]\nd = []").is_err());
        // bad enum values
        assert!(SweepSpec::from_toml_str("[grid]\nattack = [\"meteor\"]").is_err());
        assert!(SweepSpec::from_toml_str("[grid]\ncompressor = [\"gzip\"]").is_err());
        // stall probability out of range
        assert!(SweepSpec::from_toml_str("[grid]\nstall_prob = [1.5]").is_err());
        // duplicate axis values collapse job ids — rejected at expansion
        let dup = SweepSpec::from_toml_str("[grid]\nd = [5, 5]").unwrap();
        assert!(dup.expand().is_err());
        // honest-majority violation surfaces with the job label attached
        let spec = SweepSpec::from_toml_str(
            "[fixed]\ndevices = 10\nhonest = 8\n[grid]\nf = [1, 6]",
        )
        .unwrap();
        let err = spec.expand().unwrap_err().to_string();
        assert!(err.contains("f=6"), "error names the offending job: {err}");
        // stalling without a gather deadline would hang the leader
        let spec =
            SweepSpec::from_toml_str("[sweep]\nstall_prob = 0.2\n[grid]\nd = [1, 2]").unwrap();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn elasticity_axes_expand_and_re_address() {
        let spec = SweepSpec::from_toml_str(
            "[fixed]\niters = 40\nlog_every = 0\n[net]\ngather_deadline_ms = 200\n\
             [grid]\nleader_kill_iter = [0, 10]\nworker_churn = [0, 5]",
        )
        .unwrap();
        let err = spec.expand().unwrap_err().to_string();
        // kill=10 × churn=5 is the forbidden combination — named in the error
        assert!(err.contains("separate drills"), "{err}");
        let spec = SweepSpec::from_toml_str(
            "[fixed]\niters = 40\nlog_every = 0\n[net]\ngather_deadline_ms = 200\n\
             [grid]\nleader_kill_iter = [0, 10]",
        )
        .unwrap();
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 2);
        // the kill=0 arm keeps the pre-elastic canonical form (no suffix),
        // so only active drills re-address a job
        assert!(!jobs[0].canonical().contains(";kill="));
        assert!(jobs[1].canonical().ends_with(";kill=10"));
        assert_ne!(jobs[0].id, jobs[1].id);
        // churn without a gather deadline would hang the leader — rejected
        let spec = SweepSpec::from_toml_str("[grid]\nworker_churn = [5]").unwrap();
        assert!(spec.expand().is_err());
        // a kill at the end of the run leaves nothing to resume — rejected
        let spec = SweepSpec::from_toml_str(
            "[fixed]\niters = 10\n[grid]\nleader_kill_iter = [9]",
        )
        .unwrap();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn net_table_routes_every_job_through_the_deadline_path() {
        let spec = SweepSpec::from_toml_str(
            "[net]\ngather_deadline_ms = 150\n[grid]\nstall_prob = [0.0, 0.3]",
        )
        .unwrap();
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 2);
        assert!(jobs.iter().all(|j| j.cfg.net.gather_deadline_ms == 150));
        assert_eq!(jobs[0].stall_prob, 0.0);
        assert_eq!(jobs[1].stall_prob, 0.3);
    }
}
