//! The resumable job queue: one shared two-level thread budget, a
//! crash-safe journal, and deterministic results.
//!
//! Execution is two-level over one [`Pool::budgeted`] worker set: the
//! outer level fans jobs out, each job's inner stages (oracle,
//! compression, aggregation) run on a borrowed slice capped by the job's
//! own `threads` — total live parallelism is bounded by the budget no
//! matter how many jobs run concurrently, and thread counts never change
//! a trace (the `util::parallel` determinism contract). The exception is
//! wall-clock-sensitive jobs (gather deadline / stall injection, whose
//! cluster runs also spawn one OS thread per device outside the pool):
//! they execute **serially after** the concurrent leg, one cluster at a
//! time, so deadline misses reflect the seeded stall set rather than
//! fan-out load — reruns and resumes stay reproducible.
//!
//! [`run_sweep`] journals every completed job to `manifest.jsonl`
//! (append + flush per job), so a killed sweep resumes with `--resume`
//! by skipping journaled ids; once all jobs are journaled it rewrites
//! them in spec order as `results.jsonl` + a `results.csv` pivot. Journal
//! lines are copied verbatim into the results, so an interrupted-and-
//! resumed sweep emits output bit-identical to an uninterrupted one.
//!
//! [`execute`] is the same engine without the journal — the in-memory
//! path the figure drivers (fig4/5/6, byz-sweep) delegate to.

use crate::config::OracleKind;
use crate::data::linreg::LinRegDataset;
use crate::experiments::common::{run_variant_obs, Variant};
use crate::net::{LeaderOpts, MISS_RETIRE_STREAK};
use crate::obs::{Event, Obs};
use crate::server::cluster::{
    run_cluster_churn, run_cluster_kill_resume, run_cluster_with, ChurnPlan, ClusterOpts,
};
use crate::server::TrainTrace;
use crate::sweep::sink;
use crate::sweep::spec::{Job, SweepSpec};
use crate::util::parallel::{Parallelism, Pool};
use crate::util::rng::Rng;
use crate::{aggregation, attack, compress, Result};
use anyhow::{ensure, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

/// Salt between a job's run seed and the stall stream fed to the
/// crash-fault workers, so stalling never replays training randomness.
pub const STALL_SEED_SALT: u64 = 0x57A11;

/// Cache key of a generated dataset: everything `LinRegDataset::generate`
/// consumes. Jobs agreeing on all four share one dataset within a batch —
/// the figure-driver shape (one dataset, many variants) pays one
/// generation, exactly like the pre-engine shared borrow.
type DsKey = (u64, usize, usize, u64);

fn ds_key(job: &Job) -> DsKey {
    (job.data_seed, job.cfg.n_devices, job.cfg.dim, job.cfg.sigma_h.to_bits())
}

/// The one place a job's dataset is generated — [`ds_key`] names exactly
/// these inputs, so the cache and the standalone path cannot drift.
fn generate_dataset(job: &Job) -> LinRegDataset {
    let mut rng = Rng::new(job.data_seed);
    LinRegDataset::generate(job.cfg.n_devices, job.cfg.dim, job.cfg.sigma_h, &mut rng)
}

/// Per-batch dataset cache. Generation happens under the one lock, so two
/// concurrent jobs with the same key never generate twice; distinct keys
/// convoy on their *first* touch, which is fine — generation is trivial
/// next to the training run that follows.
type DsCache = Mutex<BTreeMap<DsKey, std::sync::Arc<LinRegDataset>>>;

fn dataset_for(job: &Job, cache: &DsCache) -> std::sync::Arc<LinRegDataset> {
    let mut map = cache.lock().unwrap();
    std::sync::Arc::clone(
        map.entry(ds_key(job)).or_insert_with(|| std::sync::Arc::new(generate_dataset(job))),
    )
}

/// Run one job to its trace. Deterministic: the dataset comes from
/// `Rng::new(data_seed)`, the run from `Rng::new(run_seed)`, and the pool
/// only schedules. Jobs with a stall probability or a gather deadline run
/// through the `net::Leader` retirement path (in-process cluster over the
/// real wire protocol); everything else takes the central fast path.
pub fn run_job(job: &Job, pool: &Pool) -> Result<TrainTrace> {
    run_job_on(job, &generate_dataset(job), pool, &Obs::off())
}

/// [`run_job`] against an already-generated dataset (must match
/// [`ds_key`] — the batch scheduler shares one dataset across agreeing
/// jobs via the cache). The obs context reaches the trainer / cluster
/// leader, so job phase spans and per-rule `aggregate_kernel/*`
/// histograms accumulate in the sweep's shared registry (telemetry
/// only — traces are bit-identical with obs on or off).
fn run_job_on(job: &Job, ds: &LinRegDataset, pool: &Pool, obs: &Obs) -> Result<TrainTrace> {
    let cfg = &job.cfg;
    let faulty = job.stall_prob > 0.0 || cfg.net.gather_deadline_ms > 0;
    let elastic = job.leader_kill_iter > 0 || job.worker_churn > 0;
    if !faulty && !elastic {
        let v = Variant { label: job.label.clone(), cfg: cfg.clone(), draco_r: job.draco_r };
        return run_variant_obs(ds, &v, job.run_seed, pool, obs);
    }
    ensure!(
        job.stall_prob == 0.0 || cfg.net.gather_deadline_ms > 0,
        "job {}: stall_prob > 0 needs gather_deadline_ms > 0",
        job.label
    );
    ensure!(job.draco_r.is_none(), "job {}: DRACO has no partial-participation path", job.label);
    ensure!(
        cfg.oracle == OracleKind::NativeLinreg,
        "job {}: partial-participation jobs need the native oracle",
        job.label
    );
    let agg = aggregation::from_config_pooled(cfg, pool);
    let atk = attack::from_kind(cfg.attack);
    let comp = compress::from_kind(cfg.compression);
    let opts = ClusterOpts {
        leader: LeaderOpts {
            gather_deadline: (cfg.net.gather_deadline_ms > 0)
                .then(|| Duration::from_millis(cfg.net.gather_deadline_ms)),
            device_compression: cfg.net.device_compression,
            obs: obs.clone(),
            ..Default::default()
        },
        stall_prob: job.stall_prob,
        stall_seed: job.run_seed ^ STALL_SEED_SALT,
    };
    let mut x0 = vec![0.0f32; cfg.dim];
    let mut rng = Rng::new(job.run_seed);
    if job.leader_kill_iter > 0 {
        // the leader-kill/warm-restart drill: checkpoint at the kill
        // iteration, then a fresh leader finishes the run from it — the
        // trace the sink records is the resumed (bit-identical) one
        let ckpt = std::env::temp_dir()
            .join(format!("lad-kill-{}-{}.ckpt", std::process::id(), job.id));
        let tr = run_cluster_kill_resume(
            cfg,
            ds,
            agg.as_ref(),
            atk.as_ref(),
            comp.as_ref(),
            &mut x0,
            &job.label,
            &mut rng,
            pool,
            &opts,
            job.leader_kill_iter,
            &ckpt,
        );
        let _ = std::fs::remove_file(&ckpt);
        return tr;
    }
    if job.worker_churn > 0 {
        // worker-churn drill: device 0 departs, is retired after the miss
        // streak, and a replacement adopts the slot as soon as allowed
        let plan = ChurnPlan {
            victim: 0,
            depart_iter: job.worker_churn,
            rejoin_iter: job.worker_churn + MISS_RETIRE_STREAK as u64,
        };
        return run_cluster_churn(
            cfg,
            ds,
            agg.as_ref(),
            atk.as_ref(),
            comp.as_ref(),
            &mut x0,
            &job.label,
            &mut rng,
            pool,
            &opts,
            plan,
        );
    }
    run_cluster_with(
        cfg,
        ds,
        agg.as_ref(),
        atk.as_ref(),
        comp.as_ref(),
        &mut x0,
        &job.label,
        &mut rng,
        pool,
        &opts,
    )
}

/// True when a job's outcome depends on wall-clock deadlines (gather
/// deadline / stall injection): such jobs run one at a time with the full
/// thread budget, never concurrently with sibling jobs, so an honest
/// worker's upload cannot miss the deadline just because the machine was
/// oversubscribed by the fan-out — reruns and resumes stay reproducible.
fn is_wall_clock_sensitive(job: &Job) -> bool {
    job.stall_prob > 0.0
        || job.cfg.net.gather_deadline_ms > 0
        || job.leader_kill_iter > 0
        || job.worker_churn > 0
}

/// The one scheduler behind both [`execute`] and [`run_sweep`]: run every
/// job under a shared two-level budget — the deterministic-math jobs
/// concurrently, the wall-clock-sensitive ones serially afterwards — and
/// invoke `on_done` the moment each job completes (the journaling hook;
/// called from worker threads, hence `Sync`). Returns traces in job order.
fn execute_with(
    jobs: &[&Job],
    par: Parallelism,
    obs: &Obs,
    on_done: &(dyn Fn(&Job, &TrainTrace) -> Result<()> + Sync),
) -> Result<Vec<TrainTrace>> {
    let fast: Vec<usize> =
        (0..jobs.len()).filter(|&i| !is_wall_clock_sensitive(jobs[i])).collect();
    let budget = Pool::budgeted(par.threads(), fast.len().max(1));
    let cache: DsCache = Mutex::new(BTreeMap::new());
    let mut out: Vec<Option<TrainTrace>> = (0..jobs.len()).map(|_| None).collect();
    // journal each finished job with its wall time and keep a live
    // queue-depth gauge; telemetry only — scheduling is unchanged
    let remaining = std::sync::atomic::AtomicU64::new(jobs.len() as u64);
    obs.gauge("sweep_queue_depth", jobs.len() as f64);
    let finish = |job: &Job, ns: u64| {
        if obs.enabled() {
            obs.emit(Event::SweepJobDone { id: job.id.clone(), ns });
            let left = remaining.fetch_sub(1, std::sync::atomic::Ordering::Relaxed) - 1;
            obs.gauge("sweep_queue_depth", left as f64);
        }
    };
    let done = budget.outer().par_map(&fast, |_, &i| -> Result<(usize, TrainTrace)> {
        let ds = dataset_for(jobs[i], &cache);
        let sp = obs.span("sweep_job");
        let tr = run_job_on(jobs[i], &ds, &budget.inner_capped(jobs[i].cfg.threads), obs)?;
        finish(jobs[i], sp.done());
        eprintln!("  {}", tr.summary());
        on_done(jobs[i], &tr)?;
        Ok((i, tr))
    });
    for r in done {
        let (i, tr) = r?;
        out[i] = Some(tr);
    }
    for i in (0..jobs.len()).filter(|&i| is_wall_clock_sensitive(jobs[i])) {
        let ds = dataset_for(jobs[i], &cache);
        let sp = obs.span("sweep_job");
        let tr = run_job_on(jobs[i], &ds, &budget.outer().borrow(jobs[i].cfg.threads), obs)?;
        finish(jobs[i], sp.done());
        eprintln!("  {}", tr.summary());
        on_done(jobs[i], &tr)?;
        out[i] = Some(tr);
    }
    Ok(out.into_iter().map(|t| t.expect("every job ran")).collect())
}

/// Run a job batch in memory (no journal) under one two-level budget;
/// returns the traces in job order. This is the engine the figure
/// drivers delegate to — traces are bit-identical to running each job
/// serially with a private pool. Deadline-driven jobs
/// ([`is_wall_clock_sensitive`]) are executed serially after the
/// concurrent leg.
pub fn execute(jobs: &[Job], par: Parallelism) -> Result<Vec<TrainTrace>> {
    execute_obs(jobs, par, &Obs::off())
}

/// [`execute`] with an observability sink: each finished job is
/// journaled as a `sweep_job_done` event with its wall time, and a
/// `sweep_queue_depth` gauge tracks the jobs still outstanding.
pub fn execute_obs(jobs: &[Job], par: Parallelism, obs: &Obs) -> Result<Vec<TrainTrace>> {
    let refs: Vec<&Job> = jobs.iter().collect();
    execute_with(&refs, par, obs, &|_, _| Ok(()))
}

/// What a [`run_sweep`] call did.
#[derive(Debug)]
pub struct SweepOutcome {
    /// Jobs in the expanded spec.
    pub total: usize,
    /// Jobs executed by this call.
    pub ran: usize,
    /// Jobs skipped because the journal already had them (`--resume`).
    pub skipped: usize,
    /// Jobs still missing after this call (a `--limit` run).
    pub pending: usize,
    pub manifest_path: PathBuf,
    /// Written only once every job is journaled.
    pub results_path: Option<PathBuf>,
    pub csv_path: Option<PathBuf>,
    /// Cross-seed summary (`report.csv`), written with the results.
    pub report_path: Option<PathBuf>,
}

/// Expand and run a spec against an output directory.
///
/// * `resume = false` starts fresh (an existing journal is discarded);
///   `resume = true` keeps it and skips every journaled job of this spec
///   (journaled ids from an edited spec no longer in the grid are
///   dropped, so a stale journal cannot leak foreign records).
/// * `limit` caps how many pending jobs this call executes — the hook CI
///   uses to exercise the kill-and-resume path deterministically.
pub fn run_sweep(
    spec: &SweepSpec,
    out_dir: &Path,
    resume: bool,
    limit: Option<usize>,
    par: Parallelism,
) -> Result<SweepOutcome> {
    run_sweep_obs(spec, out_dir, resume, limit, par, &Obs::off())
}

/// [`run_sweep`] with an observability sink (see [`execute_obs`]).
pub fn run_sweep_obs(
    spec: &SweepSpec,
    out_dir: &Path,
    resume: bool,
    limit: Option<usize>,
    par: Parallelism,
    obs: &Obs,
) -> Result<SweepOutcome> {
    let jobs = spec.expand()?;
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating sweep output dir {out_dir:?}"))?;
    let manifest_path = out_dir.join("manifest.jsonl");
    // results files are only ever valid for a *completed* run of *this*
    // spec — remove them up front (they are rewritten below once every
    // job is journaled) so a partial or edited-spec rerun can never leave
    // a previous sweep's output masquerading as current
    for stale in ["results.jsonl", "results.csv", "report.csv"] {
        let p = out_dir.join(stale);
        if p.exists() {
            std::fs::remove_file(&p).with_context(|| format!("clearing stale {p:?}"))?;
        }
    }
    let mut done: BTreeMap<String, String> = BTreeMap::new();
    if resume {
        done = sink::read_manifest(&manifest_path)?;
        let ids: std::collections::BTreeSet<&str> = jobs.iter().map(|j| j.id.as_str()).collect();
        let before = done.len();
        done.retain(|id, _| ids.contains(id.as_str()));
        if done.len() < before {
            eprintln!(
                "sweep: dropped {} journaled job(s) not in this spec (spec edited?)",
                before - done.len()
            );
        }
        // Compact the journal before appending: rewrite it (atomically)
        // with exactly the retained lines. This clears a torn final line
        // left by a kill mid-append — otherwise the next append would
        // glue onto it and corrupt the journal mid-file — and drops
        // edited-spec leftovers from disk, not just from memory.
        let tmp = out_dir.join("manifest.jsonl.tmp");
        let mut body = String::with_capacity(done.values().map(|l| l.len() + 1).sum());
        for line in done.values() {
            body.push_str(line);
            body.push('\n');
        }
        std::fs::write(&tmp, body).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &manifest_path)
            .with_context(|| format!("compacting manifest {manifest_path:?}"))?;
    } else if manifest_path.exists() {
        std::fs::remove_file(&manifest_path)
            .with_context(|| format!("clearing stale manifest {manifest_path:?}"))?;
    }
    let skipped = done.len();
    let pending: Vec<&Job> = jobs.iter().filter(|j| !done.contains_key(&j.id)).collect();
    let to_run: &[&Job] = match limit {
        Some(l) => &pending[..l.min(pending.len())],
        None => &pending[..],
    };
    // journaled jobs keep their original lines; fresh jobs run on the
    // shared scheduler (`execute_with`: concurrent leg, then the
    // wall-clock-sensitive jobs serially) and append to the journal the
    // moment they complete — completion order on disk, spec order
    // restored in results.jsonl.
    let writer = Mutex::new(sink::ManifestWriter::append(&manifest_path)?);
    let fresh: Mutex<Vec<(String, String)>> = Mutex::new(Vec::new());
    execute_with(to_run, par, obs, &|job, tr| {
        let line = sink::job_record(job, tr).to_string();
        writer.lock().unwrap().append_line(&line)?;
        fresh.lock().unwrap().push((job.id.clone(), line));
        Ok(())
    })?;
    drop(writer);
    let fresh = fresh.into_inner().unwrap();
    let ran = fresh.len();
    for (id, line) in fresh {
        done.insert(id, line);
    }
    let pending_after = jobs.len() - done.len();
    let (results_path, csv_path, report_path) = if pending_after == 0 {
        (
            Some(sink::write_results(out_dir, &jobs, &done)?),
            Some(sink::write_pivot_csv(out_dir, &jobs, &done)?),
            Some(sink::write_report(out_dir, &jobs, &done, obs)?),
        )
    } else {
        (None, None, None)
    };
    Ok(SweepOutcome {
        total: jobs.len(),
        ran,
        skipped,
        pending: pending_after,
        manifest_path,
        results_path,
        csv_path,
        report_path,
    })
}
