//! `lad` — leader entrypoint + CLI for the LAD / Com-LAD reproduction.
//!
//! Subcommands:
//!   train            run one configured training job (flags or --config)
//!   fig2..fig6       regenerate the paper's figures (CSV under --out)
//!   kappa            empirically estimate κ for an aggregation rule
//!   theory           print the closed-form constants for a setting
//!   obs              replay / structurally diff event journals
//!   status           read (or watch) a live run's status endpoint
//!   artifacts-check  verify the AOT artifacts load and match the native oracle
//!   help             this text

use anyhow::{bail, Context};
use lad::aggregation;
use lad::attack;
use lad::cli::Args;
use lad::compress;
use lad::config::{AggregatorKind, AttackKind, CompressionKind, OracleKind, TrainConfig};
use lad::data::linreg::LinRegDataset;
use lad::experiments::{common, fig2, fig3, fig4, fig5, fig6};
use lad::grad::{CodedGradOracle, NativeLinReg, RuntimeLinReg};
use lad::net;
use lad::obs::{Obs, StatusServer};
use lad::runtime::Runtime;
use lad::theory::TheoryParams;
use lad::util::math::{rel_err, Mat};
use lad::util::rng::Rng;
use lad::Result;

const HELP: &str = "\
lad — Byzantine-robust, communication-efficient distributed training (LAD / Com-LAD)

USAGE: lad <subcommand> [--key value ...]

SUBCOMMANDS
  train             one training run
                    --config FILE | --devices N --honest H --d D --dim Q
                    --iters T --lr G --sigma-h S --agg RULE --nnm
                    --attack A --compression C --q-hat K --oracle native|runtime
                    --seed S --threads W --out DIR
  fig2              error term vs delta (theory)          [--out DIR]
  fig3              error term vs d (theory)              [--out DIR]
  fig4              loss curves, sign-flip, no compression [--iters T --oracle O --threads W --out DIR]
  fig5              loss curves vs heterogeneity           [--iters T --oracle O --threads W --out DIR]
  fig6              loss curves, compressed communication  [--iters T --oracle O --threads W --out DIR]
  e2e               transformer e2e via PJRT artifacts     [--iters T --d D]
  byz-sweep         final loss vs Byzantine count ablation [--d D --iters T --threads W]
  sweep             declarative scenario sweep (TOML grid over attack x rule x
                    compressor x f x d x sigma_h x stall_prob x deadline x
                    leader_kill_iter x worker_churn x seed)
                    --spec FILE | --preset partial-participation|attack-zoo|
                                           ef-vs-coding|elasticity
                    [--out DIR] [--resume] [--limit N] [--threads W]
                    journals each job to DIR/manifest.jsonl; --resume skips
                    finished jobs and the final results.jsonl/results.csv are
                    bit-identical to an uninterrupted run
  kappa             estimate robustness coefficient        [--agg RULE --n N --honest H]
  theory            print closed-form constants            [--n N --honest H --d D --delta X]
  node-leader       serve one run to remote workers over TCP/UDS
                    [train flags or --config FILE] --listen tcp://HOST:PORT|uds:PATH
                    [--gather-deadline-ms MS] [--join-deadline-ms MS]
                    [--device-compression] [--rotate-byzantine] [--out DIR]
                    [--checkpoint-every K] [--checkpoint-path FILE]
                    [--halt-at-iter K]  write a checkpoint after iteration K and
                                        exit without Shutdown (failover drill)
                    [--resume-from FILE] warm-restart from a checkpoint: workers
                                        rejoin by device id; the finished trace
                                        is bit-identical to an unkilled run
  node-worker       join a leader as one device
                    --connect tcp://HOST:PORT|uds:PATH --device I [--config FILE]
                    [--reconnect-addr A] [--reconnect-attempts N]
                    [--reconnect-backoff-ms MS]  redial A after a lost
                                        connection instead of dying (failover)
  obs replay FILE...  reconstruct the membership/checkpoint timeline from an
                      event journal (multiple files merge in order — pass a
                      kill/resume pair to see the stitched run)
  obs diff A B        structural diff of two journals: compares retire/rejoin/
                      miss/discard history, role draws, checkpoints (iter +
                      bytes) and failovers, ignoring wall-clock fields; exits
                      non-zero on divergence
                      [--allow CATS]  comma list of acceptable divergence
                      categories (e.g. --allow checkpoint,failover when
                      comparing a kill/resume run against an uninterrupted
                      one); A and B may each be comma-joined journal lists,
                      merged in order
  status ADDR         one-shot pretty-JSON snapshot from a live run's status
                      endpoint (what bare `nc` gets)
                      [--watch]     subscribe instead: render one line per
                                    state change (iter, phase ns, anomalies,
                                    roster transitions) until the run ends
                      [--deltas N]  with --watch, exit after N deltas
  artifacts-check   load artifacts, compare vs native oracle
  help              print this text

OPTIONS
  --threads W       worker threads for device/variant-parallel stages
                    (1 = serial, 0 = all cores; traces are bit-identical
                    for any W — randomness is pre-split per device)

OBSERVABILITY (node-leader, node-worker, sweep — pure telemetry; traces,
  wire bytes and checkpoints are bit-identical with it on or off)
  --events-out FILE   JSONL event journal (retire/rejoin, deadline misses,
                      stale-upload discards, checkpoints, failover, role
                      draws, redials, sweep jobs); sort lines by \"seq\"
  --metrics-out FILE  counter/gauge/histogram snapshot JSON, written at exit
  --trace-out FILE    Chrome trace_event JSON of the phase spans (load in
                      chrome://tracing or Perfetto)
  --status-addr A     live status endpoint (tcp://HOST:PORT or uds:PATH);
                      each connection gets one JSON snapshot — `nc` works —
                      and a client sending `WATCH\\n` gets a pushed delta
                      stream instead (`lad status --watch A`)
  LAD_OBS=1           enable the journal + exports with default paths under
                      --out (events.jsonl, metrics.json, trace.json)
";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        None | Some("help") => {
            println!("{HELP}");
            Ok(())
        }
        Some("train") => cmd_train(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("fig3") => cmd_fig3(&args),
        Some("fig4") => cmd_fig4(&args),
        Some("fig5") => cmd_fig5(&args),
        Some("fig6") => cmd_fig6(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("byz-sweep") => cmd_byz_sweep(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("kappa") => cmd_kappa(&args),
        Some("theory") => cmd_theory(&args),
        Some("node-leader") => cmd_node_leader(&args),
        Some("node-worker") => cmd_node_worker(&args),
        Some("obs") => cmd_obs(&args),
        Some("status") => cmd_status(&args),
        Some("artifacts-check") => cmd_artifacts_check(&args),
        Some(other) => bail!("unknown subcommand {other:?} (try `lad help`)"),
    }
}

fn cfg_from_args(args: &Args) -> Result<TrainConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        TrainConfig::from_file(path)?
    } else {
        TrainConfig::default()
    };
    cfg.n_devices = args.get_usize("devices", cfg.n_devices)?;
    cfg.n_honest = args.get_usize("honest", cfg.n_honest)?;
    cfg.d = args.get_usize("d", cfg.d)?;
    cfg.dim = args.get_usize("dim", cfg.dim)?;
    cfg.iters = args.get_usize("iters", cfg.iters)?;
    cfg.lr = args.get_f64("lr", cfg.lr)?;
    cfg.sigma_h = args.get_f64("sigma-h", cfg.sigma_h)?;
    cfg.trim_frac = args.get_f64("trim", cfg.trim_frac)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.log_every = args.get_usize("log-every", cfg.log_every)?;
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    if let Some(a) = args.get("agg") {
        cfg.aggregator = AggregatorKind::parse(a)?;
    }
    if args.has_flag("nnm") {
        cfg.nnm = true;
    }
    if let Some(a) = args.get("attack") {
        cfg.attack = AttackKind::parse(a)?;
    }
    if let Some(c) = args.get("compression") {
        let c = c.to_string();
        cfg.compression = match c.as_str() {
            "none" => CompressionKind::None,
            "rand-k" => CompressionKind::RandK { k: args.get_usize("q-hat", 30)? },
            "top-k" => CompressionKind::TopK { k: args.get_usize("q-hat", 30)? },
            "qsgd" => CompressionKind::Qsgd { levels: args.get_usize("levels", 16)? as u32 },
            "ef-rand-k" => CompressionKind::EfRandK { k: args.get_usize("q-hat", 30)? },
            "ef-top-k" => CompressionKind::EfTopK { k: args.get_usize("q-hat", 30)? },
            "ef-qsgd" => {
                CompressionKind::EfQsgd { levels: args.get_usize("levels", 16)? as u32 }
            }
            other => bail!("unknown compression {other:?}"),
        };
    } else {
        let _ = args.get_usize("q-hat", 0); // consume if present
    }
    if let Some(o) = args.get("oracle") {
        cfg.oracle = match o {
            "native" => OracleKind::NativeLinreg,
            "runtime" | "pjrt" => OracleKind::RuntimeLinreg,
            other => bail!("unknown oracle {other:?}"),
        };
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Build the CLI observability context from `--events-out`,
/// `--metrics-out`, `--trace-out`, `--status-addr`, or `LAD_OBS=1`
/// (which fills in default paths under `default_dir` when given).
/// With none of them present this returns [`Obs::off`] — the hot paths
/// stay exactly what they were.
fn obs_from_args(
    args: &Args,
    default_dir: Option<&str>,
) -> Result<(Obs, Option<StatusServer>)> {
    let events_out = args.get("events-out").map(str::to_string);
    let metrics_out = args.get("metrics-out").map(str::to_string);
    let trace_out = args.get("trace-out").map(str::to_string);
    let status_addr = args.get("status-addr").map(str::to_string);
    let env_on = std::env::var("LAD_OBS").is_ok_and(|v| v == "1");
    let any_flag = events_out.is_some()
        || metrics_out.is_some()
        || trace_out.is_some()
        || status_addr.is_some();
    if !env_on && !any_flag {
        return Ok((Obs::off(), None));
    }
    let def = |name: &str| default_dir.map(|d| format!("{d}/{name}"));
    let mut b = Obs::builder();
    if let Some(p) = events_out.or_else(|| if env_on { def("events.jsonl") } else { None }) {
        b = b.events_out(p);
    }
    if let Some(p) = metrics_out.or_else(|| if env_on { def("metrics.json") } else { None }) {
        b = b.metrics_out(p);
    }
    if let Some(p) = trace_out.or_else(|| if env_on { def("trace.json") } else { None }) {
        b = b.trace_out(p);
    }
    if let Some(a) = status_addr {
        b = b.status_addr(a);
    }
    let (obs, server) = b.build()?;
    if let Some(s) = &server {
        println!("status endpoint on {}", s.addr());
    }
    Ok((obs, server))
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    let out_dir = args.get_str("out", "results");
    args.reject_unknown()?;
    let mut rng = Rng::new(cfg.seed);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
    let variant = common::Variant { label: "train".into(), cfg: cfg.clone(), draco_r: None };
    let trace = common::run_variant(&ds, &variant, cfg.seed ^ 0x7A17)?;
    println!("{}", trace.summary());
    std::fs::create_dir_all(&out_dir)?;
    let path = format!("{out_dir}/train_trace.csv");
    trace.save_csv(&path)?;
    println!("trace written to {path}");
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let out_dir = args.get_str("out", "results");
    args.reject_unknown()?;
    let out = fig2::run(&fig2::Fig2Params::default());
    out.print_table();
    let p = out.save_csv(&out_dir)?;
    println!("written {p:?}");
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let out_dir = args.get_str("out", "results");
    args.reject_unknown()?;
    let out = fig3::run(&fig3::Fig3Params::default());
    out.print_table();
    let p = out.save_csv(&out_dir)?;
    println!("written {p:?}");
    Ok(())
}

fn oracle_arg(args: &Args) -> Result<OracleKind> {
    Ok(match args.get_str("oracle", "native").as_str() {
        "native" => OracleKind::NativeLinreg,
        "runtime" | "pjrt" => OracleKind::RuntimeLinreg,
        other => bail!("unknown oracle {other:?}"),
    })
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let out_dir = args.get_str("out", "results");
    let mut p = fig4::Fig4Params::default();
    p.iters = args.get_usize("iters", p.iters)?;
    p.lr = args.get_f64("lr", p.lr)?;
    p.oracle = oracle_arg(args)?;
    p.threads = args.get_usize("threads", p.threads)?;
    args.reject_unknown()?;
    let out = fig4::run(&p)?;
    out.print_table();
    let path = out.save_csv(&out_dir)?;
    println!("written {path:?}");
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let out_dir = args.get_str("out", "results");
    let mut p = fig5::Fig5Params::default();
    p.iters = args.get_usize("iters", p.iters)?;
    p.lr = args.get_f64("lr", p.lr)?;
    p.oracle = oracle_arg(args)?;
    p.threads = args.get_usize("threads", p.threads)?;
    args.reject_unknown()?;
    for out in fig5::run(&p)? {
        out.print_table();
        let path = out.save_csv(&out_dir)?;
        println!("written {path:?}");
    }
    Ok(())
}

fn cmd_fig6(args: &Args) -> Result<()> {
    let out_dir = args.get_str("out", "results");
    let mut p = fig6::Fig6Params::default();
    p.iters = args.get_usize("iters", p.iters)?;
    p.lr = args.get_f64("lr", p.lr)?;
    p.oracle = oracle_arg(args)?;
    p.threads = args.get_usize("threads", p.threads)?;
    args.reject_unknown()?;
    let out = fig6::run(&p)?;
    out.print_table();
    let path = out.save_csv(&out_dir)?;
    println!("written {path:?}");
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    use lad::experiments::e2e;
    let mut p = e2e::E2eParams::default();
    p.iters = args.get_usize("iters", p.iters)?;
    p.lr = args.get_f64("lr", p.lr)?;
    p.n_devices = args.get_usize("devices", p.n_devices)?;
    p.n_honest = args.get_usize("honest", p.n_honest)?;
    p.d = args.get_usize("d", p.d)?;
    p.seed = args.get_u64("seed", p.seed)?;
    let out_dir = args.get_str("out", "results");
    let art_dir = args.get_str("artifacts", "artifacts");
    args.reject_unknown()?;
    let mut rt = Runtime::load(&art_dir)?;
    let trace = lad::experiments::e2e::run_default(&mut rt, &p)?;
    println!("{}", trace.summary());
    std::fs::create_dir_all(&out_dir)?;
    let path = format!("{out_dir}/e2e_transformer.csv");
    trace.save_csv(&path)?;
    println!("trace written to {path}");
    Ok(())
}

fn cmd_byz_sweep(args: &Args) -> Result<()> {
    use lad::experiments::byz_sweep;
    let out_dir = args.get_str("out", "results");
    let mut p = byz_sweep::ByzSweepParams::default();
    p.d = args.get_usize("d", p.d)?;
    p.iters = args.get_usize("iters", p.iters)?;
    p.threads = args.get_usize("threads", p.threads)?;
    args.reject_unknown()?;
    let out = byz_sweep::run(&p)?;
    out.print_table();
    let path = out.save_csv(&out_dir)?;
    println!("written {path:?}");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use lad::sweep::{queue, scenarios, SweepSpec};
    use lad::util::parallel::Parallelism;
    let spec = match (args.get("spec").map(str::to_string), args.get("preset")) {
        (Some(path), None) => SweepSpec::from_file(path)?,
        (None, Some(name)) => scenarios::preset(name)?,
        (Some(_), Some(_)) => bail!("--spec and --preset are mutually exclusive"),
        (None, None) => bail!("lad sweep needs --spec FILE or --preset NAME (try `lad help`)"),
    };
    let out_dir = args.get_str("out", &format!("results/sweep_{}", spec.name));
    let resume = args.has_flag("resume");
    let limit = match args.get_usize("limit", 0)? {
        0 => None,
        l => Some(l),
    };
    let threads = args.get_usize("threads", 0)?;
    std::fs::create_dir_all(&out_dir)?;
    let (obs, status_server) = obs_from_args(args, Some(&out_dir))?;
    args.reject_unknown()?;
    let outcome = queue::run_sweep_obs(
        &spec,
        std::path::Path::new(&out_dir),
        resume,
        limit,
        Parallelism::new(threads),
        &obs,
    )?;
    obs.finish()?;
    if let Some(s) = status_server {
        s.stop();
    }
    println!(
        "sweep {}: {} jobs — {} ran, {} skipped (journaled), {} pending",
        spec.name, outcome.total, outcome.ran, outcome.skipped, outcome.pending
    );
    println!("journal: {:?}", outcome.manifest_path);
    match (&outcome.results_path, &outcome.csv_path, &outcome.report_path) {
        (Some(r), Some(c), Some(rep)) => println!("written {r:?}, {c:?} and {rep:?}"),
        _ => println!("sweep incomplete — rerun with --resume to finish the remaining jobs"),
    }
    Ok(())
}

fn cmd_node_leader(args: &Args) -> Result<()> {
    use lad::server::Checkpoint;
    use lad::util::parallel::Pool;
    let cfg = cfg_from_args(args)?;
    let addr = args.get_str("listen", &cfg.net.addr);
    let deadline_ms = args.get_u64("gather-deadline-ms", cfg.net.gather_deadline_ms)?;
    let join_ms = args.get_u64("join-deadline-ms", cfg.net.join_deadline_ms)?;
    let device_compression =
        args.has_flag("device-compression") || cfg.net.device_compression;
    let rotate_byzantine = args.has_flag("rotate-byzantine");
    let out_dir = args.get_str("out", "results");
    let checkpoint_every = args.get_u64("checkpoint-every", 0)?;
    let halt_after = args
        .get("halt-at-iter")
        .map(|s| s.parse::<u64>().context("--halt-at-iter must be an integer"))
        .transpose()?;
    let mut checkpoint_path =
        args.get("checkpoint-path").map(std::path::PathBuf::from);
    if checkpoint_path.is_none() && (checkpoint_every > 0 || halt_after.is_some()) {
        checkpoint_path = Some(std::path::PathBuf::from(format!("{out_dir}/run.ckpt")));
    }
    let resume_from = args.get("resume-from").map(str::to_string);
    // obs output defaults land under --out, so the dir must exist first
    std::fs::create_dir_all(&out_dir)?;
    let (obs, status_server) = obs_from_args(args, Some(&out_dir))?;
    args.reject_unknown()?;

    // same dataset/run seeding as `lad train`, so the node trace is
    // directly comparable to the central one
    let mut data_rng = Rng::new(cfg.seed);
    let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut data_rng);
    let listener = net::NetListener::bind(&addr)?;
    println!(
        "leader listening on {} — waiting for {} workers (digest {:#018x})",
        listener.local_addr()?,
        cfg.n_devices,
        net::config_digest(&cfg)
    );
    let pool = Pool::new(cfg.threads);
    let agg = aggregation::from_config_pooled(&cfg, &pool);
    let atk = attack::from_kind(cfg.attack);
    let comp = compress::from_kind(cfg.compression);
    let leader = net::Leader {
        cfg: &cfg,
        ds: &ds,
        agg: agg.as_ref(),
        attack: atk.as_ref(),
        comp: comp.as_ref(),
        opts: net::LeaderOpts {
            gather_deadline: (deadline_ms > 0)
                .then(|| std::time::Duration::from_millis(deadline_ms)),
            device_compression,
            join_deadline: (join_ms > 0)
                .then(|| std::time::Duration::from_millis(join_ms)),
            rotate_byzantine,
            checkpoint_every,
            checkpoint_path,
            halt_after,
            obs: obs.clone(),
            ..Default::default()
        },
        pool,
        send_dataset: true,
    };
    // serve() owns the accept loop: a connection that never sends a valid
    // Join is dropped after --join-deadline-ms and its slot reclaimed
    let mut x0 = vec![0.0f32; cfg.dim];
    let trace = match resume_from {
        Some(path) => {
            let ckpt = Checkpoint::load(&path)
                .with_context(|| format!("loading checkpoint {path}"))?;
            println!("resuming from {path} at iteration {}", ckpt.iter);
            leader.serve_resume(&listener, &ckpt, &mut x0, "node-leader")?
        }
        None => {
            leader.serve(&listener, &mut x0, "node-leader", &mut Rng::new(cfg.seed ^ 0x7A17))?
        }
    };
    println!("{}", trace.summary());
    let path = format!("{out_dir}/node_trace.csv");
    trace.save_csv(&path)?;
    println!("trace written to {path}");
    obs.finish()?;
    if let Some(s) = status_server {
        s.stop();
    }
    Ok(())
}

fn cmd_node_worker(args: &Args) -> Result<()> {
    let device = args.get_usize("device", 0)?;
    let local_cfg = match args.get("config") {
        Some(path) => Some(TrainConfig::from_file(path)?),
        None => None,
    };
    let local_digest = local_cfg.as_ref().map(net::config_digest);
    // --connect beats the config's [net] addr beats the built-in default
    let default_addr =
        local_cfg.map(|c| c.net.addr).unwrap_or_else(|| TrainConfig::default().net.addr);
    let addr = args.get_str("connect", &default_addr);
    let reconnect_addr = args.get("reconnect-addr").map(str::to_string);
    let reconnect_attempts = args.get_usize("reconnect-attempts", 8)? as u32;
    let backoff_ms = args.get_u64("reconnect-backoff-ms", 250)?;
    // no --out here: LAD_OBS=1 alone gives an in-memory registry, and
    // --events-out journals redials to an explicit path
    let (obs, status_server) = obs_from_args(args, None)?;
    args.reject_unknown()?;
    println!("worker {device} connecting to {addr}");
    let link = net::connect(&addr)?;
    let wopts = net::WorkerOpts {
        reconnect_addr,
        reconnect_attempts,
        reconnect_backoff: std::time::Duration::from_millis(backoff_ms),
        obs: obs.clone(),
        ..Default::default()
    };
    let report = net::run_worker_opts(link, device, None, local_digest, &wopts)?;
    println!(
        "worker {} done: {} iterations, {} B up, {} B down, {} reconnect(s)",
        report.device, report.iters, report.up_bytes, report.down_bytes, report.reconnects
    );
    obs.finish()?;
    if let Some(s) = status_server {
        s.stop();
    }
    Ok(())
}

fn cmd_kappa(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 100)?;
    let h = args.get_usize("honest", 80)?;
    let dim = args.get_usize("dim", 20)?;
    let trials = args.get_usize("trials", 50)?;
    let agg_name = args.get_str("agg", "cwtm");
    let nnm = args.has_flag("nnm");
    args.reject_unknown()?;
    let mut cfg = TrainConfig::default();
    cfg.n_devices = n;
    cfg.n_honest = h;
    cfg.aggregator = AggregatorKind::parse(&agg_name)?;
    cfg.nnm = nnm;
    let agg = aggregation::from_config(&cfg);
    let mut rng = Rng::new(7);
    let k = aggregation::kappa::estimate_kappa(agg.as_ref(), h, n - h, dim, trials, &mut rng);
    println!("kappa_hat({}) = {k:.4}   [N={n}, H={h}, dim={dim}, {trials} trials]", agg.name());
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 100)?;
    let h = args.get_usize("honest", 65)?;
    let d = args.get_usize("d", 5)?;
    let delta = args.get_f64("delta", 0.0)?;
    let kappa = args.get_f64("kappa", 1.5)?;
    let beta = args.get_f64("beta", 1.0)?;
    args.reject_unknown()?;
    let tp = TheoryParams::new(n, h, d).with_delta(delta).with_kappa(kappa).with_beta(beta);
    println!("N={n} H={h} d={d} delta={delta} kappa={kappa} beta={beta}");
    println!("  lemma1 infimum      = {:.6e}", tp.lemma1());
    println!(
        "  kappa1..4           = {:.4e} {:.4e} {:.4e} {:.4e}",
        tp.kappa1(),
        tp.kappa2(),
        tp.kappa3(),
        tp.kappa4()
    );
    let (x1, x2, x3, x4) = tp.xi();
    println!("  xi1..4 (delta=0)    = {x1:.4e} {x2:.4e} {x3:.4e} {x4:.4e}");
    println!("  converges           = {}", tp.converges());
    if tp.converges() {
        println!("  gamma_max           = {:.4e}", tp.gamma_max());
    }
    println!("  error term (eq 33)  = {:.6e}", tp.error_term_bigo());
    println!("  LAD error  (eq 35)  = {:.6e}", tp.error_term_lad_bigo());
    println!("  baseline   (eq 36)  = {:.6e}", tp.error_term_baseline());
    println!("  d crossover         = {:.2}", tp.d_crossover());
    Ok(())
}

/// Merge one or more journal specs into a single timeline. Each spec
/// may itself be a comma-joined list of journal files (the shape a
/// kill/resume pair leaves behind: each restart truncates and rewrites
/// its own journal, so the halves are merged in order).
fn load_timeline(specs: &[String]) -> Result<lad::obs::RunTimeline> {
    let mut tl = lad::obs::RunTimeline::default();
    for spec in specs {
        for path in spec.split(',').filter(|p| !p.is_empty()) {
            let part = lad::obs::RunTimeline::from_journal(path)?;
            tl.merge(&part);
        }
    }
    Ok(tl)
}

fn cmd_obs(args: &Args) -> Result<()> {
    use lad::obs::replay;
    match args.positional.first().map(String::as_str) {
        Some("replay") => {
            let files = &args.positional[1..];
            anyhow::ensure!(
                !files.is_empty(),
                "usage: lad obs replay EVENTS.jsonl [MORE.jsonl ...]"
            );
            args.reject_unknown()?;
            print!("{}", load_timeline(files)?.render());
            Ok(())
        }
        Some("diff") => {
            anyhow::ensure!(
                args.positional.len() == 3,
                "usage: lad obs diff A.jsonl B.jsonl [--allow CAT,CAT]"
            );
            let allow: Vec<String> = args
                .get("allow")
                .map(|s| s.split(',').map(|c| c.trim().to_string()).collect())
                .unwrap_or_default();
            args.reject_unknown()?;
            let a = load_timeline(&args.positional[1..2])?;
            let b = load_timeline(&args.positional[2..3])?;
            let divs = replay::diff(&a, &b);
            if divs.is_empty() {
                println!("journals are structurally identical ({} vs {} events)", a.events,
                    b.events);
                return Ok(());
            }
            for d in &divs {
                println!("[{}] {}", d.category, d.detail);
            }
            let allowed: Vec<&str> = allow.iter().map(String::as_str).collect();
            if !allowed.is_empty() && replay::only_in(&divs, &allowed) {
                println!(
                    "{} divergence(s), all within --allow {}",
                    divs.len(),
                    allowed.join(",")
                );
                return Ok(());
            }
            bail!("{} structural divergence(s)", divs.len());
        }
        _ => bail!("usage: lad obs replay FILE... | lad obs diff A B (try `lad help`)"),
    }
}

fn cmd_status(args: &Args) -> Result<()> {
    use lad::net::Transport as _;
    use std::io::Write as _;
    let addr = match args.positional.first() {
        Some(a) => a.clone(),
        None => args
            .get("addr")
            .map(str::to_string)
            .context("usage: lad status [--watch] tcp://HOST:PORT|uds:PATH")?,
    };
    let watch = args.has_flag("watch");
    let deltas = match args.get_u64("deltas", 0)? {
        0 => None,
        n => Some(n),
    };
    args.reject_unknown()?;
    if watch {
        let seen = lad::obs::watch::run_watch(&addr, &mut std::io::stdout(), deltas)?;
        println!("watch stream ended after {seen} delta(s)");
    } else {
        // one-shot snapshot: connect, say nothing, print to EOF — the
        // same bytes `nc` would show
        let mut conn = net::connect(&addr)?;
        let mut out = std::io::stdout();
        let mut buf = [0u8; 4096];
        loop {
            let n = conn.recv_raw(&mut buf)?;
            if n == 0 {
                break;
            }
            out.write_all(&buf[..n])?;
        }
        out.flush()?;
    }
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    args.reject_unknown()?;
    let rt = Runtime::load(&dir).context("loading artifacts")?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {:?}", rt.manifest().entries.keys().collect::<Vec<_>>());
    // parity check vs native oracle
    let meta = &rt.manifest().entries["coded_grad"].meta;
    let n = meta["n"] as usize;
    let q = meta["q"] as usize;
    let mut rng = Rng::new(99);
    let ds = LinRegDataset::generate(n, q, 0.3, &mut rng);
    let x = rng.gauss_vec(q);
    let subsets: Vec<Vec<usize>> = {
        use lad::coding::{Assignment, TaskMatrix};
        let s = TaskMatrix::cyclic(n, 5);
        let a = Assignment::draw(n, &mut rng);
        (0..n).map(|i| a.subsets_for(s.row(a.tasks[i])).collect()).collect()
    };
    let mut native = NativeLinReg::new(ds.clone());
    let mut runtime = RuntimeLinReg::new(rt, ds)?;
    let mut g_native = Mat::zeros(n, q);
    let mut g_rt = Mat::zeros(n, q);
    native.coded_grads(&x, &subsets, &mut g_native)?;
    runtime.coded_grads(&x, &subsets, &mut g_rt)?;
    let err = rel_err(&g_rt.data, &g_native.data);
    let l_native = native.loss(&x)?;
    let l_rt = runtime.loss(&x)?;
    println!("coded_grad parity: rel_err = {err:.3e}");
    println!("loss parity: native {l_native:.6e} vs runtime {l_rt:.6e}");
    anyhow::ensure!(err < 1e-4, "coded_grad parity failure");
    anyhow::ensure!(
        (l_native - l_rt).abs() / l_native.max(1.0) < 1e-4,
        "loss parity failure"
    );
    println!("artifacts-check OK");
    Ok(())
}
