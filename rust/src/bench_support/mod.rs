//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! min/median/p95 per iteration, and prints aligned table rows so every
//! `cargo bench` target can emit the paper's tables.

use crate::util::stats::quantile;
use std::hint::black_box;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:8.1} ns")
    } else if ns < 1e6 {
        format!("{:8.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.2} ms", ns / 1e6)
    } else {
        format!("{:8.2} s ", ns / 1e9)
    }
}

/// Benchmark `f`, auto-calibrated to ~`budget_ms` of sampling.
pub fn bench<T>(name: &str, budget_ms: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // calibrate: how many calls fit in ~budget/10?
    let t0 = Instant::now();
    black_box(f());
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let per_sample = ((budget_ms / 1e3 / 30.0) / one).max(1.0) as usize;
    let n_samples = 15usize;

    let mut samples_ns = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let t = Instant::now();
        for _ in 0..per_sample {
            black_box(f());
        }
        samples_ns.push(t.elapsed().as_secs_f64() * 1e9 / per_sample as f64);
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters: per_sample * n_samples,
        min_ns: samples_ns[0],
        median_ns: quantile(&samples_ns, 0.5),
        p95_ns: quantile(&samples_ns, 0.95),
    }
}

/// Print one result as an aligned row.
pub fn report(r: &BenchResult) {
    println!(
        "  {:<44} min {}  median {}  p95 {}  ({} iters)",
        r.name,
        fmt_ns(r.min_ns),
        fmt_ns(r.median_ns),
        fmt_ns(r.p95_ns),
        r.iters
    );
}

/// Run + report in one call; returns the result for ratio computations.
pub fn run<T>(name: &str, budget_ms: f64, f: impl FnMut() -> T) -> BenchResult {
    let r = bench(name, budget_ms, f);
    report(&r);
    r
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let r = bench("noop-ish", 20.0, || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.min_ns > 0.0);
        assert!(r.median_ns >= r.min_ns);
        assert!(r.p95_ns >= r.median_ns);
        assert!(r.iters >= 15);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            min_ns: 1e3,
            median_ns: 1e3,
            p95_ns: 1e3,
        };
        // 1000 items per 1µs iteration = 1e9 items/s
        assert!((r.throughput(1000.0) - 1e9).abs() < 1.0);
    }
}
