//! The training loop — Algorithm 1 (LAD) and Algorithm 2 (Com-LAD), plus
//! the DRACO baseline loop.
//!
//! Per iteration t:
//! 1. draw the random assignment (T^t, p^t);
//! 2. obtain every device's true coded vector g_i (gradient oracle — the
//!    fused Pallas kernel on the AOT path);
//! 3. Byzantine devices craft their lies from their true messages (and, for
//!    omniscient attacks, the honest messages);
//! 4. all messages pass the compression operator C (Com-LAD; identity for
//!    LAD), with exact uplink-bit accounting — under an `ef-*` kind the
//!    error-feedback stage (`compress::ef`) compresses residual + message
//!    per device and carries the compression error forward;
//! 5. the server aggregates with the configured κ-robust rule and applies
//!    x ← x − γ·agg(·).

use crate::aggregation::Aggregator;
use crate::attack::{Attack, AttackContext};
use crate::coding::{Assignment, DracoScheme, TaskMatrix};
use crate::compress::{compress_batch, compress_batch_ef, Compressor, EfState};
use crate::config::TrainConfig;
use crate::grad::CodedGradOracle;
use crate::obs::{Event, Obs};
use crate::server::metrics::TrainTrace;
use crate::util::math::{norm, Mat};
use crate::util::parallel::Pool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use crate::Result;

/// Which devices are Byzantine this iteration. Shared with the net
/// leader (`net::leader`) so rotation consumes the run RNG identically
/// on both paths; with `rotate = false` it consumes nothing.
pub(crate) fn byz_set(cfg: &TrainConfig, rotate: bool, rng: &mut Rng) -> Vec<bool> {
    let mut is_byz = vec![false; cfg.n_devices];
    if rotate {
        for i in rng.choose_k(cfg.n_devices, cfg.n_byz()) {
            is_byz[i] = true;
        }
    } else {
        // fixed identities: the last N−H devices are Byzantine
        for b in is_byz.iter_mut().skip(cfg.n_honest) {
            *b = true;
        }
    }
    is_byz
}

/// LAD / Com-LAD trainer (meta-algorithm: aggregation rule, attack and
/// compressor are injected).
pub struct Trainer<'a> {
    pub cfg: &'a TrainConfig,
    pub agg: &'a dyn Aggregator,
    pub attack: &'a dyn Attack,
    pub comp: &'a dyn Compressor,
    /// re-sample Byzantine identities each iteration
    pub rotate_byzantine: bool,
    /// optional learning-rate schedule; `None` ⇒ the paper's fixed γ⁰
    pub schedule: Option<crate::server::schedule::Schedule>,
    /// shared worker pool; `None` ⇒ `run` builds one from `cfg.threads`
    pub pool: Option<Pool>,
    /// observability sink ([`Obs::off`] by default). Spans time the
    /// oracle / craft / compress / aggregate phases and role draws are
    /// journaled under rotation; pure telemetry — the trace, RNG
    /// stream and iterates are bit-identical with it on or off.
    pub obs: Obs,
}

impl<'a> Trainer<'a> {
    pub fn new(
        cfg: &'a TrainConfig,
        agg: &'a dyn Aggregator,
        attack: &'a dyn Attack,
        comp: &'a dyn Compressor,
    ) -> Self {
        Trainer {
            cfg,
            agg,
            attack,
            comp,
            rotate_byzantine: false,
            schedule: None,
            pool: None,
            obs: Obs::off(),
        }
    }

    /// Share an existing worker pool (ideally the same one the aggregator
    /// was built with, see `aggregation::from_config_pooled`) instead of
    /// spawning a private one per `run`.
    pub fn with_pool(mut self, pool: &Pool) -> Self {
        self.pool = Some(pool.clone());
        self
    }

    /// Attach an observability sink (events + metrics + spans).
    pub fn with_obs(mut self, obs: &Obs) -> Self {
        self.obs = obs.clone();
        self
    }

    /// Run the loop from `x0`; returns the metric trace (and leaves the
    /// final iterate in `x0`).
    pub fn run(
        &self,
        oracle: &mut dyn CodedGradOracle,
        x0: &mut Vec<f32>,
        label: &str,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        let cfg = self.cfg;
        cfg.validate()?;
        assert_eq!(oracle.n(), cfg.n_devices, "oracle N != config N");
        assert_eq!(oracle.dim(), cfg.dim, "oracle Q != config Q");
        let timer = Timer::start();
        // hand the aggregation rule the obs context so its internal
        // kernels (Gram fill, Krum scoring, NNM mixing, Weiszfeld) span
        // + histogram themselves; a no-op when obs is off
        self.agg.set_obs(&self.obs);
        // One persistent worker pool for the whole run: the oracle's
        // row-parallel kernels, per-device compression and the aggregation
        // rules (when built via from_config_pooled) all share its workers,
        // so no per-iteration spawn cost remains.
        let pool = match &self.pool {
            Some(p) => p.clone(),
            None => Pool::new(cfg.threads),
        };
        oracle.set_pool(&pool);
        // One private compression stream per device, pre-split (not forked)
        // from the run RNG: the main stream is left untouched, and because
        // no stream is shared across devices, serial and multi-threaded
        // execution consume identical randomness — the determinism contract
        // of util::parallel. Streams persist across iterations, exactly as
        // a real device's local RNG would.
        let mut comp_rngs = rng.split(cfg.n_devices);
        // Error-feedback residual memory (Some only for ef-* kinds): one
        // row per device, zero at run start, carried across iterations.
        let mut ef = EfState::for_kind(cfg.compression, cfg.n_devices, cfg.dim);
        let mut trace = TrainTrace::new(label);
        let s_hat = TaskMatrix::cyclic(cfg.n_devices, cfg.d);
        let mut coded = Mat::zeros(cfg.n_devices, cfg.dim);
        let mut subsets: Vec<Vec<usize>> = vec![Vec::with_capacity(cfg.d); cfg.n_devices];
        let mut bits_total: u64 = 0;

        for t in 0..cfg.iters {
            // (1) assignment
            let assign = Assignment::draw(cfg.n_devices, rng);
            for i in 0..cfg.n_devices {
                subsets[i].clear();
                subsets[i].extend(assign.subsets_for(s_hat.row(assign.tasks[i])));
            }
            // (2) true coded vectors for every device
            let sp_oracle = self.obs.span("oracle");
            oracle.coded_grads(x0, &subsets, &mut coded)?;
            sp_oracle.done();

            let is_byz = byz_set(cfg, self.rotate_byzantine, rng);
            if self.rotate_byzantine && self.obs.enabled() {
                self.obs.emit(Event::ByzantineRoleDrawn {
                    iter: t as u64,
                    byzantine: (0..cfg.n_devices).filter(|&i| is_byz[i]).collect(),
                });
            }
            // zero-copy: the honest / Byzantine views borrow straight from
            // the contiguous `coded` slab — no per-device row copies; owned
            // storage appears only where a crafted lie genuinely needs it
            let honest_true: Vec<&[f32]> = (0..cfg.n_devices)
                .filter(|&i| !is_byz[i])
                .map(|i| coded.row(i))
                .collect();
            let byz_true: Vec<&[f32]> = (0..cfg.n_devices)
                .filter(|&i| is_byz[i])
                .map(|i| coded.row(i))
                .collect();

            // (3) Byzantine crafting (pre-compression, as in §VII-B)
            let sp_craft = self.obs.span("craft");
            let lies = if byz_true.is_empty() {
                Vec::new()
            } else {
                let mut ctx =
                    AttackContext { honest: &honest_true, own_true: &byz_true, rng };
                self.attack.craft(&mut ctx)
            };
            sp_craft.done();

            // (4) compression + bit accounting: every device uplinks once,
            // on its own RNG stream, in parallel when cfg.threads > 1.
            // Messages are stitched back into DEVICE order so comp_rngs[i]
            // really is device i's stream even under rotating Byzantine
            // identities. With fixed identities (the default) device order
            // equals the honest-then-lies order used everywhere else.
            let mut device_msgs: Vec<&[f32]> = Vec::with_capacity(cfg.n_devices);
            let (mut hi, mut li) = (0usize, 0usize);
            for &byz in &is_byz {
                if byz {
                    device_msgs.push(&lies[li]);
                    li += 1;
                } else {
                    device_msgs.push(honest_true[hi]);
                    hi += 1;
                }
            }
            let sp_comp = self.obs.span("compress");
            let (msgs, bits) = match ef.as_mut() {
                Some(st) => {
                    compress_batch_ef(self.comp, st, &device_msgs, &mut comp_rngs, &pool)
                }
                None => compress_batch(self.comp, &device_msgs, &mut comp_rngs, &pool),
            };
            sp_comp.done();
            bits_total += bits;

            // (5) robust aggregation + model update
            let sp_agg = self.obs.span("aggregate");
            let update = self.agg.aggregate(&msgs);
            let agg_ns = sp_agg.done();
            if self.obs.enabled() {
                // per-rule kernel histogram, same key as the net leader
                self.obs.observe_ns(&format!("aggregate_kernel/{}", self.agg.name()), agg_ns);
            }
            let gamma = self.schedule.map_or(cfg.lr, |s| s.at(t)) as f32;
            for (xi, ui) in x0.iter_mut().zip(&update) {
                *xi -= gamma * ui;
            }

            if (cfg.log_every > 0 && t % cfg.log_every == 0) || t + 1 == cfg.iters {
                let loss = oracle.loss(x0)?;
                trace.record(t, loss, norm(&update), bits_total);
            }
        }
        trace.final_loss = oracle.loss(x0)?;
        trace.wall_s = timer.elapsed_s();
        Ok(trace)
    }
}

/// DRACO baseline trainer: fractional-repetition coding + exact majority
/// decode instead of robust aggregation. Recovers attack-free GD whenever
/// every group keeps an honest majority.
pub struct DracoTrainer<'a> {
    pub cfg: &'a TrainConfig,
    pub attack: &'a dyn Attack,
    /// group size r = 2b+1 (the paper quotes r=41 for N=100, b=20)
    pub r: usize,
}

impl<'a> DracoTrainer<'a> {
    pub fn run(
        &self,
        oracle: &mut dyn CodedGradOracle,
        x0: &mut Vec<f32>,
        label: &str,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        let cfg = self.cfg;
        let timer = Timer::start();
        let pool = Pool::new(cfg.threads);
        oracle.set_pool(&pool);
        let mut trace = TrainTrace::new(label);
        let scheme = DracoScheme::new(cfg.n_devices, self.r);
        let mut grads = Mat::zeros(cfg.n_devices, cfg.dim);
        let mut bits_total: u64 = 0;

        for t in 0..cfg.iters {
            oracle.grad_matrix(x0, &mut grads)?;
            let is_byz = byz_set(cfg, false, rng);
            let true_msgs: Vec<Vec<f32>> =
                (0..cfg.n_devices).map(|i| scheme.honest_message(i, &grads)).collect();
            let honest: Vec<&[f32]> = (0..cfg.n_devices)
                .filter(|&i| !is_byz[i])
                .map(|i| true_msgs[i].as_slice())
                .collect();
            let byz_true: Vec<&[f32]> = (0..cfg.n_devices)
                .filter(|&i| is_byz[i])
                .map(|i| true_msgs[i].as_slice())
                .collect();
            let lies = if byz_true.is_empty() {
                Vec::new()
            } else {
                let mut ctx = AttackContext { honest: &honest, own_true: &byz_true, rng };
                self.attack.craft(&mut ctx)
            };
            // stitch messages back into device order
            let mut msgs = true_msgs;
            let mut li = 0;
            for i in 0..cfg.n_devices {
                if is_byz[i] {
                    msgs[i] = lies[li].clone();
                    li += 1;
                }
            }
            bits_total += (cfg.n_devices * cfg.dim * 32) as u64;

            // decode; on failure, skip the update (and count the anomaly)
            let update = match scheme.decode(&msgs, 1e-3) {
                Ok(u) => u,
                Err(_) => {
                    trace.anomalies += 1;
                    vec![0.0; cfg.dim]
                }
            };
            // DRACO decodes μ = (1/N)∇F; LAD's aggregate is also ≈ μ-scale,
            // so the same learning rate applies.
            let gamma = cfg.lr as f32;
            for (xi, ui) in x0.iter_mut().zip(&update) {
                *xi -= gamma * ui;
            }
            if (cfg.log_every > 0 && t % cfg.log_every == 0) || t + 1 == cfg.iters {
                let loss = oracle.loss(x0)?;
                trace.record(t, loss, norm(&update), bits_total);
            }
        }
        trace.final_loss = oracle.loss(x0)?;
        trace.wall_s = timer.elapsed_s();
        Ok(trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::{Cwtm, Mean};
    use crate::attack::{NoAttack, SignFlip};
    use crate::compress::Identity;
    use crate::config::TrainConfig;
    use crate::data::linreg::LinRegDataset;
    use crate::grad::NativeLinReg;

    fn small_cfg() -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.n_devices = 20;
        cfg.n_honest = 16;
        cfg.d = 4;
        cfg.dim = 10;
        cfg.iters = 300;
        cfg.lr = 1e-4;
        cfg.sigma_h = 0.3;
        cfg.log_every = 50;
        cfg
    }

    fn setup(cfg: &TrainConfig, seed: u64) -> (NativeLinReg, Vec<f32>, Rng) {
        let mut rng = Rng::new(seed);
        let ds = LinRegDataset::generate(cfg.n_devices, cfg.dim, cfg.sigma_h, &mut rng);
        let x0 = vec![0.0f32; cfg.dim];
        (NativeLinReg::new(ds), x0, rng)
    }

    #[test]
    fn loss_decreases_without_attack() {
        let cfg = small_cfg();
        let (mut oracle, mut x0, mut rng) = setup(&cfg, 1);
        let l0 = oracle.loss(&x0).unwrap();
        let tr = Trainer::new(&cfg, &Mean, &NoAttack, &Identity)
            .run(&mut oracle, &mut x0, "clean", &mut rng)
            .unwrap();
        assert!(tr.final_loss < l0 * 0.5, "{} !< {}", tr.final_loss, l0);
    }

    #[test]
    fn cwtm_survives_sign_flip_where_mean_does_not() {
        let cfg = small_cfg();
        let flip = SignFlip { coeff: -2.0 };
        let (mut o1, mut x1, mut r1) = setup(&cfg, 2);
        let mean_tr = Trainer::new(&cfg, &Mean, &flip, &Identity)
            .run(&mut o1, &mut x1, "va", &mut r1)
            .unwrap();
        let (mut o2, mut x2, mut r2) = setup(&cfg, 2);
        let cwtm = Cwtm::new(0.2);
        let cwtm_tr = Trainer::new(&cfg, &cwtm, &flip, &Identity)
            .run(&mut o2, &mut x2, "cwtm", &mut r2)
            .unwrap();
        assert!(
            cwtm_tr.final_loss < mean_tr.final_loss,
            "cwtm {} !< mean {}",
            cwtm_tr.final_loss,
            mean_tr.final_loss
        );
    }

    #[test]
    fn larger_d_reduces_final_loss_under_attack() {
        let flip = SignFlip { coeff: -2.0 };
        let mut finals = Vec::new();
        for d in [1usize, 10] {
            let mut cfg = small_cfg();
            cfg.d = d;
            let (mut oracle, mut x0, mut rng) = setup(&cfg, 3);
            let cwtm = Cwtm::new(0.1);
            let tr = Trainer::new(&cfg, &cwtm, &flip, &Identity)
                .run(&mut oracle, &mut x0, &format!("d{d}"), &mut rng)
                .unwrap();
            finals.push(tr.final_loss);
        }
        assert!(finals[1] < finals[0], "d=10 {} !< d=1 {}", finals[1], finals[0]);
    }

    #[test]
    fn draco_under_attack_equals_draco_without_attack() {
        // DRACO's decode is exact whenever every group keeps an honest
        // majority, so the attacked trajectory must EQUAL the clean one.
        let mut cfg = small_cfg();
        cfg.iters = 100;
        let flip = SignFlip { coeff: -2.0 };
        let (mut o1, mut x1, mut r1) = setup(&cfg, 4);
        let attacked = DracoTrainer { cfg: &cfg, attack: &flip, r: 9 }
            .run(&mut o1, &mut x1, "draco-attacked", &mut r1)
            .unwrap();
        let mut clean_cfg = cfg.clone();
        clean_cfg.n_honest = cfg.n_devices; // nobody byzantine
        let (mut o2, mut x2, mut r2) = setup(&clean_cfg, 4);
        let clean = DracoTrainer { cfg: &clean_cfg, attack: &NoAttack, r: 9 }
            .run(&mut o2, &mut x2, "draco-clean", &mut r2)
            .unwrap();
        assert_eq!(attacked.anomalies, 0);
        let rel = (attacked.final_loss - clean.final_loss).abs()
            / clean.final_loss.max(1e-9);
        assert!(rel < 1e-6, "attacked {} vs clean {}", attacked.final_loss, clean.final_loss);
        // and it actually learns
        assert!(attacked.final_loss < attacked.loss[0]);
    }

    #[test]
    fn invsqrt_schedule_converges_no_worse_than_constant() {
        use crate::server::schedule::Schedule;
        let cfg = small_cfg();
        let flip = SignFlip { coeff: -2.0 };
        let cwtm = Cwtm::new(0.2);
        let (mut o1, mut x1, mut r1) = setup(&cfg, 8);
        let fixed = Trainer::new(&cfg, &cwtm, &flip, &Identity)
            .run(&mut o1, &mut x1, "fixed", &mut r1)
            .unwrap();
        let (mut o2, mut x2, mut r2) = setup(&cfg, 8);
        let mut tr = Trainer::new(&cfg, &cwtm, &flip, &Identity);
        tr.schedule =
            Some(Schedule::InvSqrt { gamma0: cfg.lr * 2.0, tau: cfg.iters as f64 / 4.0 });
        let sched = tr.run(&mut o2, &mut x2, "invsqrt", &mut r2).unwrap();
        // both must learn; the diminishing schedule should land in the same
        // ballpark (within 2x) of the tuned constant rate
        assert!(sched.final_loss < sched.loss[0]);
        assert!(sched.final_loss < fixed.final_loss * 2.0);
    }

    #[test]
    fn compression_bits_are_counted() {
        let mut cfg = small_cfg();
        cfg.iters = 10;
        cfg.log_every = 5;
        let (mut oracle, mut x0, mut rng) = setup(&cfg, 5);
        let comp = crate::compress::RandK::new(3);
        let cwtm = Cwtm::new(0.1);
        let tr = Trainer::new(&cfg, &cwtm, &NoAttack, &comp)
            .run(&mut oracle, &mut x0, "com", &mut rng)
            .unwrap();
        // 20 devices × 10 iters × 3·(32+4) bits
        assert_eq!(tr.total_bits(), 20 * 10 * 3 * (32 + 4));
    }
}
