//! The coordinator (Layer 3): training loop, metrics, and a threaded
//! leader/worker cluster simulation.

pub mod checkpoint;
pub mod cluster;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use checkpoint::{Checkpoint, RosterEntry, TraceBlock};
pub use metrics::TrainTrace;
pub use schedule::Schedule;
pub use trainer::{DracoTrainer, Trainer};
