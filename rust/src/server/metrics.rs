//! Training metrics: loss/grad-norm traces, exact communication bits and
//! wall time, with CSV export for the figure reproductions.

use crate::util::csv::CsvWriter;
use crate::Result;
use std::path::Path;

/// One run's trace.
#[derive(Debug, Clone, Default)]
pub struct TrainTrace {
    pub label: String,
    /// iterations at which metrics were sampled
    pub iters: Vec<usize>,
    pub loss: Vec<f64>,
    pub grad_update_norm: Vec<f64>,
    /// cumulative uplink bits transmitted by all devices up to each sample
    pub bits: Vec<u64>,
    /// decode failures (DRACO), gather-deadline misses, or other anomalies
    pub anomalies: usize,
    pub wall_s: f64,
    pub final_loss: f64,
    /// uplink bytes actually framed on the wire, cumulative over the run
    /// (set by the `net` leader; 0 on the central fast path, where `bits`
    /// is the analytic accounting and nothing is serialized)
    pub wire_up_bytes: u64,
    /// downlink (broadcast + handshake) bytes framed on the wire
    pub wire_down_bytes: u64,
    /// cumulative leader time (ns) spent encoding + writing broadcasts
    /// (set by the `net` leader; 0 on the central fast path). Wall-clock
    /// telemetry only: phase timings are never part of trace-equality
    /// comparisons or the sweep result schema.
    pub broadcast_ns: u64,
    /// cumulative leader time (ns) blocked in the uplink gather
    pub gather_ns: u64,
    /// cumulative leader time (ns) crafting, compressing and aggregating
    pub aggregate_ns: u64,
    /// gather-deadline misses broken out of `anomalies` (one per device
    /// per missed gather). Deterministic under the drill harnesses;
    /// like the `*_ns` fields, never part of trace-equality checks.
    pub deadline_misses: u64,
    /// devices retired after `net::MISS_RETIRE_STREAK` misses (or a
    /// dead link)
    pub retirements: u64,
    /// replacement joins activated into retired slots
    pub rejoins: u64,
}

impl TrainTrace {
    pub fn new(label: impl Into<String>) -> Self {
        TrainTrace { label: label.into(), ..Default::default() }
    }

    pub fn record(&mut self, iter: usize, loss: f64, upd_norm: f64, bits: u64) {
        self.iters.push(iter);
        self.loss.push(loss);
        self.grad_update_norm.push(upd_norm);
        self.bits.push(bits);
    }

    /// Total uplink bits at end of run.
    pub fn total_bits(&self) -> u64 {
        self.bits.last().copied().unwrap_or(0)
    }

    /// Write `iter,loss,update_norm,bits` rows.
    pub fn save_csv<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut w = CsvWriter::create(path, &["iter", "loss", "update_norm", "bits"])?;
        for i in 0..self.iters.len() {
            w.row(&[
                self.iters[i] as f64,
                self.loss[i],
                self.grad_update_norm[i],
                self.bits[i] as f64,
            ])?;
        }
        w.flush()?;
        Ok(())
    }

    /// Pretty one-line summary. Net runs (span-derived phase timings
    /// present) get a per-phase percentage breakdown; drills that hit
    /// the elasticity paths get the deadline-miss / retirement / rejoin
    /// breakdown next to the raw anomalies total.
    pub fn summary(&self) -> String {
        format!(
            "{:<28} final_loss={:.6e}  bits={:.3e}  wall={:.2}s{}{}{}",
            self.label,
            self.final_loss,
            self.total_bits() as f64,
            self.wall_s,
            if self.wire_up_bytes > 0 {
                format!(
                    "  wire_up={:.3e}B wire_down={:.3e}B",
                    self.wire_up_bytes as f64, self.wire_down_bytes as f64
                )
            } else {
                String::new()
            },
            self.phase_breakdown(),
            if self.anomalies > 0 {
                format!("  anomalies={}{}", self.anomalies, self.anomaly_breakdown())
            } else {
                String::new()
            }
        )
    }

    /// `"  phases[bcast 12% gather 70% agg 18%]"`, or empty when no
    /// phase spans were recorded (central fast path).
    fn phase_breakdown(&self) -> String {
        let total = self.broadcast_ns + self.gather_ns + self.aggregate_ns;
        if total == 0 {
            return String::new();
        }
        let pct = |ns: u64| (ns as f64 * 100.0 / total as f64).round() as u64;
        format!(
            "  phases[bcast {}% gather {}% agg {}%]",
            pct(self.broadcast_ns),
            pct(self.gather_ns),
            pct(self.aggregate_ns)
        )
    }

    /// `" (misses=N retired=N rejoined=N)"`, or empty when the run saw
    /// no elasticity events.
    fn anomaly_breakdown(&self) -> String {
        if self.deadline_misses == 0 && self.retirements == 0 && self.rejoins == 0 {
            return String::new();
        }
        format!(
            " (misses={} retired={} rejoined={})",
            self.deadline_misses, self.retirements, self.rejoins
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_export() {
        let mut t = TrainTrace::new("test");
        t.record(0, 10.0, 1.0, 100);
        t.record(10, 5.0, 0.5, 200);
        t.final_loss = 5.0;
        assert_eq!(t.total_bits(), 200);
        let dir = std::env::temp_dir().join("lad_trace_test");
        let p = dir.join("t.csv");
        t.save_csv(&p).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.starts_with("iter,loss,update_norm,bits\n"));
        assert_eq!(body.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_mentions_label() {
        let mut t = TrainTrace::new("lad-cwtm-d10");
        t.final_loss = 1.0;
        assert!(t.summary().contains("lad-cwtm-d10"));
    }

    #[test]
    fn summary_phase_percentages_only_when_spans_recorded() {
        let mut t = TrainTrace::new("net-run");
        t.final_loss = 1.0;
        assert!(!t.summary().contains("phases["), "central run grew a phase breakdown");
        t.broadcast_ns = 120;
        t.gather_ns = 700;
        t.aggregate_ns = 180;
        let s = t.summary();
        assert!(s.contains("phases[bcast 12% gather 70% agg 18%]"), "{s}");
    }

    #[test]
    fn summary_breaks_down_anomalies_when_elasticity_counters_set() {
        let mut t = TrainTrace::new("churn");
        t.final_loss = 1.0;
        t.anomalies = 4;
        assert!(!t.summary().contains("misses="), "breakdown without counters");
        t.deadline_misses = 3;
        t.retirements = 1;
        t.rejoins = 1;
        let s = t.summary();
        assert!(s.contains("anomalies=4 (misses=3 retired=1 rejoined=1)"), "{s}");
    }

    #[test]
    fn summary_reports_wire_bytes_only_for_net_runs() {
        let mut t = TrainTrace::new("central");
        t.final_loss = 1.0;
        assert!(!t.summary().contains("wire_up"));
        t.wire_up_bytes = 12_345;
        t.wire_down_bytes = 678;
        let s = t.summary();
        assert!(s.contains("wire_up") && s.contains("wire_down"), "{s}");
    }
}
