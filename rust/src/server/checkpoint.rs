//! Binary model checkpoints (dependency-free format).
//!
//! Layout (little-endian):
//! `magic "LADCKPT1" | iter u64 | seed u64 | len u64 | f32 × len | crc u64`
//! where crc is a simple FNV-1a over the payload bytes — enough to catch
//! truncation/corruption without pulling a hashing crate.

use crate::Result;
use anyhow::{bail, Context};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LADCKPT1";

/// A saved training state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub iter: u64,
    pub seed: u64,
    pub params: Vec<f32>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl Checkpoint {
    pub fn new(iter: u64, seed: u64, params: Vec<f32>) -> Self {
        Checkpoint { iter, seed, params }
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut payload = Vec::with_capacity(24 + 4 * self.params.len());
        payload.extend_from_slice(&self.iter.to_le_bytes());
        payload.extend_from_slice(&self.seed.to_le_bytes());
        payload.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for v in &self.params {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let crc = fnv1a(&payload);
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating checkpoint {:?}", path.as_ref()))?;
        f.write_all(MAGIC)?;
        f.write_all(&payload)?;
        f.write_all(&crc.to_le_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(&path)
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < 8 + 24 + 8 || &bytes[..8] != MAGIC {
            bail!("not a LAD checkpoint");
        }
        let payload = &bytes[8..bytes.len() - 8];
        let stored_crc = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        if fnv1a(payload) != stored_crc {
            bail!("checkpoint crc mismatch (corrupt or truncated)");
        }
        let u64_at = |off: usize| -> u64 {
            u64::from_le_bytes(payload[off..off + 8].try_into().unwrap())
        };
        let iter = u64_at(0);
        let seed = u64_at(8);
        let len = u64_at(16) as usize;
        if payload.len() != 24 + 4 * len {
            bail!("checkpoint length mismatch");
        }
        let params = payload[24..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Checkpoint { iter, seed, params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("lad_ckpt_test").join(name)
    }

    #[test]
    fn round_trip() {
        let ck = Checkpoint::new(42, 7, (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect());
        let p = tmp("rt.ckpt");
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn detects_corruption() {
        let ck = Checkpoint::new(1, 2, vec![1.0, 2.0, 3.0]);
        let p = tmp("corrupt.ckpt");
        ck.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err();
        assert!(format!("{err}").contains("crc"));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn detects_truncation() {
        let ck = Checkpoint::new(1, 2, vec![1.0; 64]);
        let p = tmp("trunc.ckpt");
        ck.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 10]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_foreign_files() {
        let p = tmp("foreign.bin");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, b"definitely not a checkpoint, sorry").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn empty_params_ok() {
        let ck = Checkpoint::new(0, 0, vec![]);
        let p = tmp("empty.ckpt");
        ck.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), ck);
        std::fs::remove_file(p).ok();
    }
}
