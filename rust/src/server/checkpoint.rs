//! Binary training checkpoints (dependency-free, versioned, sectioned).
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "LADCKPT" | version u8 (=2) | sections... | crc u64
//! section := tag u8 | body_len u64 | body
//! ```
//!
//! The trailing crc is FNV-1a over every byte between the version byte and
//! the crc itself, so truncation and bit-flips are caught before any
//! section is trusted. Unknown section tags and unknown versions are hard
//! errors — a checkpoint is resumed-from, never best-effort-parsed. The
//! legacy v1 format (`magic "LADCKPT1"`, fixed layout) shares the 7-byte
//! magic prefix; its trailing `'1'` reads as the version byte and is
//! rejected with a clear "format v1" message instead of a CRC or length
//! mismatch.
//!
//! Sections (tag → body):
//!
//! | tag | name        | body                                             |
//! |-----|-------------|--------------------------------------------------|
//! | 1   | core        | iter u64, seed u64, config digest u64, params (u64 len + f32s) |
//! | 2   | run-rng     | the leader run RNG cursor ([`RngState`])         |
//! | 3   | comp        | per-device compression streams: u64 n, n × (seed u64, [`RngState`]) |
//! | 4   | ef          | leader-side EF residual mirror: u64 n, u64 dim, n×dim f32 |
//! | 5   | momentum    | momentum-filter buffers: u64 n, u64 q, n×q f32   |
//! | 6   | roster      | u64 n, n × (dead u8, miss_streak u64, rejoin_epoch u64) |
//! | 7   | trace       | trace-so-far: label, samples, anomaly/byte counters |
//!
//! Only `core` is required. `save` is atomic (sibling `.tmp` +
//! `fs::rename`), so a leader killed mid-write leaves the previous
//! checkpoint intact — the property the failover drill relies on.

use crate::util::rng::RngState;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 7] = b"LADCKPT";
const VERSION: u8 = 2;

const SEC_CORE: u8 = 1;
const SEC_RUN_RNG: u8 = 2;
const SEC_COMP: u8 = 3;
const SEC_EF: u8 = 4;
const SEC_MOMENTUM: u8 = 5;
const SEC_ROSTER: u8 = 6;
const SEC_TRACE: u8 = 7;

/// One device's membership record in the [`Checkpoint::roster`] section.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RosterEntry {
    /// Slot retired (or never filled) at checkpoint time.
    pub dead: bool,
    /// Consecutive gather-deadline misses charged to this device.
    pub miss_streak: u64,
    /// How many times this slot has been re-admitted mid-run; salts the
    /// fresh compression seed a rejoining device is handed.
    pub rejoin_epoch: u64,
}

/// The semantic fields of a `TrainTrace` accumulated so far — everything a
/// warm restart must replay to finish with a trace bit-identical to the
/// uninterrupted run. Wall-clock telemetry (wall_s, phase ns) is
/// deliberately absent: timing is never part of trace equality.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TraceBlock {
    pub label: String,
    pub iters: Vec<u64>,
    pub loss: Vec<f64>,
    pub grad_update_norm: Vec<f64>,
    pub bits: Vec<u64>,
    pub anomalies: u64,
    /// Running analytic-bit accumulator (may be ahead of `bits.last()`
    /// when the last sample predates the checkpoint iteration).
    pub bits_total: u64,
    pub wire_up_bytes: u64,
    pub wire_down_bytes: u64,
}

/// A saved training state. `iter`/`seed`/`params` are the v1 trio (the
/// iterate and where it came from); the optional fields carry the live
/// leader state the elastic net path needs for bit-identical warm restart.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Next iteration to run (the checkpoint is cut *after* `iter - 1`).
    pub iter: u64,
    pub seed: u64,
    pub params: Vec<f32>,
    /// `net::wire::config_digest` of the run config; 0 when unknown.
    /// Resume refuses a checkpoint whose digest mismatches the config.
    pub digest: u64,
    /// Leader run-RNG cursor (assignment draws, attack crafting).
    pub run_rng: Option<RngState>,
    /// Per-device compression streams: the handshake seed plus the
    /// current cursor of the leader-side mirror.
    pub comp_streams: Option<Vec<(u64, RngState)>>,
    /// Leader-side error-feedback residual mirror, one row per device.
    pub ef_residuals: Option<Vec<Vec<f32>>>,
    /// Momentum-filter per-device buffers.
    pub momentum: Option<Vec<Vec<f32>>>,
    /// Per-device membership state.
    pub roster: Option<Vec<RosterEntry>>,
    /// Trace accumulated up to (excluding) `iter`.
    pub trace: Option<TraceBlock>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

// -- little-endian body writer/reader -----------------------------------

struct W(Vec<u8>);

impl W {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn rng(&mut self, st: &RngState) {
        self.u64(st.state);
        self.u64(st.inc);
        match st.spare_gauss {
            None => self.u8(0),
            Some(g) => {
                self.u8(1);
                self.f64(g);
            }
        }
    }
    /// Append one section: tag, body length, body.
    fn section(&mut self, tag: u8, body: W) {
        self.u8(tag);
        self.u64(body.0.len() as u64);
        self.0.extend_from_slice(&body.0);
    }
}

struct R<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.remaining() >= n, "checkpoint: short section ({} of {n} bytes)",
            self.remaining());
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// A count of `elem` -byte elements, validated against the remainder.
    fn count(&mut self, elem: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        ensure!(
            n.checked_mul(elem).is_some_and(|b| b <= self.remaining()),
            "checkpoint: implausible count {n}"
        );
        Ok(n)
    }
    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }
    fn rng(&mut self) -> Result<RngState> {
        let state = self.u64()?;
        let inc = self.u64()?;
        let spare_gauss = match self.u8()? {
            0 => None,
            1 => Some(self.f64()?),
            b => bail!("checkpoint: bad spare-gauss flag {b}"),
        };
        Ok(RngState { state, inc, spare_gauss })
    }
    fn done(self, what: &str) -> Result<()> {
        ensure!(self.remaining() == 0, "checkpoint: {} trailing bytes in {what} section",
            self.remaining());
        Ok(())
    }
}

impl Checkpoint {
    /// The v1-compatible constructor: iterate + provenance, no live state.
    pub fn new(iter: u64, seed: u64, params: Vec<f32>) -> Self {
        Checkpoint {
            iter,
            seed,
            params,
            digest: 0,
            run_rng: None,
            comp_streams: None,
            ef_residuals: None,
            momentum: None,
            roster: None,
            trace: None,
        }
    }

    /// Serialize and write atomically: the bytes land in a sibling `.tmp`
    /// file which is then renamed over `path`, so a crash mid-write never
    /// leaves a torn checkpoint where a good one used to be.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let body = self.encode_sections();
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating checkpoint {tmp:?}"))?;
            f.write_all(MAGIC)?;
            f.write_all(&[VERSION])?;
            f.write_all(&body)?;
            f.write_all(&fnv1a(&body).to_le_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
        Ok(())
    }

    fn encode_sections(&self) -> Vec<u8> {
        let mut out = W(Vec::with_capacity(64 + 4 * self.params.len()));
        let mut core = W(Vec::new());
        core.u64(self.iter);
        core.u64(self.seed);
        core.u64(self.digest);
        core.u64(self.params.len() as u64);
        for &v in &self.params {
            core.f32(v);
        }
        out.section(SEC_CORE, core);
        if let Some(st) = &self.run_rng {
            let mut w = W(Vec::new());
            w.rng(st);
            out.section(SEC_RUN_RNG, w);
        }
        if let Some(streams) = &self.comp_streams {
            let mut w = W(Vec::new());
            w.u64(streams.len() as u64);
            for (seed, st) in streams {
                w.u64(*seed);
                w.rng(st);
            }
            out.section(SEC_COMP, w);
        }
        if let Some(rows) = &self.ef_residuals {
            let dim = rows.first().map_or(0, |r| r.len());
            let mut w = W(Vec::new());
            w.u64(rows.len() as u64);
            w.u64(dim as u64);
            for row in rows {
                assert_eq!(row.len(), dim, "ragged EF residual rows");
                for &v in row {
                    w.f32(v);
                }
            }
            out.section(SEC_EF, w);
        }
        if let Some(rows) = &self.momentum {
            let q = rows.first().map_or(0, |r| r.len());
            let mut w = W(Vec::new());
            w.u64(rows.len() as u64);
            w.u64(q as u64);
            for row in rows {
                assert_eq!(row.len(), q, "ragged momentum rows");
                for &v in row {
                    w.f32(v);
                }
            }
            out.section(SEC_MOMENTUM, w);
        }
        if let Some(roster) = &self.roster {
            let mut w = W(Vec::new());
            w.u64(roster.len() as u64);
            for e in roster {
                w.u8(u8::from(e.dead));
                w.u64(e.miss_streak);
                w.u64(e.rejoin_epoch);
            }
            out.section(SEC_ROSTER, w);
        }
        if let Some(t) = &self.trace {
            let mut w = W(Vec::new());
            w.u64(t.label.len() as u64);
            w.0.extend_from_slice(t.label.as_bytes());
            let k = t.iters.len();
            assert!(
                t.loss.len() == k && t.grad_update_norm.len() == k && t.bits.len() == k,
                "ragged trace columns"
            );
            w.u64(k as u64);
            for i in 0..k {
                w.u64(t.iters[i]);
                w.f64(t.loss[i]);
                w.f64(t.grad_update_norm[i]);
                w.u64(t.bits[i]);
            }
            w.u64(t.anomalies);
            w.u64(t.bits_total);
            w.u64(t.wire_up_bytes);
            w.u64(t.wire_down_bytes);
            out.section(SEC_TRACE, w);
        }
        out.0
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(&path)
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < 8 || &bytes[..7] != MAGIC {
            bail!("not a LAD checkpoint");
        }
        match bytes[7] {
            VERSION => {}
            b'1' => bail!(
                "checkpoint format v1 is no longer supported (this build reads v{VERSION}); \
                 re-run training to produce a fresh checkpoint"
            ),
            v => bail!("unsupported checkpoint version {v} (this build reads v{VERSION})"),
        }
        ensure!(bytes.len() >= 8 + 8, "checkpoint crc mismatch (corrupt or truncated)");
        let body = &bytes[8..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        if fnv1a(body) != stored {
            bail!("checkpoint crc mismatch (corrupt or truncated)");
        }
        Self::decode_sections(body)
    }

    fn decode_sections(body: &[u8]) -> Result<Self> {
        let mut r = R { buf: body, pos: 0 };
        let mut ck: Option<Checkpoint> = None;
        let mut run_rng = None;
        let mut comp_streams = None;
        let mut ef_residuals = None;
        let mut momentum = None;
        let mut roster = None;
        let mut trace = None;
        while r.remaining() > 0 {
            let tag = r.u8()?;
            let len = r.u64()? as usize;
            let mut s = R { buf: r.take(len)?, pos: 0 };
            match tag {
                SEC_CORE => {
                    ensure!(ck.is_none(), "checkpoint: duplicate core section");
                    let iter = s.u64()?;
                    let seed = s.u64()?;
                    let digest = s.u64()?;
                    let n = s.count(4)?;
                    let params = s.f32_vec(n)?;
                    s.done("core")?;
                    let mut c = Checkpoint::new(iter, seed, params);
                    c.digest = digest;
                    ck = Some(c);
                }
                SEC_RUN_RNG => {
                    run_rng = Some(s.rng()?);
                    s.done("run-rng")?;
                }
                SEC_COMP => {
                    let n = s.count(25)?;
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        let seed = s.u64()?;
                        v.push((seed, s.rng()?));
                    }
                    s.done("comp")?;
                    comp_streams = Some(v);
                }
                SEC_EF | SEC_MOMENTUM => {
                    let n = s.count(8)?;
                    let dim = s.u64()? as usize;
                    ensure!(
                        n.checked_mul(dim).and_then(|c| c.checked_mul(4))
                            .is_some_and(|b| b <= s.remaining()),
                        "checkpoint: implausible {n}x{dim} float block"
                    );
                    let mut rows = Vec::with_capacity(n);
                    for _ in 0..n {
                        rows.push(s.f32_vec(dim)?);
                    }
                    s.done(if tag == SEC_EF { "ef" } else { "momentum" })?;
                    if tag == SEC_EF {
                        ef_residuals = Some(rows);
                    } else {
                        momentum = Some(rows);
                    }
                }
                SEC_ROSTER => {
                    let n = s.count(17)?;
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        let dead = match s.u8()? {
                            0 => false,
                            1 => true,
                            b => bail!("checkpoint: bad roster dead flag {b}"),
                        };
                        let miss_streak = s.u64()?;
                        let rejoin_epoch = s.u64()?;
                        v.push(RosterEntry { dead, miss_streak, rejoin_epoch });
                    }
                    s.done("roster")?;
                    roster = Some(v);
                }
                SEC_TRACE => {
                    let lab_len = s.count(1)?;
                    let label = String::from_utf8(s.take(lab_len)?.to_vec())
                        .context("checkpoint: trace label is not UTF-8")?;
                    let k = s.count(32)?;
                    let mut t = TraceBlock { label, ..Default::default() };
                    for _ in 0..k {
                        t.iters.push(s.u64()?);
                        t.loss.push(s.f64()?);
                        t.grad_update_norm.push(s.f64()?);
                        t.bits.push(s.u64()?);
                    }
                    t.anomalies = s.u64()?;
                    t.bits_total = s.u64()?;
                    t.wire_up_bytes = s.u64()?;
                    t.wire_down_bytes = s.u64()?;
                    s.done("trace")?;
                    trace = Some(t);
                }
                other => bail!("checkpoint: unknown section tag {other}"),
            }
        }
        let Some(mut ck) = ck else {
            bail!("checkpoint: missing core section");
        };
        ck.run_rng = run_rng;
        ck.comp_streams = comp_streams;
        ck.ef_residuals = ef_residuals;
        ck.momentum = momentum;
        ck.roster = roster;
        ck.trace = trace;
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("lad_ckpt_test").join(name)
    }

    fn full(name: &str) -> Checkpoint {
        let mut ck = Checkpoint::new(42, 7, (0..100).map(|i| i as f32 * 0.5 - 3.0).collect());
        ck.digest = 0xFEED_FACE_CAFE_BEEF;
        ck.run_rng = Some(RngState { state: 1, inc: 3, spare_gauss: Some(-0.25) });
        ck.comp_streams = Some(vec![
            (11, RngState { state: 5, inc: 7, spare_gauss: None }),
            (13, RngState { state: 9, inc: 11, spare_gauss: Some(1.5) }),
        ]);
        ck.ef_residuals = Some(vec![vec![0.5, -1.25, 3.0], vec![0.0, -0.0, f32::MIN_POSITIVE]]);
        ck.momentum = Some(vec![vec![1.0; 4], vec![-2.0; 4]]);
        ck.roster = Some(vec![
            RosterEntry { dead: false, miss_streak: 0, rejoin_epoch: 0 },
            RosterEntry { dead: true, miss_streak: 3, rejoin_epoch: 1 },
        ]);
        ck.trace = Some(TraceBlock {
            label: name.to_string(),
            iters: vec![0, 10],
            loss: vec![10.0, 5.0],
            grad_update_norm: vec![1.0, 0.5],
            bits: vec![100, 200],
            anomalies: 2,
            bits_total: 200,
            wire_up_bytes: 4321,
            wire_down_bytes: 8765,
        });
        ck
    }

    #[test]
    fn round_trip() {
        let ck = Checkpoint::new(42, 7, (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect());
        let p = tmp("rt.ckpt");
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn full_state_round_trips_bitwise() {
        // satellite: EF residual mirrors and momentum buffers survive
        // save/load bitwise, including a retired-then-rejoined device's
        // roster entry (dead=true, rejoin_epoch=1) alongside the zeroed
        // residual the rejoin path would leave behind
        let mut ck = full("elastic");
        ck.ef_residuals = Some(vec![vec![0.5, -1.25, 3.0], vec![0.0; 3]]);
        let p = tmp("full.ckpt");
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        let ef = back.ef_residuals.unwrap();
        assert_eq!(
            ef[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            [0.5f32, -1.25, 3.0].iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert!(ef[1].iter().all(|v| v.to_bits() == 0), "rejoined residual stays zeroed");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn detects_corruption() {
        let ck = full("corrupt");
        let p = tmp("corrupt.ckpt");
        ck.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err();
        assert!(format!("{err}").contains("crc"));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn detects_truncation() {
        let ck = Checkpoint::new(1, 2, vec![1.0; 64]);
        let p = tmp("trunc.ckpt");
        ck.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 10]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_foreign_files() {
        let p = tmp("foreign.bin");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, b"definitely not a checkpoint, sorry").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_v1_with_a_clear_message() {
        // a byte-accurate v1 checkpoint: magic "LADCKPT1", fixed layout
        let p = tmp("v1.ckpt");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u64.to_le_bytes()); // iter
        payload.extend_from_slice(&2u64.to_le_bytes()); // seed
        payload.extend_from_slice(&1u64.to_le_bytes()); // len
        payload.extend_from_slice(&1.0f32.to_le_bytes());
        let mut bytes = b"LADCKPT1".to_vec();
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("v1") && err.contains("no longer supported"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_unknown_versions_and_sections() {
        let p = tmp("vx.ckpt");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, b"LADCKPT\x09________").unwrap();
        let err = format!("{}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("version 9"), "{err}");
        // a valid frame around an unknown section tag is rejected too
        let mut body = W(Vec::new());
        body.section(99, W(vec![1, 2, 3]));
        let mut bytes = MAGIC.to_vec();
        bytes.push(VERSION);
        bytes.extend_from_slice(&body.0);
        bytes.extend_from_slice(&fnv1a(&body.0).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("unknown section tag 99"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn save_is_atomic_no_tmp_left_behind() {
        let ck = full("atomic");
        let p = tmp("atomic.ckpt");
        ck.save(&p).unwrap();
        // overwrite with new content: tmp sibling must not linger
        let mut ck2 = ck.clone();
        ck2.iter = 99;
        ck2.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap().iter, 99);
        let mut tmp_path = p.as_os_str().to_owned();
        tmp_path.push(".tmp");
        assert!(!std::path::Path::new(&tmp_path).exists());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn empty_params_ok() {
        let ck = Checkpoint::new(0, 0, vec![]);
        let p = tmp("empty.ckpt");
        ck.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), ck);
        std::fs::remove_file(p).ok();
    }
}
