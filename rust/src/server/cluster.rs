//! Threaded leader/worker cluster simulation.
//!
//! The fast trainer computes all device messages centrally (bit-identical,
//! see DESIGN.md); this module runs the *actual distributed topology*: one
//! worker thread per device and a leader exchanging the real wire protocol
//! (`net::wire` messages in CRC32 frames) over in-process channel
//! transports — the same [`crate::net::Leader`] / [`crate::net::run_worker`]
//! event loops that serve TCP and Unix-domain sockets in `lad node-leader`
//! / `lad node-worker`. Used by `examples/cluster_demo` and
//! `rust/tests/cluster_tests` to verify that the central fast path and the
//! message-passing path produce identical traces.
//!
//! Workers borrow the caller's dataset directly (scoped threads), so a
//! multi-variant sweep no longer clones the dataset per `run_cluster`
//! call the way the old `Arc::new(ds.clone())` plumbing did.

use crate::aggregation::Aggregator;
use crate::attack::Attack;
use crate::compress::Compressor;
use crate::config::TrainConfig;
use crate::data::linreg::LinRegDataset;
use crate::net::transport::{ChannelTransport, Transport};
use crate::net::worker::{run_worker_opts, WorkerOpts};
use crate::net::{Leader, LeaderOpts, Msg, RejoinRequest, MISS_RETIRE_STREAK};
use crate::server::checkpoint::Checkpoint;
use crate::server::metrics::TrainTrace;
use crate::util::parallel::Pool;
use crate::util::rng::Rng;
use crate::Result;
use std::path::Path;
use std::sync::mpsc;

/// Fault-injection options for [`run_cluster_with`] — the
/// partial-participation experiment knobs (sweep `stall_prob` ×
/// `gather_deadline_ms` axes).
#[derive(Debug, Clone, Default)]
pub struct ClusterOpts {
    /// Leader policy (gather deadline, compression site, join deadline).
    pub leader: LeaderOpts,
    /// Per-broadcast stall probability applied to every worker (each
    /// worker draws from its own pre-split stream, so stall patterns are
    /// deterministic and independent of thread scheduling). Requires a
    /// gather deadline — a deadline-less leader would wait forever.
    pub stall_prob: f64,
    /// Seed the per-worker stall streams are split from.
    pub stall_seed: u64,
}

/// Run Algorithm 1/2 over real threads + the wire protocol. Honest workers
/// compute their own coded vector from the shared dataset; Byzantine
/// crafting and compression happen on the leader (the historical
/// leader-side compression mode, trace-identical to `Trainer::run`).
///
/// Builds a private pool from `cfg.threads`; prefer [`run_cluster_in`]
/// when the caller already owns a (budgeted) pool, so the cluster
/// simulation respects a process-level thread budget instead of
/// multiplying workers per call.
pub fn run_cluster(
    cfg: &TrainConfig,
    ds: &LinRegDataset,
    agg: &dyn Aggregator,
    attack: &dyn Attack,
    comp: &dyn Compressor,
    x0: &mut Vec<f32>,
    label: &str,
    rng: &mut Rng,
) -> Result<TrainTrace> {
    run_cluster_in(cfg, ds, agg, attack, comp, x0, label, rng, &Pool::new(cfg.threads))
}

/// [`run_cluster`] with an explicit worker pool for the leader's
/// compression batch — pass a [`Pool::budgeted`] slice (see
/// `PoolBudget::inner_capped`) to bound total threads across concurrent
/// cluster runs. The pool only schedules; traces are bit-identical for
/// any pool width.
pub fn run_cluster_in(
    cfg: &TrainConfig,
    ds: &LinRegDataset,
    agg: &dyn Aggregator,
    attack: &dyn Attack,
    comp: &dyn Compressor,
    x0: &mut Vec<f32>,
    label: &str,
    rng: &mut Rng,
    pool: &Pool,
) -> Result<TrainTrace> {
    run_cluster_with(cfg, ds, agg, attack, comp, x0, label, rng, pool, &ClusterOpts::default())
}

/// [`run_cluster_in`] with fault injection: per-worker stall streams and
/// the leader's crash-tolerance knobs ([`ClusterOpts`]). This is the
/// engine behind the partial-participation sweep — a stalled upload
/// costs a gather-deadline miss, a long enough streak retires the device
/// (`net::MISS_RETIRE_STREAK`), and the trace's anomaly counter records
/// every miss. With a generous deadline the miss set is exactly the
/// (seeded, deterministic) stall set, so traces are reproducible.
pub fn run_cluster_with(
    cfg: &TrainConfig,
    ds: &LinRegDataset,
    agg: &dyn Aggregator,
    attack: &dyn Attack,
    comp: &dyn Compressor,
    x0: &mut Vec<f32>,
    label: &str,
    rng: &mut Rng,
    pool: &Pool,
    opts: &ClusterOpts,
) -> Result<TrainTrace> {
    cfg.validate()?;
    anyhow::ensure!(
        opts.stall_prob == 0.0 || opts.leader.gather_deadline.is_some(),
        "stalling workers need a gather deadline (the leader would wait forever)"
    );
    let n = cfg.n_devices;
    let stall_seeds = Rng::new(opts.stall_seed).split_seeds(n);
    std::thread::scope(|scope| {
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        for i in 0..n {
            let (leader_half, worker_half) = ChannelTransport::pair();
            links.push(Box::new(leader_half));
            let wopts = WorkerOpts {
                stall_prob: opts.stall_prob,
                stall_seed: stall_seeds[i],
                ..WorkerOpts::default()
            };
            scope.spawn(move || {
                // worker event loop: join, then answer every broadcast;
                // errors surface on the leader side as a lost connection
                let _ = run_worker_opts(Box::new(worker_half), i, Some(ds), None, &wopts);
            });
        }
        let leader = Leader {
            cfg,
            ds,
            agg,
            attack,
            comp,
            opts: opts.leader.clone(),
            pool: pool.clone(),
            send_dataset: false,
        };
        leader.run(links, x0, label, rng)
    })
}

/// The leader-kill / warm-restart drill as a single in-process harness:
/// run phase 1 with [`LeaderOpts::halt_after`] set to `kill_iter` (the
/// leader completes that iteration, writes a final [`Checkpoint`] to
/// `ckpt_path`, and dies *without* `Shutdown`), then load the checkpoint
/// and finish the run with a fresh leader + fresh worker threads via
/// [`Leader::resume`]. The returned trace — and the final iterate left
/// in `x0` — are bit-identical to an uninterrupted [`run_cluster_with`]
/// run (resume handshake bytes are not counted; pinned by
/// `tests/net_cluster.rs` and the warm-restart lattice in
/// `tests/fuzz_determinism.rs`).
///
/// Worker-side stall streams restart from scratch in phase 2, so this
/// harness rejects `stall_prob > 0` — compose churn via
/// [`run_cluster_churn`]'s deterministic `stall_after_iter` instead.
pub fn run_cluster_kill_resume(
    cfg: &TrainConfig,
    ds: &LinRegDataset,
    agg: &dyn Aggregator,
    attack: &dyn Attack,
    comp: &dyn Compressor,
    x0: &mut Vec<f32>,
    label: &str,
    rng: &mut Rng,
    pool: &Pool,
    opts: &ClusterOpts,
    kill_iter: u64,
    ckpt_path: &Path,
) -> Result<TrainTrace> {
    cfg.validate()?;
    anyhow::ensure!(
        kill_iter + 1 < cfg.iters as u64,
        "kill_iter {kill_iter} leaves no iterations to resume ({} total)",
        cfg.iters
    );
    anyhow::ensure!(
        opts.stall_prob == 0.0,
        "kill/resume is incompatible with stall_prob: restarted workers would \
         redraw their stall streams; use run_cluster_churn for churn"
    );
    let n = cfg.n_devices;

    // ---- phase 1: train to kill_iter, checkpoint, die without Shutdown ----
    let mut lopts = opts.leader.clone();
    lopts.checkpoint_path = Some(ckpt_path.to_path_buf());
    lopts.halt_after = Some(kill_iter);
    let phase1 = std::thread::scope(|scope| {
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        for i in 0..n {
            let (leader_half, worker_half) = ChannelTransport::pair();
            links.push(Box::new(leader_half));
            let wopts = WorkerOpts::default();
            scope.spawn(move || {
                // phase boundary: the halting leader drops its links, the
                // worker's recv errors out and the thread exits
                let _ = run_worker_opts(Box::new(worker_half), i, Some(ds), None, &wopts);
            });
        }
        let leader = Leader {
            cfg,
            ds,
            agg,
            attack,
            comp,
            opts: lopts,
            pool: pool.clone(),
            send_dataset: false,
        };
        leader.run(links, x0, label, rng)
    });
    match phase1 {
        Ok(_) => anyhow::bail!("leader survived past halt_after = {kill_iter}"),
        Err(e) if e.to_string().contains("halt-after drill") => {}
        Err(e) => return Err(e),
    }

    // ---- phase 2: warm restart from the checkpoint ----
    let ckpt = Checkpoint::load(ckpt_path)?;
    let mut lopts = opts.leader.clone();
    lopts.checkpoint_path = Some(ckpt_path.to_path_buf());
    lopts.halt_after = None;
    std::thread::scope(|scope| {
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        for i in 0..n {
            let (leader_half, worker_half) = ChannelTransport::pair();
            links.push(Box::new(leader_half));
            let wopts = WorkerOpts::default();
            scope.spawn(move || {
                let _ = run_worker_opts(Box::new(worker_half), i, Some(ds), None, &wopts);
            });
        }
        let leader = Leader {
            cfg,
            ds,
            agg,
            attack,
            comp,
            opts: lopts,
            pool: pool.clone(),
            send_dataset: false,
        };
        leader.resume(links, &ckpt, x0, label)
    })
}

/// When/who of a worker-churn drill (see [`run_cluster_churn`]).
#[derive(Debug, Clone, Copy)]
pub struct ChurnPlan {
    /// Device slot that goes silent and is later re-filled.
    pub victim: usize,
    /// First iteration the victim swallows (stops answering broadcasts).
    pub depart_iter: u64,
    /// Earliest iteration the replacement may be activated into the
    /// retired slot; must allow `net::MISS_RETIRE_STREAK` misses first.
    pub rejoin_iter: u64,
}

/// Worker-churn drill: device `plan.victim` goes silent at
/// `plan.depart_iter` (deterministic `stall_after_iter`, not a stall
/// stream), misses [`MISS_RETIRE_STREAK`] gathers, and is retired; a
/// replacement connection — pre-handshaked here exactly the way the
/// socket leader's accept loop does it — is activated into the slot at
/// `plan.rejoin_iter` with a fresh split compression-stream seed and a
/// zeroed EF residual. Incumbent devices' RNG streams are untouched, so
/// everything up to the departure is bit-identical to a no-churn run.
/// Requires a gather deadline (the silent victim would otherwise hang
/// the gather forever).
pub fn run_cluster_churn(
    cfg: &TrainConfig,
    ds: &LinRegDataset,
    agg: &dyn Aggregator,
    attack: &dyn Attack,
    comp: &dyn Compressor,
    x0: &mut Vec<f32>,
    label: &str,
    rng: &mut Rng,
    pool: &Pool,
    opts: &ClusterOpts,
    plan: ChurnPlan,
) -> Result<TrainTrace> {
    cfg.validate()?;
    let n = cfg.n_devices;
    anyhow::ensure!(plan.victim < n, "churn victim {} out of range (n = {n})", plan.victim);
    anyhow::ensure!(
        opts.leader.gather_deadline.is_some(),
        "worker churn needs a gather deadline (the silent victim would hang the leader)"
    );
    anyhow::ensure!(
        plan.rejoin_iter >= plan.depart_iter + MISS_RETIRE_STREAK as u64,
        "rejoin_iter {} is before the victim can be retired (depart {} + {} misses)",
        plan.rejoin_iter,
        plan.depart_iter,
        MISS_RETIRE_STREAK
    );
    let stall_seeds = Rng::new(opts.stall_seed).split_seeds(n);
    std::thread::scope(|scope| {
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        for i in 0..n {
            let (leader_half, worker_half) = ChannelTransport::pair();
            links.push(Box::new(leader_half));
            let wopts = WorkerOpts {
                stall_prob: opts.stall_prob,
                stall_seed: stall_seeds[i],
                stall_after_iter: (i == plan.victim).then_some(plan.depart_iter),
                ..WorkerOpts::default()
            };
            scope.spawn(move || {
                let _ = run_worker_opts(Box::new(worker_half), i, Some(ds), None, &wopts);
            });
        }
        // The replacement joins through the same channel the socket
        // leader's handshake threads feed: consume its Join here (what
        // `handshake_join` does on an accepted connection) and pre-load
        // the rejoin intake with the validated link + activation gate.
        let (rep_leader_half, rep_worker_half) = ChannelTransport::pair();
        let wdef = WorkerOpts::default();
        scope.spawn(move || {
            let _ = run_worker_opts(Box::new(rep_worker_half), plan.victim, Some(ds), None, &wdef);
        });
        let mut rep_link: Box<dyn Transport> = Box::new(rep_leader_half);
        let (msg, join_bytes) = rep_link.recv()?;
        match msg {
            Msg::Join { device, .. } if device as usize == plan.victim => {}
            other => anyhow::bail!("replacement sent {other:?}, expected Join as {}", plan.victim),
        }
        let (tx, rx) = mpsc::channel();
        tx.send(RejoinRequest {
            device: plan.victim,
            not_before: plan.rejoin_iter,
            join_bytes,
            link: rep_link,
        })
        .expect("rejoin intake receiver alive");
        drop(tx);
        let leader = Leader {
            cfg,
            ds,
            agg,
            attack,
            comp,
            opts: opts.leader.clone(),
            pool: pool.clone(),
            send_dataset: false,
        };
        leader.run_rejoin(links, Some(&rx), x0, label, rng)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::Cwtm;
    use crate::attack::SignFlip;
    use crate::compress::Identity;

    #[test]
    fn cluster_trains_under_attack() {
        let mut cfg = TrainConfig::default();
        cfg.n_devices = 12;
        cfg.n_honest = 9;
        cfg.d = 3;
        cfg.dim = 8;
        cfg.iters = 60;
        cfg.lr = 2e-5;
        cfg.log_every = 20;
        let mut rng = Rng::new(11);
        let ds = LinRegDataset::generate(12, 8, 0.2, &mut rng);
        let mut x0 = vec![0.0f32; 8];
        let l0 = ds.loss(&x0);
        let cwtm = Cwtm::new(0.2);
        let tr = run_cluster(
            &cfg,
            &ds,
            &cwtm,
            &SignFlip { coeff: -2.0 },
            &Identity,
            &mut x0,
            "cluster",
            &mut rng,
        )
        .unwrap();
        assert!(tr.final_loss < l0, "{} !< {l0}", tr.final_loss);
        // the in-process transport carries real frames: bytes are measured
        assert!(tr.wire_up_bytes > 0 && tr.wire_down_bytes > 0);
    }

    #[test]
    fn cluster_respects_a_shared_budgeted_pool() {
        let mut cfg = TrainConfig::default();
        cfg.n_devices = 8;
        cfg.n_honest = 6;
        cfg.d = 2;
        cfg.dim = 6;
        cfg.iters = 20;
        cfg.lr = 5e-5;
        cfg.log_every = 10;
        let mut rng = Rng::new(21);
        let ds = LinRegDataset::generate(8, 6, 0.2, &mut rng);
        let cwtm = Cwtm::new(0.2);
        let budget = Pool::budgeted(4, 2);
        let mut run = |pool: &Pool, seed: u64| {
            let mut x0 = vec![0.0f32; 6];
            let tr = run_cluster_in(
                &cfg,
                &ds,
                &cwtm,
                &SignFlip { coeff: -2.0 },
                &Identity,
                &mut x0,
                "budgeted",
                &mut Rng::new(seed),
                pool,
            )
            .unwrap();
            (tr, x0)
        };
        // a borrowed budget slice and a private pool give identical traces
        let (tr_a, x_a) = run(&budget.inner(), 31);
        let (tr_b, x_b) = run(&Pool::new(cfg.threads), 31);
        assert_eq!(x_a, x_b);
        assert_eq!(tr_a.loss, tr_b.loss);
    }
}
