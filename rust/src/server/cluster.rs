//! Threaded leader/worker cluster simulation.
//!
//! The fast trainer computes all device messages centrally (bit-identical,
//! see DESIGN.md); this module runs the *actual distributed topology*: one
//! worker thread per device, the leader broadcasting (x^t, task row,
//! permutation) over channels and collecting messages, exactly as Fig. 1 of
//! the paper. Used by `examples/cluster_demo` and `rust/tests/cluster_tests`
//! to verify that the central fast path and the message-passing path
//! produce identical traces.

use crate::aggregation::Aggregator;
use crate::attack::{Attack, AttackContext};
use crate::coding::{Assignment, TaskMatrix};
use crate::compress::{compress_batch, Compressor};
use crate::config::TrainConfig;
use crate::data::linreg::LinRegDataset;
use crate::server::metrics::TrainTrace;
use crate::util::math::norm;
use crate::util::parallel::Pool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use crate::Result;
use std::sync::mpsc;
use std::sync::Arc;

/// Message from leader to a worker: the broadcast of iteration t.
struct Broadcast {
    x: Arc<Vec<f32>>,
    /// subsets this worker must compute (already T/p-resolved)
    subsets: Vec<usize>,
}

/// Run Algorithm 1/2 over real threads + channels. Honest workers compute
/// their own coded vector from the shared dataset; Byzantine crafting and
/// compression happen device-side, aggregation happens on the leader.
pub fn run_cluster(
    cfg: &TrainConfig,
    ds: &LinRegDataset,
    agg: &dyn Aggregator,
    attack: &dyn Attack,
    comp: &dyn Compressor,
    x0: &mut Vec<f32>,
    label: &str,
    rng: &mut Rng,
) -> Result<TrainTrace> {
    cfg.validate()?;
    let timer = Timer::start();
    let n = cfg.n_devices;
    let ds = Arc::new(ds.clone());
    // Leader-side persistent pool for the compression step (the per-device
    // compute runs on the dedicated worker threads below).
    let pool = Pool::new(cfg.threads);
    // Same pre-split per-device compression streams as Trainer::run — the
    // cluster path must consume RNG identically to stay trace-identical
    // with the central fast path (cluster_tests.rs pins this).
    let mut comp_rngs = rng.split(n);
    let mut trace = TrainTrace::new(label);
    let s_hat = TaskMatrix::cyclic(n, cfg.d);
    let mut bits_total: u64 = 0;

    std::thread::scope(|scope| -> Result<()> {
        // per-worker channels
        let mut to_workers = Vec::with_capacity(n);
        let (result_tx, result_rx) = mpsc::channel::<(usize, Vec<f32>)>();
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Broadcast>();
            to_workers.push(tx);
            let ds = Arc::clone(&ds);
            let result_tx = result_tx.clone();
            scope.spawn(move || {
                // worker event loop: compute coded vector for each broadcast
                while let Ok(msg) = rx.recv() {
                    let mut coded = vec![0.0f32; ds.dim()];
                    for &k in &msg.subsets {
                        let g = ds.subset_grad(k, &msg.x);
                        crate::util::math::axpy(1.0, &g, &mut coded);
                    }
                    crate::util::math::scale(&mut coded, 1.0 / msg.subsets.len() as f32);
                    if result_tx.send((i, coded)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(result_tx);

        for t in 0..cfg.iters {
            let assign = Assignment::draw(n, rng);
            let x_arc = Arc::new(x0.clone());
            for i in 0..n {
                let subsets: Vec<usize> =
                    assign.subsets_for(s_hat.row(assign.tasks[i])).collect();
                to_workers[i]
                    .send(Broadcast { x: Arc::clone(&x_arc), subsets })
                    .map_err(|_| anyhow::anyhow!("worker {i} died"))?;
            }
            // gather
            let mut coded: Vec<Option<Vec<f32>>> = vec![None; n];
            for _ in 0..n {
                let (i, v) = result_rx.recv().map_err(|_| anyhow::anyhow!("gather failed"))?;
                coded[i] = Some(v);
            }
            let coded: Vec<Vec<f32>> = coded.into_iter().map(|v| v.unwrap()).collect();

            // fixed identities: last N−H byzantine (matches Trainer default)
            let honest: Vec<Vec<f32>> = coded[..cfg.n_honest].to_vec();
            let byz_true: Vec<Vec<f32>> = coded[cfg.n_honest..].to_vec();
            let lies = if byz_true.is_empty() {
                Vec::new()
            } else {
                let mut ctx = AttackContext { honest: &honest, own_true: &byz_true, rng };
                attack.craft(&mut ctx)
            };
            // leader-side compression, one pre-split stream per device
            let all: Vec<&[f32]> = honest
                .iter()
                .map(|m| m.as_slice())
                .chain(lies.iter().map(|m| m.as_slice()))
                .collect();
            let (msgs, bits) = compress_batch(comp, &all, &mut comp_rngs, &pool);
            bits_total += bits;
            let update = agg.aggregate(&msgs);
            for (xi, ui) in x0.iter_mut().zip(&update) {
                *xi -= cfg.lr as f32 * ui;
            }
            if (cfg.log_every > 0 && t % cfg.log_every == 0) || t + 1 == cfg.iters {
                trace.record(t, ds.loss(x0), norm(&update), bits_total);
            }
        }
        // closing the senders terminates the workers
        drop(to_workers);
        Ok(())
    })?;

    trace.final_loss = ds.loss(x0);
    trace.wall_s = timer.elapsed_s();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::Cwtm;
    use crate::attack::SignFlip;
    use crate::compress::Identity;

    #[test]
    fn cluster_trains_under_attack() {
        let mut cfg = TrainConfig::default();
        cfg.n_devices = 12;
        cfg.n_honest = 9;
        cfg.d = 3;
        cfg.dim = 8;
        cfg.iters = 60;
        cfg.lr = 2e-5;
        cfg.log_every = 20;
        let mut rng = Rng::new(11);
        let ds = LinRegDataset::generate(12, 8, 0.2, &mut rng);
        let mut x0 = vec![0.0f32; 8];
        let l0 = ds.loss(&x0);
        let cwtm = Cwtm::new(0.2);
        let tr = run_cluster(
            &cfg,
            &ds,
            &cwtm,
            &SignFlip { coeff: -2.0 },
            &Identity,
            &mut x0,
            "cluster",
            &mut rng,
        )
        .unwrap();
        assert!(tr.final_loss < l0, "{} !< {l0}", tr.final_loss);
    }
}
