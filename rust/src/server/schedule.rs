//! Learning-rate schedules.
//!
//! The paper's analysis fixes γ^t = γ⁰ (Theorems 1–2); this module adds the
//! standard schedules as an *extension* (the paper's "diminishing step"
//! remark): constant, step decay, 1/√(1+t/τ) and cosine. The trainer takes
//! an optional schedule; `None` reproduces the paper exactly.

/// γ^t as a function of the iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// γ^t = γ⁰ (the paper's setting).
    Constant { gamma0: f64 },
    /// γ^t = γ⁰ · factor^⌊t/every⌋.
    Step { gamma0: f64, factor: f64, every: usize },
    /// γ^t = γ⁰ / √(1 + t/τ) — the classic diminishing rate that makes the
    /// stochastic term of Theorem 1 vanish as T → ∞.
    InvSqrt { gamma0: f64, tau: f64 },
    /// Cosine decay from γ⁰ to `floor` over `total` iterations.
    Cosine { gamma0: f64, floor: f64, total: usize },
}

impl Schedule {
    pub fn at(&self, t: usize) -> f64 {
        match *self {
            Schedule::Constant { gamma0 } => gamma0,
            Schedule::Step { gamma0, factor, every } => {
                gamma0 * factor.powi((t / every.max(1)) as i32)
            }
            Schedule::InvSqrt { gamma0, tau } => {
                gamma0 / (1.0 + t as f64 / tau.max(1e-12)).sqrt()
            }
            Schedule::Cosine { gamma0, floor, total } => {
                let p = (t as f64 / total.max(1) as f64).min(1.0);
                floor + 0.5 * (gamma0 - floor) * (1.0 + (std::f64::consts::PI * p).cos())
            }
        }
    }

    /// Parse "constant", "step:0.5:100", "invsqrt:200", "cosine:1e-7:3000".
    pub fn parse(spec: &str, gamma0: f64) -> crate::Result<Self> {
        let parts: Vec<&str> = spec.split(':').collect();
        Ok(match parts[0] {
            "constant" => Schedule::Constant { gamma0 },
            "step" => Schedule::Step {
                gamma0,
                factor: parts.get(1).map_or(Ok(0.5), |s| s.parse()).map_err(bad(spec))?,
                every: parts.get(2).map_or(Ok(1000), |s| s.parse()).map_err(bad(spec))?,
            },
            "invsqrt" => Schedule::InvSqrt {
                gamma0,
                tau: parts.get(1).map_or(Ok(100.0), |s| s.parse()).map_err(bad(spec))?,
            },
            "cosine" => Schedule::Cosine {
                gamma0,
                floor: parts.get(1).map_or(Ok(0.0), |s| s.parse()).map_err(bad(spec))?,
                total: parts.get(2).map_or(Ok(1000), |s| s.parse()).map_err(bad(spec))?,
            },
            other => anyhow::bail!("unknown schedule {other:?}"),
        })
    }
}

fn bad<E: std::fmt::Display>(spec: &str) -> impl Fn(E) -> anyhow::Error + '_ {
    move |e| anyhow::anyhow!("bad schedule spec {spec:?}: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { gamma0: 3e-5 };
        assert_eq!(s.at(0), 3e-5);
        assert_eq!(s.at(10_000), 3e-5);
    }

    #[test]
    fn step_halves() {
        let s = Schedule::Step { gamma0: 1.0, factor: 0.5, every: 100 };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(99), 1.0);
        assert_eq!(s.at(100), 0.5);
        assert_eq!(s.at(250), 0.25);
    }

    #[test]
    fn invsqrt_decays_monotonically() {
        let s = Schedule::InvSqrt { gamma0: 1.0, tau: 50.0 };
        let mut prev = f64::INFINITY;
        for t in [0usize, 10, 100, 1000, 10_000] {
            let g = s.at(t);
            assert!(g < prev && g > 0.0);
            prev = g;
        }
        assert!((s.at(50) - 1.0 / 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cosine_hits_endpoints() {
        let s = Schedule::Cosine { gamma0: 1.0, floor: 0.1, total: 100 };
        assert!((s.at(0) - 1.0).abs() < 1e-12);
        assert!((s.at(100) - 0.1).abs() < 1e-12);
        assert!((s.at(200) - 0.1).abs() < 1e-12); // clamped
        assert!(s.at(50) > 0.1 && s.at(50) < 1.0);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            Schedule::parse("constant", 2.0).unwrap(),
            Schedule::Constant { gamma0: 2.0 }
        );
        assert_eq!(
            Schedule::parse("step:0.1:500", 1.0).unwrap(),
            Schedule::Step { gamma0: 1.0, factor: 0.1, every: 500 }
        );
        assert!(matches!(
            Schedule::parse("invsqrt:77", 1.0).unwrap(),
            Schedule::InvSqrt { tau, .. } if (tau - 77.0).abs() < 1e-12
        ));
        assert!(Schedule::parse("warp-drive", 1.0).is_err());
    }
}
