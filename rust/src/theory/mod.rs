//! Closed-form quantities from the convergence analysis (§VI) — used by the
//! Fig. 2/3 reproductions and by theory-vs-experiment tests.
//!
//! All formulas follow eqs. (21)–(36). Com-LAD constants κ₁..κ₄ depend on
//! (N, H, d, δ, β); LAD's ξ₁..ξ₄ are the δ = 0 special case.

/// System parameters entering the bounds.
#[derive(Debug, Clone, Copy)]
pub struct TheoryParams {
    pub n: f64,
    pub h: f64,
    pub d: f64,
    /// compression error constant δ (eq. 10); 0 for LAD
    pub delta: f64,
    /// heterogeneity bound β (Assumption 2)
    pub beta: f64,
    /// robustness coefficient κ (Definition 1)
    pub kappa: f64,
    /// smoothness constant L (Assumption 1)
    pub l_smooth: f64,
    /// fixed learning rate γ⁰
    pub gamma0: f64,
}

impl TheoryParams {
    pub fn new(n: usize, h: usize, d: usize) -> Self {
        TheoryParams {
            n: n as f64,
            h: h as f64,
            d: d as f64,
            delta: 0.0,
            beta: 1.0,
            kappa: 1.5,
            l_smooth: 1.0,
            gamma0: 1e-6,
        }
    }
    pub fn with_delta(mut self, delta: f64) -> Self {
        self.delta = delta;
        self
    }
    pub fn with_beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }
    pub fn with_kappa(mut self, kappa: f64) -> Self {
        self.kappa = kappa;
        self
    }

    /// (N−H)(N−d) / (dH(N−1)N) — the Lemma-1 infimum.
    pub fn lemma1(&self) -> f64 {
        let TheoryParams { n, h, d, .. } = *self;
        (n - h) * (n - d) / (d * h * (n - 1.0) * n)
    }

    /// κ₁ (eq. 21).
    pub fn kappa1(&self) -> f64 {
        let TheoryParams { n, h, d, delta, beta, .. } = *self;
        n * beta * beta * ((1.0 / h + 1.0) * 4.0 * delta / d)
            + 4.0 * beta * beta * (n - d) * n / (d * h * (n - 1.0))
    }

    /// κ₂ (eq. 22).
    pub fn kappa2(&self) -> f64 {
        let TheoryParams { n, h, d, delta, .. } = *self;
        ((1.0 / h + 1.0) * 4.0 * delta / d
            + 4.0 * (n - h) * (n - d) / (d * h * (n - 1.0) * n))
            / n
    }

    /// κ₃ (eq. 24).
    pub fn kappa3(&self) -> f64 {
        let TheoryParams { n, h, d, delta, beta, .. } = *self;
        (4.0 * delta / (h * d) + 4.0 * (n - h) * (n - d) / (d * h * (n - 1.0) * n))
            * n
            * beta
            * beta
    }

    /// κ₄ (eq. 25).
    pub fn kappa4(&self) -> f64 {
        let TheoryParams { n, h, d, delta, .. } = *self;
        2.0 / (n * n)
            + 4.0 * delta / (h * d * n)
            + 4.0 * (n - h) * (n - d) / (d * h * (n - 1.0) * n * n)
    }

    /// ξ₁..ξ₄ (eqs. 28–31) — the δ=0 LAD constants.
    pub fn xi(&self) -> (f64, f64, f64, f64) {
        let z = TheoryParams { delta: 0.0, ..*self };
        let TheoryParams { n, h, d, beta, .. } = z;
        let xi1 = 4.0 * beta * beta * (n - d) * n / (d * h * (n - 1.0));
        let xi2 = 4.0 * (n - h) * (n - d) / (d * h * (n - 1.0) * n) / n;
        let xi3 = 8.0 * (n - h) * (n - d) / (d * h * (n - 1.0)) * beta * beta;
        let xi4 = 2.0 / (n * n) + 8.0 * (n - h) * (n - d) / (d * h * (n - 1.0) * n * n);
        (xi1, xi2, xi3, xi4)
    }

    /// Convergence condition √(κκ₂) < 1/N (Theorem 1).
    pub fn converges(&self) -> bool {
        (self.kappa * self.kappa2()).sqrt() < 1.0 / self.n
    }

    /// Learning-rate ceiling γ⁰ < (1/N − √(κκ₂)) / (Lκκ₂ + Lκ₄).
    pub fn gamma_max(&self) -> f64 {
        let k2 = self.kappa2();
        let k4 = self.kappa4();
        (1.0 / self.n - (self.kappa * k2).sqrt())
            / (self.l_smooth * self.kappa * k2 + self.l_smooth * k4)
    }

    /// Exact Com-LAD error term ε (eq. 32), using the configured γ⁰.
    pub fn error_term_exact(&self) -> f64 {
        let (k1, k2, k3, k4) =
            (self.kappa1(), self.kappa2(), self.kappa3(), self.kappa4());
        let kappa = self.kappa;
        let num = k1 * kappa.sqrt() / (2.0 * k2.sqrt())
            + self.gamma0 * (self.l_smooth * kappa * k1 + self.l_smooth * k3);
        let den = (1.0 / self.n - (kappa * k2).sqrt())
            - self.gamma0 * (self.l_smooth * kappa * k2 + self.l_smooth * k4);
        num / den
    }

    /// Big-O error term (eq. 33): κ₁√κ / √κ₂ — the quantity plotted in
    /// Figs. 2 and 3.
    pub fn error_term_bigo(&self) -> f64 {
        self.kappa1() * self.kappa.sqrt() / self.kappa2().sqrt()
    }

    /// Big-O error term under error-feedback compression: eq. (33)
    /// re-evaluated at the EF-attenuated constant δ_EF = δ²/(1+δ).
    ///
    /// Not a bound from the source paper. Error feedback (Rammal et al.,
    /// arXiv 2310.09804; the same memory mechanism underlying the
    /// momentum-filter analysis of arXiv 2409.08640) carries each round's
    /// compression error into the next round's input instead of discarding
    /// it, so the asymptotic penalty of a δ-approximate compressor enters
    /// at order δ² rather than δ. This helper plots that attenuation on
    /// the paper's own ε axis for the `ef-vs-coding` sweep: it coincides
    /// with [`Self::error_term_bigo`] at δ = 0 and never exceeds it
    /// (δ²/(1+δ) ≤ δ for all δ ≥ 0).
    pub fn error_term_ef_bigo(&self) -> f64 {
        let delta_ef = self.delta * self.delta / (1.0 + self.delta);
        TheoryParams { delta: delta_ef, ..*self }.error_term_bigo()
    }

    /// LAD big-O error term (eq. 35): β²√(κ(N−d)N / (dH(N−H))).
    pub fn error_term_lad_bigo(&self) -> f64 {
        let TheoryParams { n, h, d, beta, kappa, .. } = *self;
        beta * beta * (kappa * (n - d) * n / (d * h * (n - h))).sqrt()
    }

    /// Baseline (robust aggregation alone, [23], eq. 36): O(β²κ).
    pub fn error_term_baseline(&self) -> f64 {
        self.beta * self.beta * self.kappa
    }

    /// Threshold d above which LAD beats the baseline:
    /// d ≥ N² / (κH(N−H) + N)  (from comparing (35) and (36)).
    pub fn d_crossover(&self) -> f64 {
        let TheoryParams { n, h, kappa, .. } = *self;
        n * n / (kappa * h * (n - h) + n)
    }

    /// Evaluate the full Theorem-1 bound on (1/T)Σ E‖∇F‖² after T iters,
    /// given F(x⁰) − F*.
    pub fn bound_after(&self, t: usize, f0_minus_fstar: f64) -> f64 {
        let k2 = self.kappa2();
        let k4 = self.kappa4();
        let den = self.gamma0 * (1.0 / self.n - (self.kappa * k2).sqrt())
            - self.gamma0 * self.gamma0 * (self.l_smooth * self.kappa * k2 + self.l_smooth * k4);
        f0_minus_fstar / (t as f64 * den) + self.error_term_exact()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig_params() -> TheoryParams {
        // Fig. 2/3 setting: N=100, H=65, κ=1.5, β=1
        TheoryParams::new(100, 65, 5).with_kappa(1.5).with_beta(1.0)
    }

    #[test]
    fn lemma1_matches_coding_module() {
        let p = fig_params();
        let want = crate::coding::task_matrix::lemma1_infimum(100, 65, 5);
        assert!((p.lemma1() - want).abs() < 1e-15);
    }

    #[test]
    fn xi_equals_kappa_at_delta_zero() {
        let p = fig_params().with_delta(0.0);
        let (x1, x2, _x3, _x4) = p.xi();
        assert!((p.kappa1() - x1).abs() < 1e-12);
        assert!((p.kappa2() - x2).abs() < 1e-12);
        // κ₃|δ=0 = 4(N−H)(N−d)/(dH(N−1)N)·Nβ² vs ξ₃ = 8(N−H)(N−d)/(dH(N−1))β²
        // differ by design (Theorem 2 folds constants); both positive:
        assert!(p.kappa3() > 0.0 && _x3 > 0.0);
    }

    #[test]
    fn error_decreases_with_d() {
        // Fig. 3's shape: ε shrinks as d grows
        let mut prev = f64::INFINITY;
        for d in [2usize, 5, 10, 20, 50, 99] {
            let p = TheoryParams::new(100, 65, d)
                .with_kappa(1.5)
                .with_beta(1.0)
                .with_delta(0.5);
            let e = p.error_term_bigo();
            assert!(e < prev, "d={d}: {e} !< {prev}");
            prev = e;
        }
    }

    #[test]
    fn error_increases_with_delta() {
        // Fig. 2's shape: ε grows with δ
        let mut prev = 0.0;
        for delta in [0.0, 0.25, 0.5, 1.0, 2.0] {
            let p = fig_params().with_delta(delta);
            let e = p.error_term_bigo();
            assert!(e >= prev, "δ={delta}: {e} < {prev}");
            prev = e;
        }
    }

    #[test]
    fn ef_error_term_attenuates_the_compression_penalty() {
        // δ = 0: EF is a no-op on the bound
        let p0 = fig_params().with_delta(0.0);
        assert!((p0.error_term_ef_bigo() - p0.error_term_bigo()).abs() < 1e-12);
        // δ > 0: the EF term never exceeds the plain term, stays monotone
        let mut prev = 0.0;
        for delta in [0.25, 0.5, 1.0, 2.0] {
            let p = fig_params().with_delta(delta);
            let ef = p.error_term_ef_bigo();
            assert!(ef <= p.error_term_bigo(), "δ={delta}: EF term above plain");
            assert!(ef >= prev, "δ={delta}: not monotone");
            prev = ef;
        }
    }

    #[test]
    fn lad_error_vanishes_at_d_equals_n() {
        let p = TheoryParams::new(100, 65, 100).with_kappa(1.5);
        assert!(p.error_term_lad_bigo() < 1e-12);
    }

    #[test]
    fn crossover_matches_paper_example() {
        // paper: N=100, H=65, κ=1.5 => LAD wins for d ≥ 3
        let p = fig_params();
        let c = p.d_crossover();
        assert!(c > 2.0 && c <= 3.0, "crossover {c}");
    }

    #[test]
    fn convergence_condition_sane() {
        // larger d should help the condition hold
        let bad = TheoryParams::new(100, 55, 1).with_kappa(5.0).with_delta(3.0);
        let good = TheoryParams::new(100, 80, 50).with_kappa(0.5);
        assert!(good.converges());
        assert!(good.gamma_max() > 0.0);
        // the bad config may or may not converge but must not panic
        let _ = bad.converges();
    }

    #[test]
    fn bound_shrinks_with_t() {
        // need a setting satisfying √(κκ₂) < 1/N: large d, tiny δ
        let p = TheoryParams::new(100, 80, 50).with_kappa(1.5).with_delta(0.01);
        let p = TheoryParams { gamma0: p.gamma_max() * 0.5, ..p };
        assert!(p.converges());
        let b10 = p.bound_after(10, 100.0);
        let b1000 = p.bound_after(1000, 100.0);
        assert!(b1000 < b10);
        assert!(b1000 >= p.error_term_exact() * 0.99);
    }
}
