//! Live leader status endpoint.
//!
//! A read-only status server built on the same [`NetListener`]
//! machinery the training wire uses (`tcp://HOST:PORT` or `uds:PATH`),
//! speaking two modes distinguished by the first line a client sends:
//!
//! * **snapshot** (default): the client sends nothing; after a short
//!   handshake window the server writes one pretty-printed JSON
//!   snapshot and closes. No request parsing, no framing, so
//!   `nc 127.0.0.1 PORT` (or `nc -U leader.status`) is a complete
//!   client. The snapshot carries the run label, current iteration,
//!   per-phase ns totals, the roster with per-device miss streaks /
//!   epochs / liveness, and a full metrics registry dump.
//! * **watch**: the client sends a single `WATCH\n` line; the server
//!   keeps the connection open and pushes one compact JSON delta line
//!   (the snapshot minus the metrics dump) whenever the run state
//!   changes, until the run ends or the client disconnects. This is
//!   what `lad status --watch` speaks (see [`crate::obs::watch`]).
//!
//! The endpoint is pull-only telemetry either way: it shares no locks
//! with the RNG, wire, or checkpoint paths, so polling or subscribing
//! cannot perturb a run's trace (pinned by the recorder-parity fuzz
//! leg).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::net::transport::{NetListener, Transport};
use crate::obs::metrics::Metrics;
use crate::util::json::Json;

/// Per-device roster entry mirrored for the status snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceStatus {
    /// Retired (deadline-miss streak or dead link).
    pub dead: bool,
    /// Consecutive gather deadline misses.
    pub miss_streak: u64,
    /// Connection epoch (bumps when a replacement joins the slot).
    pub epoch: u64,
}

#[derive(Default)]
struct StatusInner {
    label: String,
    phase: String,
    iter: u64,
    total_iters: u64,
    anomalies: u64,
    broadcast_ns: u64,
    gather_ns: u64,
    aggregate_ns: u64,
    roster: Vec<DeviceStatus>,
}

/// Shared mutable state behind the endpoint: the leader updates it
/// once per phase / roster change, the server thread reads it per
/// request.
pub struct StatusState {
    inner: Mutex<StatusInner>,
    metrics: Arc<Metrics>,
}

impl StatusState {
    pub fn new(metrics: Arc<Metrics>) -> StatusState {
        StatusState { inner: Mutex::new(StatusInner::default()), metrics }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, StatusInner> {
        self.inner.lock().expect("status state poisoned")
    }

    /// Reset for a fresh run: label, planned iterations, roster size.
    pub fn begin_run(&self, label: &str, total_iters: u64, n_devices: usize) {
        let mut s = self.lock();
        *s = StatusInner::default();
        s.label = label.to_string();
        s.total_iters = total_iters;
        s.roster = vec![DeviceStatus::default(); n_devices];
    }

    pub fn set_iter(&self, iter: u64) {
        self.lock().iter = iter;
    }

    pub fn set_phase(&self, phase: &str) {
        let mut s = self.lock();
        s.phase.clear();
        s.phase.push_str(phase);
    }

    pub fn add_phase_ns(&self, broadcast: u64, gather: u64, aggregate: u64) {
        let mut s = self.lock();
        s.broadcast_ns += broadcast;
        s.gather_ns += gather;
        s.aggregate_ns += aggregate;
    }

    pub fn add_anomalies(&self, n: u64) {
        self.lock().anomalies += n;
    }

    /// Seed a slot's full status at once (warm-restart roster import).
    pub fn set_device(&self, device: usize, status: DeviceStatus) {
        let mut s = self.lock();
        if let Some(d) = s.roster.get_mut(device) {
            *d = status;
        }
    }

    pub fn device_miss(&self, device: usize, streak: u64) {
        let mut s = self.lock();
        if let Some(d) = s.roster.get_mut(device) {
            d.miss_streak = streak;
        }
    }

    pub fn device_answered(&self, device: usize) {
        let mut s = self.lock();
        if let Some(d) = s.roster.get_mut(device) {
            d.miss_streak = 0;
        }
    }

    pub fn device_retired(&self, device: usize) {
        let mut s = self.lock();
        if let Some(d) = s.roster.get_mut(device) {
            d.dead = true;
        }
    }

    pub fn device_rejoined(&self, device: usize, epoch: u64) {
        let mut s = self.lock();
        if let Some(d) = s.roster.get_mut(device) {
            d.dead = false;
            d.miss_streak = 0;
            d.epoch = epoch;
        }
    }

    /// One self-contained snapshot object (run state + roster +
    /// metrics dump).
    pub fn snapshot_json(&self) -> Json {
        let mut top = match self.delta_json() {
            Json::Obj(o) => o,
            _ => unreachable!("delta_json returns an object"),
        };
        top.insert("metrics".to_string(), self.metrics.snapshot());
        Json::Obj(top)
    }

    /// The run-state object without the metrics dump — the per-change
    /// payload of the `WATCH` subscribe mode, compact enough to push
    /// every iteration.
    pub fn delta_json(&self) -> Json {
        use std::collections::BTreeMap;
        let (label, phase, iter, total, anomalies, bns, gns, ans, roster) = {
            let s = self.lock();
            (
                s.label.clone(),
                s.phase.clone(),
                s.iter,
                s.total_iters,
                s.anomalies,
                s.broadcast_ns,
                s.gather_ns,
                s.aggregate_ns,
                s.roster.clone(),
            )
        };
        let mut top = BTreeMap::new();
        top.insert("label".to_string(), Json::Str(label));
        top.insert("phase".to_string(), Json::Str(phase));
        top.insert("iter".to_string(), Json::Num(iter as f64));
        top.insert("total_iters".to_string(), Json::Num(total as f64));
        top.insert("anomalies".to_string(), Json::Num(anomalies as f64));
        let mut phases = BTreeMap::new();
        phases.insert("broadcast_ns".to_string(), Json::Num(bns as f64));
        phases.insert("gather_ns".to_string(), Json::Num(gns as f64));
        phases.insert("aggregate_ns".to_string(), Json::Num(ans as f64));
        top.insert("phase_ns".to_string(), Json::Obj(phases));
        let devices = roster
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let mut o = BTreeMap::new();
                o.insert("device".to_string(), Json::Num(i as f64));
                o.insert("dead".to_string(), Json::Bool(d.dead));
                o.insert("miss_streak".to_string(), Json::Num(d.miss_streak as f64));
                o.insert("epoch".to_string(), Json::Num(d.epoch as f64));
                Json::Obj(o)
            })
            .collect();
        top.insert("roster".to_string(), Json::Arr(devices));
        Json::Obj(top)
    }
}

/// Polling interval of the acceptor thread between empty
/// `try_accept`s; also the delta-push cadence for `WATCH` subscribers.
const POLL_INTERVAL: Duration = Duration::from_millis(5);

/// How long an accepted connection gets to send its `WATCH` line
/// before the server falls back to the one-shot snapshot (the bare-nc
/// path sends nothing and just waits to read).
const WATCH_HANDSHAKE_TIMEOUT: Duration = Duration::from_millis(25);

/// Read one newline-terminated request line (≤ 64 bytes) within the
/// handshake window. `None` on timeout, EOF, or an overlong line —
/// all of which mean "serve the snapshot".
fn read_request_line(conn: &mut dyn Transport) -> Option<String> {
    let _ = conn.set_recv_timeout(Some(WATCH_HANDSHAKE_TIMEOUT));
    let mut buf = [0u8; 64];
    let mut len = 0;
    while !buf[..len].contains(&b'\n') {
        if len == buf.len() {
            return None;
        }
        match conn.recv_raw(&mut buf[len..]) {
            Ok(0) | Err(_) => return None,
            Ok(n) => len += n,
        }
    }
    let nl = buf[..len].iter().position(|&b| b == b'\n').expect("loop exit implies newline");
    Some(String::from_utf8_lossy(&buf[..nl]).trim().to_string())
}

/// Background acceptor serving [`StatusState`]. Snapshot connections
/// are accept → write → close; `WATCH` subscribers stay registered and
/// get a compact delta line pushed on every state change. Stop (or
/// drop) to shut the thread down (subscriber connections close with
/// it).
pub struct StatusServer {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    addr: String,
}

impl StatusServer {
    /// Spawn the acceptor on an already-bound listener (use port 0 +
    /// [`StatusServer::addr`] to serve on an ephemeral port).
    pub fn spawn(listener: NetListener, state: Arc<StatusState>) -> Result<StatusServer> {
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("lad-status".to_string())
            .spawn(move || {
                // (connection, last delta line pushed) per subscriber
                let mut subs: Vec<(Box<dyn Transport>, String)> = Vec::new();
                while !stop_flag.load(Ordering::Relaxed) {
                    match listener.try_accept() {
                        Ok(Some(mut conn)) => {
                            let watch = read_request_line(conn.as_mut())
                                .is_some_and(|l| l == "WATCH");
                            if watch {
                                let mut line = state.delta_json().to_string();
                                line.push('\n');
                                if conn.send_frame(line.as_bytes()).is_ok() {
                                    subs.push((conn, line));
                                }
                            } else {
                                let mut body = state.snapshot_json().to_pretty_string();
                                body.push('\n');
                                // Raw bytes, no wire framing: any
                                // TCP/UDS client (nc, curl
                                // --unix-socket) can read the snapshot
                                // until EOF.
                                let _ = conn.send_frame(body.as_bytes());
                            }
                        }
                        Ok(None) | Err(_) => {}
                    }
                    if !subs.is_empty() {
                        let mut line = state.delta_json().to_string();
                        line.push('\n');
                        // push only on change; drop subscribers whose
                        // socket errors (disconnected watcher)
                        subs.retain_mut(|(conn, last)| {
                            if *last == line {
                                return true;
                            }
                            match conn.send_frame(line.as_bytes()) {
                                Ok(_) => {
                                    last.clear();
                                    last.push_str(&line);
                                    true
                                }
                                Err(_) => false,
                            }
                        });
                    }
                    std::thread::sleep(POLL_INTERVAL);
                }
            })
            .expect("spawning status server thread");
        Ok(StatusServer { stop, handle: Some(handle), addr })
    }

    /// The bound address in connectable form (`tcp://ip:port` /
    /// `uds:path`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Signal the acceptor and join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::connect;

    fn read_line(conn: &mut dyn Transport) -> String {
        let mut out: Vec<u8> = Vec::new();
        let mut b = [0u8; 256];
        while !out.contains(&b'\n') {
            let n = conn.recv_raw(&mut b).expect("watch stream read");
            if n == 0 {
                break;
            }
            out.extend_from_slice(&b[..n]);
        }
        let nl = out.iter().position(|&c| c == b'\n').unwrap_or(out.len());
        String::from_utf8_lossy(&out[..nl]).into_owned()
    }

    #[test]
    fn watch_subscriber_gets_deltas_and_bare_client_gets_snapshot() {
        let metrics = Arc::new(Metrics::default());
        metrics.counter("wire_up_bytes").add(11);
        let state = Arc::new(StatusState::new(metrics));
        state.begin_run("drill", 40, 2);
        state.set_iter(3);
        let listener = NetListener::bind("tcp://127.0.0.1:0").unwrap();
        let server = StatusServer::spawn(listener, state.clone()).unwrap();

        // subscribe: one delta immediately, another after a change
        let mut sub = connect(server.addr()).unwrap();
        sub.send_frame(b"WATCH\n").unwrap();
        let first = crate::util::json::parse(&read_line(sub.as_mut())).unwrap();
        assert_eq!(first.get("iter").and_then(Json::as_f64), Some(3.0));
        assert!(first.get("metrics").is_none(), "deltas omit the metrics dump");
        state.set_iter(4);
        state.set_phase("gather");
        let second = crate::util::json::parse(&read_line(sub.as_mut())).unwrap();
        assert_eq!(second.get("iter").and_then(Json::as_f64), Some(4.0));

        // bare client (nc shape): no request line, one snapshot to EOF
        let mut snap = connect(server.addr()).unwrap();
        let mut body = Vec::new();
        let mut b = [0u8; 512];
        loop {
            match snap.recv_raw(&mut b) {
                Ok(0) | Err(_) => break,
                Ok(n) => body.extend_from_slice(&b[..n]),
            }
        }
        let j = crate::util::json::parse(&String::from_utf8_lossy(&body)).unwrap();
        assert_eq!(j.get("label").and_then(Json::as_str), Some("drill"));
        assert!(j.get("metrics").is_some(), "snapshot keeps the metrics dump");
        drop(sub);
        server.stop();
    }

    #[test]
    fn roster_updates_flow_into_the_snapshot() {
        let state = StatusState::new(Arc::new(Metrics::default()));
        state.begin_run("drill", 40, 3);
        state.set_iter(7);
        state.set_phase("gather");
        state.device_miss(1, 2);
        state.device_retired(2);
        state.device_rejoined(2, 1);
        state.add_phase_ns(10, 20, 30);
        state.add_anomalies(3);
        let snap = state.snapshot_json();
        assert_eq!(snap.get("label").and_then(Json::as_str), Some("drill"));
        assert_eq!(snap.get("iter").and_then(Json::as_f64), Some(7.0));
        assert_eq!(snap.get("phase").and_then(Json::as_str), Some("gather"));
        assert_eq!(
            snap.get("phase_ns").and_then(|p| p.get("gather_ns")).and_then(Json::as_f64),
            Some(20.0)
        );
        let roster = snap.get("roster").and_then(Json::as_arr).unwrap();
        assert_eq!(roster.len(), 3);
        assert_eq!(roster[1].get("miss_streak").and_then(Json::as_f64), Some(2.0));
        assert_eq!(roster[2].get("dead"), Some(&Json::Bool(false)));
        assert_eq!(roster[2].get("epoch").and_then(Json::as_f64), Some(1.0));
        assert!(snap.get("metrics").is_some());
    }
}
