//! Journal replay: the read side of the event journal.
//!
//! [`read_journal`] parses an `events.jsonl` file back into typed
//! [`Event`]s (tolerating the torn final line a kill mid-append leaves,
//! exactly like the sweep manifest reader), sorts them by the
//! process-monotonic `seq` envelope field to recover emission order,
//! and [`RunTimeline::from_events`] folds them into a structural
//! description of the run: per-device retire / rejoin / miss / discard
//! history, role-rotation draws, checkpoint and failover points, and
//! sweep job completions.
//!
//! [`diff`] compares two timelines **structurally**: wall-clock fields
//! (checkpoint / sweep-job `ns`, the `ms` envelope) and run-local paths
//! (the failover checkpoint path) are excluded, so two same-seed runs
//! — or a kill/resume pair, modulo its checkpoint/failover events —
//! compare equal even though their journals were written at different
//! speeds into different directories. This is what lets CI replace
//! whole-file `cmp`s with semantic diffs (`lad obs diff`).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{ensure, Context as _, Result};

use crate::obs::events::Event;
use crate::util::json::{self, Json};

/// Parse a journal file into `(seq, event)` pairs sorted by `seq`.
///
/// Journal lines are written with one atomic `write(2)` each, but lock
/// shards interleave — sorting by `seq` recovers emission order. A
/// torn **final** line (kill mid-append) is dropped with a note;
/// corruption anywhere else is an error. Lines with an unknown
/// `event` discriminator parse but don't type — they are skipped, so
/// journals from newer builds stay replayable.
pub fn read_journal<P: AsRef<Path>>(path: P) -> Result<Vec<(u64, Event)>> {
    let path = path.as_ref();
    let body =
        std::fs::read_to_string(path).with_context(|| format!("reading journal {path:?}"))?;
    let lines: Vec<&str> = body.lines().collect();
    let mut out: Vec<(u64, Event)> = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = json::parse(line).and_then(|j| {
            j.get("seq")
                .and_then(Json::as_f64)
                .map(|s| (s as u64, j))
                .ok_or_else(|| anyhow::anyhow!("missing \"seq\" envelope field"))
        });
        match parsed {
            Ok((seq, j)) => {
                if let Some(ev) = Event::from_json(&j) {
                    out.push((seq, ev));
                }
                // unknown discriminator: skip, keep replaying
            }
            Err(e) => {
                ensure!(
                    i + 1 == lines.len(),
                    "corrupt journal line {} of {path:?}: {e}",
                    i + 1
                );
                eprintln!("obs: ignoring truncated final journal line {} ({e})", i + 1);
            }
        }
    }
    out.sort_by_key(|&(seq, _)| seq);
    Ok(out)
}

/// How a discarded upload was classified from its journal entry — the
/// distinction the `epoch` field on `stale_upload_discarded` exists
/// for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiscardKind {
    /// Arrived on a dead connection epoch (the slot was re-filled).
    Ghost,
    /// Live epoch, old iteration tag: an honest-but-late upload.
    LateHonest,
    /// Live epoch, current iteration, but the slot already answered.
    Duplicate,
    /// The frame's device label did not match its link.
    Mislabel,
}

impl DiscardKind {
    fn classify(reason: &str) -> DiscardKind {
        if reason.starts_with("ghost epoch") {
            DiscardKind::Ghost
        } else if reason.starts_with("duplicate") {
            DiscardKind::Duplicate
        } else if reason.starts_with("upload labeled") {
            DiscardKind::Mislabel
        } else {
            DiscardKind::LateHonest
        }
    }

    fn label(&self) -> &'static str {
        match self {
            DiscardKind::Ghost => "ghost",
            DiscardKind::LateHonest => "late-honest",
            DiscardKind::Duplicate => "duplicate",
            DiscardKind::Mislabel => "mislabel",
        }
    }
}

/// One device's membership history, reconstructed from the journal.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceTimeline {
    /// `(iter, reason)` of every retirement, in order.
    pub retires: Vec<(u64, String)>,
    /// `(iter, epoch)` of every rejoin, in order.
    pub rejoins: Vec<(u64, u64)>,
    /// `(iter, streak)` of every gather-deadline miss, in order.
    pub misses: Vec<(u64, u64)>,
    /// `(iter, upload_iter, epoch, kind)` of every discarded upload.
    pub discards: Vec<(u64, u64, u64, DiscardKind)>,
    /// `attempt` numbers of worker redials attributed to this slot.
    pub redials: Vec<u64>,
}

impl DeviceTimeline {
    fn is_empty(&self) -> bool {
        self.retires.is_empty()
            && self.rejoins.is_empty()
            && self.misses.is_empty()
            && self.discards.is_empty()
            && self.redials.is_empty()
    }
}

/// The typed reconstruction of one run's journal: everything the
/// membership / checkpoint / rotation machinery emitted, with
/// wall-clock envelope data dropped.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunTimeline {
    /// Per-device histories, indexed by device id (grown on demand).
    pub devices: Vec<DeviceTimeline>,
    /// `(iter, byzantine set)` of every role-rotation draw, in order.
    pub role_draws: Vec<(u64, Vec<usize>)>,
    /// `(iter, bytes, ns)` of every checkpoint cut. `bytes` is
    /// deterministic (checkpoint bytes are bit-identical across
    /// same-seed runs); `ns` is wall clock and excluded from [`diff`].
    pub checkpoints: Vec<(u64, u64, u64)>,
    /// `(iter, checkpoint path)` of every warm restart. The path is
    /// run-local and excluded from [`diff`].
    pub failovers: Vec<(u64, String)>,
    /// `(id, ns)` of every completed sweep job. `ns` is wall clock and
    /// excluded from [`diff`].
    pub sweep_jobs: Vec<(String, u64)>,
    /// Total typed events consumed.
    pub events: usize,
}

impl RunTimeline {
    /// Fold a `seq`-sorted event list into a timeline.
    pub fn from_events(events: &[(u64, Event)]) -> RunTimeline {
        let mut tl = RunTimeline::default();
        for (_, ev) in events {
            tl.apply(ev);
        }
        tl
    }

    /// Replay a journal file end-to-end.
    pub fn from_journal<P: AsRef<Path>>(path: P) -> Result<RunTimeline> {
        Ok(Self::from_events(&read_journal(path)?))
    }

    fn device(&mut self, dev: usize) -> &mut DeviceTimeline {
        if dev >= self.devices.len() {
            self.devices.resize(dev + 1, DeviceTimeline::default());
        }
        &mut self.devices[dev]
    }

    fn apply(&mut self, ev: &Event) {
        self.events += 1;
        match ev {
            Event::DeviceRetired { device, iter, reason } => {
                self.device(*device).retires.push((*iter, reason.clone()));
            }
            Event::DeviceRejoined { device, iter, epoch } => {
                self.device(*device).rejoins.push((*iter, *epoch));
            }
            Event::DeadlineMiss { device, iter, streak } => {
                self.device(*device).misses.push((*iter, *streak));
            }
            Event::StaleUploadDiscarded { device, iter, upload_iter, epoch, reason } => {
                let kind = DiscardKind::classify(reason);
                self.device(*device).discards.push((*iter, *upload_iter, *epoch, kind));
            }
            Event::CheckpointWritten { iter, bytes, ns } => {
                self.checkpoints.push((*iter, *bytes, *ns));
            }
            Event::LeaderFailover { iter, checkpoint } => {
                self.failovers.push((*iter, checkpoint.clone()));
            }
            Event::ByzantineRoleDrawn { iter, byzantine } => {
                self.role_draws.push((*iter, byzantine.clone()));
            }
            Event::SweepJobDone { id, ns } => {
                self.sweep_jobs.push((id.clone(), *ns));
            }
            Event::WorkerRedial { device, attempt, reason: _ } => {
                self.device(*device).redials.push(*attempt);
            }
        }
    }

    /// Append another timeline's history onto this one — the shape a
    /// kill/resume pair takes when each leg wrote its own journal.
    pub fn merge(&mut self, other: &RunTimeline) {
        if other.devices.len() > self.devices.len() {
            self.devices.resize(other.devices.len(), DeviceTimeline::default());
        }
        for (dst, src) in self.devices.iter_mut().zip(&other.devices) {
            dst.retires.extend(src.retires.iter().cloned());
            dst.rejoins.extend(src.rejoins.iter().cloned());
            dst.misses.extend(src.misses.iter().cloned());
            dst.discards.extend(src.discards.iter().cloned());
            dst.redials.extend(src.redials.iter().cloned());
        }
        self.role_draws.extend(other.role_draws.iter().cloned());
        self.checkpoints.extend(other.checkpoints.iter().cloned());
        self.failovers.extend(other.failovers.iter().cloned());
        self.sweep_jobs.extend(other.sweep_jobs.iter().cloned());
        self.events += other.events;
    }

    /// Human-readable rendering: one line per reconstructed fact, in
    /// device / iteration order — the CI timeline artifact.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "run timeline ({} events)", self.events);
        for (dev, d) in self.devices.iter().enumerate() {
            if d.is_empty() {
                continue;
            }
            let _ = writeln!(s, "device {dev}:");
            for (iter, streak) in &d.misses {
                let _ = writeln!(s, "  iter {iter:>6}  deadline miss (streak {streak})");
            }
            for (iter, reason) in &d.retires {
                let _ = writeln!(s, "  iter {iter:>6}  retired: {reason}");
            }
            for (iter, epoch) in &d.rejoins {
                let _ = writeln!(s, "  iter {iter:>6}  rejoined (epoch {epoch})");
            }
            for (iter, up_iter, epoch, kind) in &d.discards {
                let _ = writeln!(
                    s,
                    "  iter {iter:>6}  discarded upload for iter {up_iter} \
                     (epoch {epoch}, {})",
                    kind.label()
                );
            }
            for attempt in &d.redials {
                let _ = writeln!(s, "  redial attempt {attempt}");
            }
        }
        for (iter, bytes, _) in &self.checkpoints {
            let _ = writeln!(s, "checkpoint at iter {iter} ({bytes} bytes)");
        }
        for (iter, path) in &self.failovers {
            let _ = writeln!(s, "failover resume at iter {iter} (from {path})");
        }
        if !self.role_draws.is_empty() {
            let _ = writeln!(s, "role rotation: {} draws", self.role_draws.len());
        }
        for (id, _) in &self.sweep_jobs {
            let _ = writeln!(s, "sweep job done: {id}");
        }
        s
    }
}

/// One structural difference between two timelines.
#[derive(Debug, Clone, PartialEq)]
pub struct Divergence {
    /// Which leg diverged: `retire`, `rejoin`, `miss`, `discard`,
    /// `redial`, `role_draw`, `checkpoint`, `failover`, `sweep_job`,
    /// or `roster`.
    pub category: &'static str,
    pub detail: String,
}

fn diff_list<T: PartialEq + std::fmt::Debug>(
    out: &mut Vec<Divergence>,
    category: &'static str,
    what: &str,
    a: &[T],
    b: &[T],
) {
    if a == b {
        return;
    }
    out.push(Divergence {
        category,
        detail: format!("{what}: {} vs {} entries, a={a:?} b={b:?}", a.len(), b.len()),
    });
}

/// Compare two timelines structurally. Wall-clock fields (`ns`) and
/// run-local paths (the failover checkpoint path) are excluded; device
/// membership history, role draws, checkpoint schedule + sizes, and
/// sweep job ids must match exactly. Returns one [`Divergence`] per
/// differing leg — empty means the runs are structurally identical.
pub fn diff(a: &RunTimeline, b: &RunTimeline) -> Vec<Divergence> {
    let mut out = Vec::new();
    let n = a.devices.len().max(b.devices.len());
    let empty = DeviceTimeline::default();
    if a.devices.len() != b.devices.len() {
        // only a divergence when the extra slots carry history
        let longer = if a.devices.len() > b.devices.len() { &a.devices } else { &b.devices };
        let shorter_len = a.devices.len().min(b.devices.len());
        if longer[shorter_len..].iter().any(|d| !d.is_empty()) {
            out.push(Divergence {
                category: "roster",
                detail: format!(
                    "device count {} vs {}",
                    a.devices.len(),
                    b.devices.len()
                ),
            });
        }
    }
    for dev in 0..n {
        let da = a.devices.get(dev).unwrap_or(&empty);
        let db = b.devices.get(dev).unwrap_or(&empty);
        diff_list(&mut out, "retire", &format!("device {dev} retires"), &da.retires, &db.retires);
        diff_list(&mut out, "rejoin", &format!("device {dev} rejoins"), &da.rejoins, &db.rejoins);
        diff_list(&mut out, "miss", &format!("device {dev} misses"), &da.misses, &db.misses);
        diff_list(
            &mut out,
            "discard",
            &format!("device {dev} discards"),
            &da.discards,
            &db.discards,
        );
        diff_list(&mut out, "redial", &format!("device {dev} redials"), &da.redials, &db.redials);
    }
    diff_list(&mut out, "role_draw", "role draws", &a.role_draws, &b.role_draws);
    // checkpoint ns is wall clock — compare (iter, bytes) only
    let ck = |t: &RunTimeline| -> Vec<(u64, u64)> {
        t.checkpoints.iter().map(|&(i, b, _)| (i, b)).collect()
    };
    diff_list(&mut out, "checkpoint", "checkpoints", &ck(a), &ck(b));
    // the checkpoint path is run-local — compare failover iterations only
    let fo = |t: &RunTimeline| -> Vec<u64> { t.failovers.iter().map(|&(i, _)| i).collect() };
    diff_list(&mut out, "failover", "failovers", &fo(a), &fo(b));
    let sj = |t: &RunTimeline| -> Vec<&str> {
        t.sweep_jobs.iter().map(|(id, _)| id.as_str()).collect()
    };
    diff_list(&mut out, "sweep_job", "sweep jobs", &sj(a), &sj(b));
    out
}

/// True when every divergence falls in one of `allowed` categories —
/// the kill/resume acceptance check ("diverges only in
/// checkpoint/failover events").
pub fn only_in(divs: &[Divergence], allowed: &[&str]) -> bool {
    divs.iter().all(|d| allowed.contains(&d.category))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<(u64, Event)> {
        vec![
            (0, Event::DeadlineMiss { device: 1, iter: 4, streak: 1 }),
            (1, Event::DeadlineMiss { device: 1, iter: 5, streak: 2 }),
            (2, Event::DeadlineMiss { device: 1, iter: 6, streak: 3 }),
            (3, Event::DeviceRetired { device: 1, iter: 6, reason: "3 misses".into() }),
            (4, Event::DeviceRejoined { device: 1, iter: 7, epoch: 1 }),
            (
                5,
                Event::StaleUploadDiscarded {
                    device: 1,
                    iter: 7,
                    upload_iter: 4,
                    epoch: 0,
                    reason: "ghost epoch 0 (slot re-filled, now epoch 1)".into(),
                },
            ),
            (6, Event::CheckpointWritten { iter: 8, bytes: 640, ns: 1000 }),
            (7, Event::LeaderFailover { iter: 8, checkpoint: "/tmp/a/run.ckpt".into() }),
        ]
    }

    #[test]
    fn timeline_reconstructs_membership_history() {
        let tl = RunTimeline::from_events(&sample());
        assert_eq!(tl.devices.len(), 2);
        let d = &tl.devices[1];
        assert_eq!(d.misses, vec![(4, 1), (5, 2), (6, 3)]);
        assert_eq!(d.retires.len(), 1);
        assert_eq!(d.retires[0].0, 6);
        assert_eq!(d.rejoins, vec![(7, 1)]);
        assert_eq!(d.discards, vec![(7, 4, 0, DiscardKind::Ghost)]);
        assert_eq!(tl.checkpoints, vec![(8, 640, 1000)]);
        assert_eq!(tl.failovers.len(), 1);
        let text = tl.render();
        assert!(text.contains("device 1:"), "{text}");
        assert!(text.contains("rejoined (epoch 1)"), "{text}");
    }

    #[test]
    fn diff_excludes_wall_clock_and_run_local_fields() {
        let a = RunTimeline::from_events(&sample());
        let mut evs = sample();
        // different wall clock + different checkpoint directory: still
        // structurally identical
        evs[6].1 = Event::CheckpointWritten { iter: 8, bytes: 640, ns: 999_999 };
        evs[7].1 = Event::LeaderFailover { iter: 8, checkpoint: "/tmp/b/run.ckpt".into() };
        let b = RunTimeline::from_events(&evs);
        assert_eq!(diff(&a, &b), Vec::new());
        // a real structural change (different rejoin epoch) is caught
        let mut evs = sample();
        evs[4].1 = Event::DeviceRejoined { device: 1, iter: 7, epoch: 2 };
        let c = RunTimeline::from_events(&evs);
        let divs = diff(&a, &c);
        assert_eq!(divs.len(), 1);
        assert_eq!(divs[0].category, "rejoin");
        assert!(only_in(&divs, &["rejoin"]));
        assert!(!only_in(&divs, &["checkpoint", "failover"]));
    }

    #[test]
    fn read_journal_sorts_by_seq_and_drops_a_torn_tail() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("lad_replay_{}.jsonl", std::process::id()));
        // out-of-order seqs (shard interleave), one unknown event kind,
        // and a torn final line
        let body = "\
{\"event\":\"deadline_miss\",\"device\":2,\"iter\":5,\"streak\":1,\"seq\":1,\"ms\":9}\n\
{\"event\":\"device_retired\",\"device\":2,\"iter\":5,\"reason\":\"x\",\"seq\":0,\"ms\":8}\n\
{\"event\":\"from_the_future\",\"seq\":2,\"ms\":10}\n\
{\"event\":\"checkpoint_written\",\"iter\":6,\"by";
        std::fs::write(&path, body).unwrap();
        let evs = read_journal(&path).unwrap();
        assert_eq!(evs.len(), 2, "unknown kind skipped, torn tail dropped");
        assert_eq!(evs[0].0, 0);
        assert!(matches!(evs[0].1, Event::DeviceRetired { .. }));
        assert!(matches!(evs[1].1, Event::DeadlineMiss { .. }));
        // corruption NOT at the tail is an error
        std::fs::write(&path, "garbage\n{\"seq\":0,\"event\":\"deadline_miss\"}\n").unwrap();
        assert!(read_journal(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
