//! Named counters, gauges, and fixed-bucket histograms.
//!
//! The registry is lock-protected but individual instruments are plain
//! atomics, so the usual pattern in a loop is: resolve the `Arc`
//! handle once outside, then `add`/`observe` lock-free inside.
//! Histograms bucket by power of two (`64 - leading_zeros`), so the
//! hot `observe` path is integer-only — no float math on any
//! per-iteration site.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Monotonic counter (wire bytes, frames encoded, events seen).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, delta: u64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (pool queue depth, EF residual norm). Stored
/// as `f64` bits; integer sites pay one int→float convert on `set`,
/// which keeps a single snapshot representation.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, value: f64) {
        self.0.store(value.to_bits(), Ordering::Relaxed);
    }
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Bucket count in [`Histogram`]: bucket 0 holds exactly `v == 0`,
/// bucket `i ≥ 1` holds `2^(i-1) ≤ v < 2^i`, up to the full `u64`
/// range.
pub const HIST_BUCKETS: usize = 65;

/// Fixed-bucket power-of-2 histogram for nanosecond samples. Integer
/// arithmetic only: index is `64 - leading_zeros`, and `count`/`sum`
/// ride along for mean/rate derivation at snapshot time.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Bucket index for a sample: `0` for `0`, else `64 - lz(v)`.
    pub fn bucket_index(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Inclusive lower bound of a bucket (`0`, then `2^(i-1)`).
    pub fn bucket_lo(idx: usize) -> u64 {
        if idx == 0 {
            0
        } else {
            1u64 << (idx - 1)
        }
    }

    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Largest sample seen (exact, not bucketed).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Estimated quantile `q ∈ (0, 1]`, linearly interpolated inside
    /// the matched power-of-two bucket and clamped to the exact
    /// recorded `max` (so the top bucket never extrapolates past a
    /// real sample). Returns `0` on an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let max = self.max();
        let target = (q * count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if cum + n >= target {
                let lo = Self::bucket_lo(idx);
                let hi = if idx >= 64 {
                    max
                } else {
                    (Self::bucket_lo(idx + 1) - 1).min(max)
                };
                let hi = hi.max(lo);
                let pos = (target - cum) as f64 / n as f64;
                let v = lo as f64 + pos * (hi - lo) as f64;
                return (v.round() as u64).min(max);
            }
            cum += n;
        }
        max
    }

    /// `{count, sum, max, p50, p95, p99, buckets: [[lo, n], …]}` with
    /// empty buckets elided.
    pub fn snapshot_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("count".to_string(), Json::Num(self.count() as f64));
        o.insert("sum".to_string(), Json::Num(self.sum() as f64));
        o.insert("max".to_string(), Json::Num(self.max() as f64));
        o.insert("p50".to_string(), Json::Num(self.quantile(0.50) as f64));
        o.insert("p95".to_string(), Json::Num(self.quantile(0.95) as f64));
        o.insert("p99".to_string(), Json::Num(self.quantile(0.99) as f64));
        let mut buckets = Vec::new();
        for (idx, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                buckets.push(Json::Arr(vec![
                    Json::Num(Self::bucket_lo(idx) as f64),
                    Json::Num(n as f64),
                ]));
            }
        }
        o.insert("buckets".to_string(), Json::Arr(buckets));
        Json::Obj(o)
    }
}

/// Named instrument registry. Get-or-create by name; handles are
/// `Arc`s so loops cache them outside the hot path and the registry
/// mutex is only touched at resolution and snapshot time.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Metrics {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().expect("metrics registry poisoned");
        match map.get(name) {
            Some(c) => c.clone(),
            None => {
                let c = Arc::new(Counter::default());
                map.insert(name.to_string(), c.clone());
                c
            }
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().expect("metrics registry poisoned");
        match map.get(name) {
            Some(g) => g.clone(),
            None => {
                let g = Arc::new(Gauge::default());
                map.insert(name.to_string(), g.clone());
                g
            }
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().expect("metrics registry poisoned");
        match map.get(name) {
            Some(h) => h.clone(),
            None => {
                let h = Arc::new(Histogram::default());
                map.insert(name.to_string(), h.clone());
                h
            }
        }
    }

    /// Read-only histogram lookup: `None` when nothing has been
    /// recorded under `name`. Report writers probe with this instead of
    /// [`Metrics::histogram`] so asking about a kernel that never ran
    /// does not register an empty instrument in the snapshot.
    pub fn histogram_get(&self, name: &str) -> Option<Arc<Histogram>> {
        self.histograms.lock().expect("metrics registry poisoned").get(name).cloned()
    }

    /// Full registry snapshot:
    /// `{"counters": {..}, "gauges": {..}, "histograms": {..}}`.
    pub fn snapshot(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, c) in self.counters.lock().expect("metrics registry poisoned").iter() {
            counters.insert(name.clone(), Json::Num(c.get() as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, g) in self.gauges.lock().expect("metrics registry poisoned").iter() {
            gauges.insert(name.clone(), Json::Num(g.get()));
        }
        let mut histograms = BTreeMap::new();
        for (name, h) in self.histograms.lock().expect("metrics registry poisoned").iter() {
            histograms.insert(name.clone(), h.snapshot_json());
        }
        let mut top = BTreeMap::new();
        top.insert("counters".to_string(), Json::Obj(counters));
        top.insert("gauges".to_string(), Json::Obj(gauges));
        top.insert("histograms".to_string(), Json::Obj(histograms));
        Json::Obj(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_power_of_two_exact() {
        let cases: &[(u64, usize)] = &[
            (0, 0),
            (1, 1),
            (2, 2),
            (3, 2),
            (4, 3),
            (7, 3),
            (8, 4),
            (1023, 10),
            (1024, 11),
            (u64::MAX, 64),
        ];
        for &(v, idx) in cases {
            assert_eq!(Histogram::bucket_index(v), idx, "bucket_index({v})");
            assert!(Histogram::bucket_lo(idx) <= v, "lo({idx}) > {v}");
            if idx < 64 {
                // v sits below the next bucket's lower bound.
                assert!(v < Histogram::bucket_lo(idx + 1), "{v} >= lo({})", idx + 1);
            }
        }
        assert_eq!(Histogram::bucket_lo(0), 0);
        assert_eq!(Histogram::bucket_lo(1), 1);
        assert_eq!(Histogram::bucket_lo(11), 1024);
    }

    #[test]
    fn histogram_observe_tracks_count_sum_and_buckets() {
        let h = Histogram::default();
        for v in [0u64, 1, 3, 1024, 1024] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 2052);
        let snap = h.snapshot_json();
        let buckets = snap.get("buckets").and_then(Json::as_arr).unwrap();
        // buckets: 0 → one, 1 → one, 2..4 → one (v=3), 1024.. → two
        let pairs: Vec<(u64, u64)> = buckets
            .iter()
            .map(|b| {
                let p = b.as_arr().unwrap();
                (p[0].as_f64().unwrap() as u64, p[1].as_f64().unwrap() as u64)
            })
            .collect();
        assert_eq!(pairs, vec![(0, 1), (1, 1), (2, 1), (1024, 2)]);
    }

    #[test]
    fn quantiles_interpolate_to_exact_recorded_values() {
        // Uniform 1..=100: interpolation inside the matched
        // power-of-two bucket lands on the exact order statistic.
        let h = Histogram::default();
        for v in 1..=100u64 {
            h.observe(v);
        }
        assert_eq!(h.max(), 100);
        assert_eq!(h.quantile(0.50), 50);
        assert_eq!(h.quantile(0.95), 95);
        assert_eq!(h.quantile(0.99), 99);
        let snap = h.snapshot_json();
        assert_eq!(snap.get("p50").and_then(Json::as_f64), Some(50.0));
        assert_eq!(snap.get("p95").and_then(Json::as_f64), Some(95.0));
        assert_eq!(snap.get("p99").and_then(Json::as_f64), Some(99.0));
        assert_eq!(snap.get("max").and_then(Json::as_f64), Some(100.0));

        // Degenerate distribution: the max clamp keeps the top
        // quantiles at the real sample instead of the bucket edge.
        let d = Histogram::default();
        for _ in 0..100 {
            d.observe(7);
        }
        assert_eq!(d.max(), 7);
        assert_eq!(d.quantile(0.99), 7);
        assert_eq!(d.quantile(1.0), 7);

        // Empty histogram reports zeros, not NaN-ish artifacts.
        let e = Histogram::default();
        assert_eq!(e.quantile(0.5), 0);
        assert_eq!(e.max(), 0);
    }

    #[test]
    fn registry_get_or_create_shares_instruments() {
        let m = Metrics::default();
        m.counter("wire_up_bytes").add(10);
        m.counter("wire_up_bytes").add(5);
        m.gauge("queue_depth").set(3.0);
        m.histogram("gather").observe(100);
        assert_eq!(m.counter("wire_up_bytes").get(), 15);
        let snap = m.snapshot();
        assert_eq!(
            snap.get("counters").and_then(|c| c.get("wire_up_bytes")).and_then(Json::as_f64),
            Some(15.0)
        );
        assert_eq!(
            snap.get("gauges").and_then(|g| g.get("queue_depth")).and_then(Json::as_f64),
            Some(3.0)
        );
        assert_eq!(
            snap.get("histograms")
                .and_then(|h| h.get("gather"))
                .and_then(|h| h.get("count"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
    }
}
