//! Structured observability: typed event journal, metrics registry,
//! span profiler, and live leader status endpoint.
//!
//! Everything in this module is wall-clock telemetry **only**: with the
//! recorder on, off, or exporting, traces, wire bytes, RNG stream
//! order, checkpoints, and pinned sweep job ids are bit-identical.
//! That invariant is pinned by `fuzzed_recorder_parity_*` in
//! `tests/fuzz_determinism.rs` and by the CI `obs` job's `cmp`
//! assertion of a recorder-on CLI drill against a recorder-off
//! reference. Nothing here may influence control flow, RNG draws, or
//! bytes on the training wire.
//!
//! The layer has four legs, all std-only:
//!
//! - [`events`] — a [`Recorder`] trait with a lock-sharded JSONL sink
//!   ([`JsonlRecorder`]): one `events.jsonl` line per [`Event`], atomic
//!   appends, process-monotonic sequence numbers.
//! - [`metrics`] — a named registry of counters / gauges / power-of-2
//!   bucket histograms ([`Metrics`]); integer-only in hot paths,
//!   snapshotable as JSON next to `results.csv`.
//! - [`spans`] — nestable [`span!`](crate::span) guards feeding both
//!   the histogram registry and an optional Chrome-trace-format dump
//!   ([`export::write_chrome_trace`]) for flamegraph viewing.
//! - [`status`] — a read-only, one-request-per-connection snapshot
//!   endpoint ([`StatusServer`]) over `net::transport` listeners
//!   (`tcp://` or `uds:`), serving the roster, phase timings, and a
//!   metrics dump while a run is live.
//!
//! # Event schema
//!
//! Events serialize as JSONL: `{"seq":…,"ms":…,"event":"<kind>",…}`
//! with a process-monotonic `seq` and `ms` since recorder creation.
//! Each line is written with a single `write(2)` on an `O_APPEND`
//! descriptor, so lines never tear, but lines from different lock
//! shards may interleave out of emission order — sort by `seq` to
//! reconstruct it.
//!
//! | `event`                  | payload fields                   | emitted from |
//! |--------------------------|----------------------------------|--------------|
//! | `device_retired`         | `device`, `iter`, `reason`       | leader gather loop |
//! | `device_rejoined`        | `device`, `iter`, `epoch`        | leader rejoin intake |
//! | `deadline_miss`          | `device`, `iter`, `streak`       | leader gather deadline |
//! | `stale_upload_discarded` | `device`, `iter`, `upload_iter`, `epoch`, `reason` | epoch reader |
//! | `checkpoint_written`     | `iter`, `bytes`, `ns`            | leader checkpoint cut |
//! | `leader_failover`        | `iter`, `checkpoint`             | warm-restart entry |
//! | `byzantine_role_drawn`   | `iter`, `byzantine`              | per-iter role rotation |
//! | `sweep_job_done`         | `id`, `ns`                       | sweep queue |
//! | `worker_redial`          | `device`, `attempt`, `reason`    | worker redial loop |

pub mod events;
pub mod export;
pub mod metrics;
pub mod replay;
pub mod spans;
pub mod status;
pub mod watch;

pub use events::{Event, JsonlRecorder, NullRecorder, Recorder};
pub use metrics::{Counter, Gauge, Histogram, Metrics};
pub use replay::{DiscardKind, Divergence, RunTimeline};
pub use spans::{SpanGuard, SpanRec, SpanSink};
pub use status::{DeviceStatus, StatusServer, StatusState};

use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::net::transport::NetListener;

/// Everything a live [`Obs`] context carries. Shared via `Arc` so
/// cloning an `Obs` (into leader opts, worker opts, pool closures) is
/// one refcount bump and all clones feed the same sinks.
struct Core {
    recorder: Box<dyn Recorder>,
    metrics: Arc<Metrics>,
    spans: Arc<SpanSink>,
    status: Option<Arc<StatusState>>,
    metrics_out: Option<PathBuf>,
    trace_out: Option<PathBuf>,
}

/// Cheap, cloneable observability handle threaded through the leader,
/// worker, trainer, and sweep paths. [`Obs::off`] (the default) is a
/// `None` inner — every call short-circuits on one branch and the hot
/// paths stay byte-for-byte what they were before this layer existed.
#[derive(Clone, Default)]
pub struct Obs {
    core: Option<Arc<Core>>,
}

impl fmt::Debug for Obs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.core {
            None => f.write_str("Obs(off)"),
            Some(c) => write!(f, "Obs(on, status={})", c.status.is_some()),
        }
    }
}

impl Obs {
    /// Disabled context: every emit/metric/span call is a no-op branch.
    pub fn off() -> Obs {
        Obs { core: None }
    }

    /// Enabled context with the given recorder, a fresh metrics
    /// registry and span sink, and no export paths or status endpoint.
    /// The shape the tests use; CLI entry points use [`ObsBuilder`].
    pub fn recording(recorder: Box<dyn Recorder>) -> Obs {
        Obs {
            core: Some(Arc::new(Core {
                recorder,
                metrics: Arc::new(Metrics::default()),
                spans: Arc::new(SpanSink::new()),
                status: None,
                metrics_out: None,
                trace_out: None,
            })),
        }
    }

    /// Start a builder for the full CLI shape (journal file, export
    /// paths, status endpoint).
    pub fn builder() -> ObsBuilder {
        ObsBuilder::default()
    }

    pub fn enabled(&self) -> bool {
        self.core.is_some()
    }

    /// Record a typed event. No-op when off.
    pub fn emit(&self, ev: Event) {
        if let Some(core) = &self.core {
            core.recorder.record(&ev);
        }
    }

    /// The shared metrics registry, when on.
    pub fn metrics(&self) -> Option<&Arc<Metrics>> {
        self.core.as_ref().map(|c| &c.metrics)
    }

    /// The live status state, when a status endpoint is attached.
    pub fn status(&self) -> Option<&Arc<StatusState>> {
        self.core.as_ref().and_then(|c| c.status.as_ref())
    }

    /// Bump a named counter. No-op when off.
    pub fn add(&self, name: &str, delta: u64) {
        if let Some(core) = &self.core {
            core.metrics.counter(name).add(delta);
        }
    }

    /// Set a named gauge. No-op when off.
    pub fn gauge(&self, name: &str, value: f64) {
        if let Some(core) = &self.core {
            core.metrics.gauge(name).set(value);
        }
    }

    /// Record a nanosecond sample into a named histogram. No-op when
    /// off.
    pub fn observe_ns(&self, name: &str, ns: u64) {
        if let Some(core) = &self.core {
            core.metrics.histogram(name).observe(ns);
        }
    }

    /// Open a span guard. The guard always measures wall time — its
    /// [`SpanGuard::done`] returns elapsed ns so `TrainTrace` phase
    /// fields stay populated with obs off — but only records into the
    /// histogram registry / Chrome-trace sink when obs is on.
    pub fn span(&self, name: &'static str) -> SpanGuard<'_> {
        SpanGuard::enter(self, name)
    }

    /// Internal: called by [`SpanGuard`] when a span closes.
    pub(crate) fn record_span(&self, name: &'static str, start: Instant, dur_ns: u64) {
        if let Some(core) = &self.core {
            core.spans.record(name, start, dur_ns);
            core.metrics.histogram(name).observe(dur_ns);
        }
    }

    /// Flush the journal and write the metrics / Chrome-trace dumps to
    /// their configured paths (if any). Call once at run end; safe to
    /// call on an off context (no-op).
    pub fn finish(&self) -> Result<()> {
        let Some(core) = &self.core else { return Ok(()) };
        core.recorder.flush()?;
        if let Some(path) = &core.metrics_out {
            export::write_metrics(&core.metrics, path)
                .with_context(|| format!("writing metrics snapshot {}", path.display()))?;
        }
        if let Some(path) = &core.trace_out {
            export::write_chrome_trace(&core.spans, path)
                .with_context(|| format!("writing Chrome trace {}", path.display()))?;
        }
        Ok(())
    }
}

/// Builder for the CLI observability shape. Every output is optional;
/// with nothing set, `build()` returns an enabled context that only
/// feeds the in-memory registry (useful with `LAD_OBS=1` alone).
#[derive(Default)]
pub struct ObsBuilder {
    events_out: Option<PathBuf>,
    metrics_out: Option<PathBuf>,
    trace_out: Option<PathBuf>,
    status_addr: Option<String>,
}

impl ObsBuilder {
    /// JSONL event journal destination (recreated, not appended-to,
    /// per run).
    pub fn events_out<P: Into<PathBuf>>(mut self, path: P) -> Self {
        self.events_out = Some(path.into());
        self
    }

    /// Metrics snapshot JSON destination, written by [`Obs::finish`].
    pub fn metrics_out<P: Into<PathBuf>>(mut self, path: P) -> Self {
        self.metrics_out = Some(path.into());
        self
    }

    /// Chrome-trace (`trace_event`) JSON destination, written by
    /// [`Obs::finish`].
    pub fn trace_out<P: Into<PathBuf>>(mut self, path: P) -> Self {
        self.trace_out = Some(path.into());
        self
    }

    /// Bind a live status endpoint (`tcp://HOST:PORT` or `uds:PATH`).
    pub fn status_addr<S: Into<String>>(mut self, addr: S) -> Self {
        self.status_addr = Some(addr.into());
        self
    }

    /// Build the context; binds and spawns the status server when a
    /// status address was given (caller keeps the handle alive for the
    /// run, then [`StatusServer::stop`]s it).
    pub fn build(self) -> Result<(Obs, Option<StatusServer>)> {
        let recorder: Box<dyn Recorder> = match &self.events_out {
            Some(path) => Box::new(
                JsonlRecorder::create(path)
                    .with_context(|| format!("opening event journal {}", path.display()))?,
            ),
            None => Box::new(NullRecorder),
        };
        let metrics = Arc::new(Metrics::default());
        let (status, server) = match &self.status_addr {
            Some(addr) => {
                let listener = NetListener::bind(addr)
                    .with_context(|| format!("binding status endpoint {addr}"))?;
                let state = Arc::new(StatusState::new(metrics.clone()));
                let server = StatusServer::spawn(listener, state.clone())?;
                (Some(state), Some(server))
            }
            None => (None, None),
        };
        let obs = Obs {
            core: Some(Arc::new(Core {
                recorder,
                metrics,
                spans: Arc::new(SpanSink::new()),
                status,
                metrics_out: self.metrics_out,
                trace_out: self.trace_out,
            })),
        };
        Ok((obs, server))
    }
}

/// Open a nestable profiling span: `let sp = span!("gather", obs);`
/// then `let ns = sp.done();`. Sugar for [`Obs::span`]; the guard
/// always measures wall time and only records when obs is on.
#[macro_export]
macro_rules! span {
    ($name:literal, $obs:expr) => {
        $obs.span($name)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_context_is_inert_and_cheap_to_clone() {
        let obs = Obs::off();
        assert!(!obs.enabled());
        obs.add("x", 3);
        obs.gauge("g", 1.5);
        obs.observe_ns("h", 10);
        obs.emit(Event::SweepJobDone { id: "aa".into(), ns: 1 });
        let sp = obs.span("phase");
        let _ns = sp.done();
        assert!(obs.metrics().is_none());
        assert!(obs.status().is_none());
        let clone = obs.clone();
        assert!(!clone.enabled());
        obs.finish().unwrap();
    }

    #[test]
    fn recording_context_feeds_registry_and_spans() {
        let obs = Obs::recording(Box::new(NullRecorder));
        assert!(obs.enabled());
        obs.add("wire_up_bytes", 7);
        obs.add("wire_up_bytes", 5);
        let sp = span!("gather", obs);
        std::thread::sleep(std::time::Duration::from_millis(1));
        let ns = sp.done();
        assert!(ns > 0);
        let m = obs.metrics().unwrap();
        assert_eq!(m.counter("wire_up_bytes").get(), 12);
        assert_eq!(m.histogram("gather").count(), 1);
        obs.finish().unwrap();
    }
}
