//! Nestable wall-clock spans feeding the histogram registry and the
//! Chrome-trace exporter.
//!
//! A [`SpanGuard`] always measures — [`SpanGuard::done`] returns the
//! elapsed nanoseconds so the `TrainTrace` phase fields
//! (`broadcast_ns` / `gather_ns` / `aggregate_ns`) stay populated even
//! with obs off — but it only *records* (histogram sample + trace
//! event) when the owning [`Obs`](crate::obs::Obs) is enabled.
//! Nesting needs no explicit parent tracking: Chrome's `trace_event`
//! viewer nests complete (`"ph":"X"`) events by time containment per
//! thread lane, and each OS thread gets a stable lane id here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::obs::Obs;

/// One closed span: name, start offset from the sink's epoch, wall
/// duration, and the recording thread's lane id.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRec {
    pub name: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub tid: u64,
}

/// Soft cap on retained span records: beyond it, spans still measure
/// and feed histograms but are dropped from the Chrome-trace buffer
/// (counted in [`SpanSink::dropped`]) so unbounded sweeps cannot
/// exhaust memory.
pub const SPAN_CAP: usize = 1 << 20;

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn current_tid() -> u64 {
    TID.with(|t| *t)
}

/// Append-only buffer of closed spans, timed against one process
/// epoch so records from every thread share a timeline.
pub struct SpanSink {
    epoch: Instant,
    recs: Mutex<Vec<SpanRec>>,
    dropped: AtomicU64,
}

impl Default for SpanSink {
    fn default() -> SpanSink {
        SpanSink::new()
    }
}

impl SpanSink {
    pub fn new() -> SpanSink {
        SpanSink { epoch: Instant::now(), recs: Mutex::new(Vec::new()), dropped: AtomicU64::new(0) }
    }

    /// Record a closed span. `start` may predate the sink's epoch (a
    /// guard opened before the sink existed); it saturates to offset 0.
    pub fn record(&self, name: &'static str, start: Instant, dur_ns: u64) {
        let start_ns = start.saturating_duration_since(self.epoch).as_nanos() as u64;
        let mut recs = self.recs.lock().expect("span sink poisoned");
        if recs.len() >= SPAN_CAP {
            drop(recs);
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        recs.push(SpanRec { name, start_ns, dur_ns, tid: current_tid() });
    }

    /// Copy of everything recorded so far.
    pub fn snapshot(&self) -> Vec<SpanRec> {
        self.recs.lock().expect("span sink poisoned").clone()
    }

    /// Spans dropped after [`SPAN_CAP`] was reached.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// RAII span: opened by [`Obs::span`] / [`span!`](crate::span), closed
/// by [`done`](SpanGuard::done) (returning elapsed ns) or implicitly
/// on drop.
pub struct SpanGuard<'a> {
    obs: &'a Obs,
    name: &'static str,
    start: Instant,
    finished: bool,
}

impl<'a> SpanGuard<'a> {
    pub(crate) fn enter(obs: &'a Obs, name: &'static str) -> SpanGuard<'a> {
        SpanGuard { obs, name, start: Instant::now(), finished: false }
    }

    /// Close the span and return its wall duration in nanoseconds —
    /// the value the caller folds into `TrainTrace` phase counters,
    /// keeping those fields span-derived on and off.
    pub fn done(mut self) -> u64 {
        self.finished = true;
        let ns = self.start.elapsed().as_nanos() as u64;
        self.obs.record_span(self.name, self.start, ns);
        ns
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if !self.finished {
            let ns = self.start.elapsed().as_nanos() as u64;
            self.obs.record_span(self.name, self.start, ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::NullRecorder;

    #[test]
    fn spans_nest_and_share_a_timeline() {
        let obs = Obs::recording(Box::new(NullRecorder));
        {
            let outer = obs.span("iteration");
            {
                let inner = obs.span("gather");
                std::thread::sleep(std::time::Duration::from_millis(1));
                let ns = inner.done();
                assert!(ns >= 1_000_000, "inner span under-measured: {ns}ns");
            }
            drop(outer); // implicit close path
        }
        let m = obs.metrics().unwrap();
        assert_eq!(m.histogram("iteration").count(), 1);
        assert_eq!(m.histogram("gather").count(), 1);
        // The guard fed the span sink too: both records present, and
        // the outer span contains the inner one in time.
        let core_spans = {
            // Reach the sink through a fresh snapshot via export-side
            // accessors: Obs has no public sink getter, so check the
            // histogram side here and containment in export tests.
            m.histogram("iteration").sum() >= m.histogram("gather").sum()
        };
        assert!(core_spans, "outer span shorter than inner");
    }

    #[test]
    fn sink_records_offsets_and_lane_ids() {
        let sink = SpanSink::new();
        let t0 = Instant::now();
        sink.record("a", t0, 10);
        sink.record("b", t0, 20);
        let recs = sink.snapshot();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].name, "a");
        assert_eq!(recs[0].tid, recs[1].tid, "same thread, same lane");
        assert_eq!(sink.dropped(), 0);
        // Pre-epoch starts saturate instead of panicking.
        let sink2 = SpanSink::new();
        sink2.record("pre", t0, 5);
        assert_eq!(sink2.snapshot()[0].start_ns, 0);
    }
}
