//! Run-end exporters: metrics snapshot JSON and Chrome-trace
//! (`trace_event` format) span dumps.
//!
//! The Chrome trace loads directly into `chrome://tracing`,
//! <https://ui.perfetto.dev>, or `speedscope` for flamegraph viewing:
//! every span is a complete (`"ph":"X"`) event with microsecond
//! timestamps on the sink's shared epoch, one lane per OS thread, so
//! nesting falls out of time containment.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context as _, Result};

use crate::obs::metrics::Metrics;
use crate::obs::spans::SpanSink;
use crate::util::json::Json;

/// Serialize the span sink as Chrome `trace_event` JSON.
pub fn chrome_trace_json(sink: &SpanSink) -> Json {
    let mut events = Vec::new();
    for rec in sink.snapshot() {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(rec.name.to_string()));
        o.insert("ph".to_string(), Json::Str("X".to_string()));
        o.insert("ts".to_string(), Json::Num(rec.start_ns as f64 / 1_000.0));
        o.insert("dur".to_string(), Json::Num(rec.dur_ns as f64 / 1_000.0));
        o.insert("pid".to_string(), Json::Num(1.0));
        o.insert("tid".to_string(), Json::Num(rec.tid as f64));
        events.push(Json::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(events));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    if sink.dropped() > 0 {
        top.insert("droppedSpans".to_string(), Json::Num(sink.dropped() as f64));
    }
    Json::Obj(top)
}

/// Write the span sink as a Chrome-trace file.
pub fn write_chrome_trace<P: AsRef<Path>>(sink: &SpanSink, path: P) -> Result<()> {
    let path = path.as_ref();
    let mut text = chrome_trace_json(sink).to_string();
    text.push('\n');
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))
}

/// Write the metrics registry snapshot as pretty JSON (dumped next to
/// `results.csv` / `node_trace.csv` at run end).
pub fn write_metrics<P: AsRef<Path>>(metrics: &Metrics, path: P) -> Result<()> {
    let path = path.as_ref();
    let mut text = metrics.snapshot().to_pretty_string();
    text.push('\n');
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn chrome_trace_has_complete_events_in_microseconds() {
        let sink = SpanSink::new();
        let epoch = Instant::now();
        sink.record("gather", epoch, 2_000);
        let j = chrome_trace_json(&sink);
        let evs = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(evs[0].get("name").and_then(Json::as_str), Some("gather"));
        assert_eq!(evs[0].get("dur").and_then(Json::as_f64), Some(2.0));
        assert!(evs[0].get("tid").is_some());
        assert!(j.get("droppedSpans").is_none());
    }

    #[test]
    fn exporters_write_parseable_files() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let trace_path = dir.join(format!("lad_trace_{pid}.json"));
        let metrics_path = dir.join(format!("lad_metrics_{pid}.json"));
        let sink = SpanSink::new();
        sink.record("aggregate", Instant::now(), 500);
        write_chrome_trace(&sink, &trace_path).unwrap();
        let m = Metrics::default();
        m.counter("frames_encoded").add(4);
        write_metrics(&m, &metrics_path).unwrap();
        let t = crate::util::json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
        assert_eq!(t.get("traceEvents").and_then(Json::as_arr).map(<[Json]>::len), Some(1));
        let ms = crate::util::json::parse(&std::fs::read_to_string(&metrics_path).unwrap());
        let ms = ms.unwrap();
        assert_eq!(
            ms.get("counters").and_then(|c| c.get("frames_encoded")).and_then(Json::as_f64),
            Some(4.0)
        );
        let _ = std::fs::remove_file(&trace_path);
        let _ = std::fs::remove_file(&metrics_path);
    }
}
