//! Terminal client for the status endpoint's `WATCH` subscribe mode.
//!
//! `lad status --watch tcp://…` connects to a live run's status
//! endpoint, sends the one-line `WATCH` subscribe request, and renders
//! each pushed delta as a single terminal line — iteration progress,
//! current phase, cumulative per-phase wall time, anomaly counter, and
//! a compact roster — with indented notes whenever the roster changes
//! (retire / rejoin / deadline miss). The bare snapshot mode (`nc` or
//! `lad status` without `--watch`) stays available for one-shot reads;
//! this module is the streaming side.

use std::io::Write;

use anyhow::{Context as _, Result};

use crate::net::transport::connect;
use crate::obs::status::DeviceStatus;
use crate::util::json::{self, Json};

/// Decode the `roster` array of a delta into typed entries.
fn roster_of(delta: &Json) -> Vec<DeviceStatus> {
    delta
        .get("roster")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .map(|d| DeviceStatus {
                    dead: matches!(d.get("dead"), Some(Json::Bool(true))),
                    miss_streak: d.get("miss_streak").and_then(Json::as_f64).unwrap_or(0.0)
                        as u64,
                    epoch: d.get("epoch").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                })
                .collect()
        })
        .unwrap_or_default()
}

fn ms(ns: f64) -> f64 {
    ns / 1e6
}

/// Render one delta line (plus roster-change notes against the
/// previous delta's roster, when given).
pub fn render_delta(
    delta: &Json,
    prev: Option<&[DeviceStatus]>,
    out: &mut dyn Write,
) -> Result<()> {
    let num = |k: &str| delta.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let pns = |k: &str| {
        delta.get("phase_ns").and_then(|p| p.get(k)).and_then(Json::as_f64).unwrap_or(0.0)
    };
    let phase = delta.get("phase").and_then(Json::as_str).unwrap_or("-");
    let roster = roster_of(delta);
    let tags: Vec<String> = roster
        .iter()
        .map(|d| {
            if d.dead {
                "dead".to_string()
            } else if d.miss_streak > 0 {
                format!("miss:{}", d.miss_streak)
            } else {
                "ok".to_string()
            }
        })
        .collect();
    writeln!(
        out,
        "iter {:>6}/{}  phase={}  anomalies={}  broadcast={:.1}ms gather={:.1}ms \
         aggregate={:.1}ms  roster=[{}]",
        num("iter") as u64,
        num("total_iters") as u64,
        phase,
        num("anomalies") as u64,
        ms(pns("broadcast_ns")),
        ms(pns("gather_ns")),
        ms(pns("aggregate_ns")),
        tags.join(" ")
    )?;
    if let Some(prev) = prev {
        for (i, (p, c)) in prev.iter().zip(&roster).enumerate() {
            if !p.dead && c.dead {
                writeln!(out, "  device {i} retired")?;
            }
            if p.dead && !c.dead {
                writeln!(out, "  device {i} rejoined (epoch {})", c.epoch)?;
            }
            if c.miss_streak > p.miss_streak {
                writeln!(out, "  device {i} missed a deadline (streak {})", c.miss_streak)?;
            }
        }
    }
    Ok(())
}

/// Subscribe to `addr` and render deltas to `out` until the server
/// closes the stream (run ended) — or, with `count` set, until that
/// many deltas have been rendered (the CI smoke shape). Returns the
/// number of deltas seen.
pub fn run_watch(addr: &str, out: &mut dyn Write, count: Option<u64>) -> Result<u64> {
    let mut conn =
        connect(addr).with_context(|| format!("connecting to status endpoint {addr}"))?;
    conn.send_frame(b"WATCH\n").context("sending WATCH subscribe line")?;
    let mut prev: Option<Vec<DeviceStatus>> = None;
    let mut seen = 0u64;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    'stream: loop {
        let n = conn.recv_raw(&mut chunk).context("reading watch stream")?;
        if n == 0 {
            break; // run ended, server closed the connection
        }
        buf.extend_from_slice(&chunk[..n]);
        while let Some(nl) = buf.iter().position(|&b| b == b'\n') {
            let raw: Vec<u8> = buf.drain(..=nl).collect();
            let line = String::from_utf8_lossy(&raw[..nl]);
            if line.trim().is_empty() {
                continue;
            }
            let delta = json::parse(&line)
                .with_context(|| format!("unparseable delta line: {line}"))?;
            render_delta(&delta, prev.as_deref(), out)?;
            prev = Some(roster_of(&delta));
            seen += 1;
            if count.is_some_and(|c| seen >= c) {
                break 'stream;
            }
        }
    }
    Ok(seen)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::NetListener;
    use crate::obs::metrics::Metrics;
    use crate::obs::status::{StatusServer, StatusState};
    use std::sync::Arc;
    use std::time::Duration;

    fn delta(iter: u64, roster: &[(bool, u64, u64)]) -> Json {
        use std::collections::BTreeMap;
        let mut top = BTreeMap::new();
        top.insert("iter".to_string(), Json::Num(iter as f64));
        top.insert("total_iters".to_string(), Json::Num(40.0));
        top.insert("phase".to_string(), Json::Str("gather".into()));
        top.insert("anomalies".to_string(), Json::Num(1.0));
        let mut p = BTreeMap::new();
        p.insert("broadcast_ns".to_string(), Json::Num(1_500_000.0));
        p.insert("gather_ns".to_string(), Json::Num(2_000_000.0));
        p.insert("aggregate_ns".to_string(), Json::Num(500_000.0));
        top.insert("phase_ns".to_string(), Json::Obj(p));
        let devs = roster
            .iter()
            .map(|&(dead, miss, epoch)| {
                let mut o = BTreeMap::new();
                o.insert("dead".to_string(), Json::Bool(dead));
                o.insert("miss_streak".to_string(), Json::Num(miss as f64));
                o.insert("epoch".to_string(), Json::Num(epoch as f64));
                Json::Obj(o)
            })
            .collect();
        top.insert("roster".to_string(), Json::Arr(devs));
        Json::Obj(top)
    }

    #[test]
    fn render_flags_roster_transitions() {
        let mut out = Vec::new();
        let d0 = delta(5, &[(false, 0, 0), (false, 0, 0)]);
        render_delta(&d0, None, &mut out).unwrap();
        let prev = roster_of(&d0);
        let d1 = delta(6, &[(false, 0, 0), (true, 3, 0)]);
        render_delta(&d1, Some(&prev), &mut out).unwrap();
        let prev = roster_of(&d1);
        let d2 = delta(7, &[(false, 0, 0), (false, 0, 1)]);
        render_delta(&d2, Some(&prev), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("iter      5/40"), "{text}");
        assert!(text.contains("broadcast=1.5ms"), "{text}");
        assert!(text.contains("roster=[ok dead]"), "{text}");
        assert!(text.contains("device 1 retired"), "{text}");
        assert!(text.contains("device 1 rejoined (epoch 1)"), "{text}");
    }

    #[test]
    fn watch_client_streams_deltas_from_a_live_server() {
        let state = Arc::new(StatusState::new(Arc::new(Metrics::default())));
        state.begin_run("watch-test", 40, 2);
        state.set_iter(1);
        let listener = NetListener::bind("tcp://127.0.0.1:0").unwrap();
        let server = StatusServer::spawn(listener, state.clone()).unwrap();
        let mutator = {
            let state = state.clone();
            std::thread::spawn(move || {
                for i in 2..=4 {
                    std::thread::sleep(Duration::from_millis(40));
                    state.set_iter(i);
                }
            })
        };
        let mut out = Vec::new();
        let seen = run_watch(server.addr(), &mut out, Some(3)).unwrap();
        assert_eq!(seen, 3);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("iter      1/40"), "{text}");
        assert!(text.contains("iter      2/40"), "{text}");
        mutator.join().unwrap();
        server.stop();
    }
}
