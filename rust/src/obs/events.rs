//! Typed events and the JSONL journal sink.
//!
//! Every event that used to be a silent `continue`, a bare
//! `anomalies += 1`, or a free-form `eprintln!` in the leader / worker
//! loops is a variant here, carrying the device, iteration, and reason
//! that the old paths dropped. See the module-level schema table in
//! [`crate::obs`].

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::util::json::Json;

/// A structured observability event. Serialized as one JSONL line with
/// `seq` / `ms` envelope fields added by the sink.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A device crossed the miss-streak threshold (or its link died)
    /// and was removed from the active roster.
    DeviceRetired { device: usize, iter: u64, reason: String },
    /// A late `Join` was activated into a retired slot; `epoch` is the
    /// slot's new connection epoch.
    DeviceRejoined { device: usize, iter: u64, epoch: u64 },
    /// A device missed a gather deadline; `streak` counts consecutive
    /// misses (retirement fires at `net::MISS_RETIRE_STREAK`).
    DeadlineMiss { device: usize, iter: u64, streak: u64 },
    /// An upload was discarded by the leader's epoch-tagged reader —
    /// either a ghost from a dead connection epoch or a stale
    /// iteration (`upload_iter < iter`). `epoch` is the connection
    /// epoch the upload arrived on, so replay can tell a late-honest
    /// upload (live epoch, old iteration) from a replaced-connection
    /// ghost (dead epoch).
    StaleUploadDiscarded { device: usize, iter: u64, upload_iter: u64, epoch: u64, reason: String },
    /// A periodic checkpoint was cut: file size and wall time of the
    /// atomic tmp+rename write.
    CheckpointWritten { iter: u64, bytes: u64, ns: u64 },
    /// A leader warm-restarted from a checkpoint (standby takeover or
    /// `--resume-from`).
    LeaderFailover { iter: u64, checkpoint: String },
    /// Per-iteration Byzantine role rotation drew a fresh honest/byz
    /// split.
    ByzantineRoleDrawn { iter: u64, byzantine: Vec<usize> },
    /// A sweep job finished; `id` is the content-addressed job id.
    SweepJobDone { id: String, ns: u64 },
    /// A worker's redial loop failed an attempt against the reconnect
    /// address (the reason used to die in a local `anyhow::Error`).
    WorkerRedial { device: usize, attempt: u64, reason: String },
}

impl Event {
    /// Stable snake_case discriminator used as the `"event"` field.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::DeviceRetired { .. } => "device_retired",
            Event::DeviceRejoined { .. } => "device_rejoined",
            Event::DeadlineMiss { .. } => "deadline_miss",
            Event::StaleUploadDiscarded { .. } => "stale_upload_discarded",
            Event::CheckpointWritten { .. } => "checkpoint_written",
            Event::LeaderFailover { .. } => "leader_failover",
            Event::ByzantineRoleDrawn { .. } => "byzantine_role_drawn",
            Event::SweepJobDone { .. } => "sweep_job_done",
            Event::WorkerRedial { .. } => "worker_redial",
        }
    }

    /// Payload as a JSON object (discriminator included, no envelope).
    pub fn to_json(&self) -> Json {
        fn num(o: &mut BTreeMap<String, Json>, k: &str, v: u64) {
            o.insert(k.to_string(), Json::Num(v as f64));
        }
        let mut o = BTreeMap::new();
        o.insert("event".to_string(), Json::Str(self.kind().to_string()));
        match self {
            Event::DeviceRetired { device, iter, reason } => {
                num(&mut o, "device", *device as u64);
                num(&mut o, "iter", *iter);
                o.insert("reason".into(), Json::Str(reason.clone()));
            }
            Event::DeviceRejoined { device, iter, epoch } => {
                num(&mut o, "device", *device as u64);
                num(&mut o, "iter", *iter);
                num(&mut o, "epoch", *epoch);
            }
            Event::DeadlineMiss { device, iter, streak } => {
                num(&mut o, "device", *device as u64);
                num(&mut o, "iter", *iter);
                num(&mut o, "streak", *streak);
            }
            Event::StaleUploadDiscarded { device, iter, upload_iter, epoch, reason } => {
                num(&mut o, "device", *device as u64);
                num(&mut o, "iter", *iter);
                num(&mut o, "upload_iter", *upload_iter);
                num(&mut o, "epoch", *epoch);
                o.insert("reason".into(), Json::Str(reason.clone()));
            }
            Event::CheckpointWritten { iter, bytes, ns } => {
                num(&mut o, "iter", *iter);
                num(&mut o, "bytes", *bytes);
                num(&mut o, "ns", *ns);
            }
            Event::LeaderFailover { iter, checkpoint } => {
                num(&mut o, "iter", *iter);
                o.insert("checkpoint".into(), Json::Str(checkpoint.clone()));
            }
            Event::ByzantineRoleDrawn { iter, byzantine } => {
                num(&mut o, "iter", *iter);
                let devs = byzantine.iter().map(|d| Json::Num(*d as f64)).collect();
                o.insert("byzantine".into(), Json::Arr(devs));
            }
            Event::SweepJobDone { id, ns } => {
                o.insert("id".into(), Json::Str(id.clone()));
                num(&mut o, "ns", *ns);
            }
            Event::WorkerRedial { device, attempt, reason } => {
                num(&mut o, "device", *device as u64);
                num(&mut o, "attempt", *attempt);
                o.insert("reason".into(), Json::Str(reason.clone()));
            }
        }
        Json::Obj(o)
    }

    /// Parse an event back from a JSON object (envelope fields are
    /// ignored). Returns `None` on an unknown discriminator or missing
    /// field — journal readers skip rather than fail.
    pub fn from_json(j: &Json) -> Option<Event> {
        let kind = j.get("event")?.as_str()?;
        let num = |k: &str| j.get(k).and_then(Json::as_f64).map(|v| v as u64);
        let s = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
        Some(match kind {
            "device_retired" => Event::DeviceRetired {
                device: num("device")? as usize,
                iter: num("iter")?,
                reason: s("reason")?,
            },
            "device_rejoined" => Event::DeviceRejoined {
                device: num("device")? as usize,
                iter: num("iter")?,
                epoch: num("epoch")?,
            },
            "deadline_miss" => Event::DeadlineMiss {
                device: num("device")? as usize,
                iter: num("iter")?,
                streak: num("streak")?,
            },
            "stale_upload_discarded" => Event::StaleUploadDiscarded {
                device: num("device")? as usize,
                iter: num("iter")?,
                upload_iter: num("upload_iter")?,
                // Pre-epoch journals lack the field; default to 0 so
                // old runs stay replayable.
                epoch: num("epoch").unwrap_or(0),
                reason: s("reason")?,
            },
            "checkpoint_written" => Event::CheckpointWritten {
                iter: num("iter")?,
                bytes: num("bytes")?,
                ns: num("ns")?,
            },
            "leader_failover" => Event::LeaderFailover {
                iter: num("iter")?,
                checkpoint: s("checkpoint")?,
            },
            "byzantine_role_drawn" => Event::ByzantineRoleDrawn {
                iter: num("iter")?,
                byzantine: j
                    .get("byzantine")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_f64().map(|v| v as usize))
                    .collect::<Option<Vec<_>>>()?,
            },
            "sweep_job_done" => Event::SweepJobDone { id: s("id")?, ns: num("ns")? },
            "worker_redial" => Event::WorkerRedial {
                device: num("device")? as usize,
                attempt: num("attempt")?,
                reason: s("reason")?,
            },
            _ => return None,
        })
    }
}

/// Event sink. Implementations must be cheap and must never panic out
/// of a training loop — telemetry failures are swallowed or surfaced
/// at `flush`, not mid-iteration.
pub trait Recorder: Send + Sync {
    fn record(&self, ev: &Event);
    /// Flush buffered output; called once by [`crate::obs::Obs::finish`].
    fn flush(&self) -> Result<()> {
        Ok(())
    }
}

/// Discards every event (enabled obs with metrics/spans but no
/// journal).
#[derive(Debug, Default)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    fn record(&self, _ev: &Event) {}
}

/// Number of independent file-handle shards in [`JsonlRecorder`].
/// Writers hash by sequence number, so concurrent emitters (pool
/// threads, worker threads, the leader loop) rarely contend on one
/// mutex; `O_APPEND` keeps each line append atomic regardless of which
/// shard wrote it.
pub const JOURNAL_SHARDS: usize = 4;

/// Lock-sharded JSONL sink writing `events.jsonl`-style journals.
///
/// Each event becomes exactly one line, written with a single
/// `write_all` on an `O_APPEND` handle — appends are atomic at the
/// kernel level, so lines from different shards interleave but never
/// tear. `seq` is process-monotonic; sort by it to recover emission
/// order.
pub struct JsonlRecorder {
    shards: Vec<Mutex<File>>,
    seq: AtomicU64,
    epoch: Instant,
}

impl JsonlRecorder {
    /// Create (truncating any previous journal at `path`) and open the
    /// shard handles.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<JsonlRecorder> {
        let path = path.as_ref();
        // A fresh run starts a fresh journal; O_APPEND and O_TRUNC
        // don't compose in OpenOptions, so drop any stale file first.
        let _ = std::fs::remove_file(path);
        let first = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut shards = Vec::with_capacity(JOURNAL_SHARDS);
        for _ in 1..JOURNAL_SHARDS {
            shards.push(Mutex::new(first.try_clone().context("cloning journal handle")?));
        }
        shards.push(Mutex::new(first));
        Ok(JsonlRecorder { shards, seq: AtomicU64::new(0), epoch: Instant::now() })
    }
}

impl Recorder for JsonlRecorder {
    fn record(&self, ev: &Event) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut obj = match ev.to_json() {
            Json::Obj(o) => o,
            other => {
                let mut o = BTreeMap::new();
                o.insert("payload".to_string(), other);
                o
            }
        };
        obj.insert("seq".to_string(), Json::Num(seq as f64));
        obj.insert("ms".to_string(), Json::Num(self.epoch.elapsed().as_millis() as f64));
        let mut line = Json::Obj(obj).to_string();
        line.push('\n');
        let shard = &self.shards[seq as usize % self.shards.len()];
        if let Ok(mut f) = shard.lock() {
            // One write(2) per fully-formed line: atomic under O_APPEND.
            let _ = f.write_all(line.as_bytes());
        }
    }

    fn flush(&self) -> Result<()> {
        for shard in &self.shards {
            if let Ok(mut f) = shard.lock() {
                f.flush().context("flushing event journal")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample_events() -> Vec<Event> {
        vec![
            Event::DeviceRetired { device: 3, iter: 17, reason: "miss streak 3".into() },
            Event::DeviceRejoined { device: 3, iter: 22, epoch: 2 },
            Event::DeadlineMiss { device: 5, iter: 9, streak: 1 },
            Event::StaleUploadDiscarded {
                device: 1,
                iter: 10,
                upload_iter: 8,
                epoch: 1,
                reason: "ghost epoch".into(),
            },
            Event::CheckpointWritten { iter: 20, bytes: 4096, ns: 1_500_000 },
            Event::LeaderFailover { iter: 21, checkpoint: "ckpt.bin".into() },
            Event::ByzantineRoleDrawn { iter: 4, byzantine: vec![0, 6] },
            Event::SweepJobDone { id: "6d71af87f6a38e78".into(), ns: 9_999 },
            Event::WorkerRedial { device: 2, attempt: 1, reason: "connection refused".into() },
        ]
    }

    #[test]
    fn event_json_round_trips() {
        for ev in sample_events() {
            let j = ev.to_json();
            let back = Event::from_json(&j).expect("round trip");
            assert_eq!(ev, back, "round trip mismatch for {}", ev.kind());
        }
    }

    #[test]
    fn jsonl_sink_writes_sorted_reconstructible_lines() {
        let path = std::env::temp_dir().join(format!("lad_obs_{}.jsonl", std::process::id()));
        let rec = JsonlRecorder::create(&path).unwrap();
        let evs = sample_events();
        for ev in &evs {
            rec.record(ev);
        }
        rec.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<(u64, Event)> = text
            .lines()
            .map(|l| {
                let j = json::parse(l).expect("valid json line");
                let seq = j.get("seq").and_then(Json::as_f64).expect("seq") as u64;
                assert!(j.get("ms").is_some(), "missing ms envelope");
                (seq, Event::from_json(&j).expect("typed event"))
            })
            .collect();
        lines.sort_by_key(|(seq, _)| *seq);
        let seqs: Vec<u64> = lines.iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, (0..evs.len() as u64).collect::<Vec<_>>(), "seq not monotonic");
        let got: Vec<Event> = lines.into_iter().map(|(_, e)| e).collect();
        assert_eq!(got, evs);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn create_truncates_a_stale_journal() {
        let path = std::env::temp_dir().join(format!("lad_obs_trunc_{}.jsonl", std::process::id()));
        std::fs::write(&path, "stale line\n").unwrap();
        let rec = JsonlRecorder::create(&path).unwrap();
        rec.record(&Event::SweepJobDone { id: "x".into(), ns: 1 });
        rec.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("stale"), "old journal leaked through: {text}");
        assert_eq!(text.lines().count(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
