//! Wall-clock timing helpers.

use std::time::Instant;

/// Scope timer: `let t = Timer::start(); ...; t.elapsed_ms()`.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
        assert!(t.elapsed_us() > t.elapsed_ms());
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
