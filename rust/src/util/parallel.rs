//! Zero-dependency data-parallel execution engine (no rayon).
//!
//! Two execution strategies share one chunking discipline:
//!
//! * **Scoped spawns** (the free functions [`par_map`], [`par_map_mut`],
//!   [`par_for`], [`par_chunks_mut`]): each call splits its input into at
//!   most [`Parallelism::threads`] contiguous chunks, spawns one scoped
//!   worker per extra chunk, processes the first chunk on the calling
//!   thread, and joins in order. No state persists between calls.
//! * **Persistent pool** ([`Pool`]): `Pool::new(threads)` parks `threads−1`
//!   workers on a shared injector once; every subsequent `pool.par_map(...)`
//!   call dispatches chunk tasks to the already-running workers, so the
//!   per-call ~10µs spawn cost disappears from the many-small-iteration
//!   regime. The caller thread claims chunks too (help-first join), which
//!   also makes nested dispatch deadlock-free. `Pool::scoped(par)` preserves
//!   the scoped-spawn engine behind the same method API.
//!
//! Both strategies produce identical chunk boundaries and apply the closure
//! to items in the same order, so swapping one for the other can never
//! change a result.
//!
//! A third layer composes the pool for nested fan-outs: [`Pool::budgeted`]
//! builds a **two-level thread budget** — one shared worker set, an outer
//! fan-out dispatched onto it, and per-branch inner handles
//! ([`Pool::borrow`]) whose chunking width is capped so `branches ×
//! inner_threads` stays at the total instead of multiplying past it. See
//! [`PoolBudget`].
//!
//! # Determinism contract
//!
//! Every primitive here is a *pure scheduler*: the closure is applied to the
//! same items, in the same per-item state, regardless of the thread count
//! or execution strategy. Callers keep bit-identical results across
//! `threads = 1` and `threads = N` by never sharing mutable state between
//! items — in particular, seeded RNG streams must be pre-split per item
//! ([`crate::util::rng::Rng::split`]) rather than shared.
//! `rust/tests/parallel_determinism.rs` and `rust/tests/fuzz_determinism.rs`
//! pin this contract end-to-end for the LAD / Com-LAD training loop.
//!
//! # Panics
//!
//! A panic inside a worker closure is propagated to the caller: the scoped
//! engine panics with a `"... worker panicked"` message, the pool resumes
//! the original payload on the dispatching thread.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// How many worker threads a parallel stage may use.
///
/// `Parallelism` is a plain `Copy` value (not a pool): threads are scoped to
/// each call, so nesting and concurrent use from multiple tests are safe.
/// `0` means "all available cores" at construction time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
}

impl Parallelism {
    /// `threads` workers; `0` resolves to [`available_threads`].
    pub fn new(threads: usize) -> Self {
        Parallelism { threads: if threads == 0 { available_threads() } else { threads } }
    }

    /// All available cores.
    pub fn auto() -> Self {
        Parallelism::new(0)
    }

    /// Exactly one thread (the calling one) — the serial fallback.
    pub fn serial() -> Self {
        Parallelism { threads: 1 }
    }

    /// Resolved worker count (always ≥ 1).
    pub fn threads(&self) -> usize {
        self.threads.max(1)
    }

    /// True when no worker threads would be spawned.
    pub fn is_serial(&self) -> bool {
        self.threads() == 1
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::auto()
    }
}

/// Cores visible to this process (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel, order-preserving map over a shared slice.
///
/// `f(index, item)` runs once per item; the result vector matches the input
/// order exactly.
pub fn par_map<T, R, F>(par: Parallelism, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = par.threads().min(items.len());
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let mut out: Vec<Vec<R>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::with_capacity(threads - 1);
        for (c, slice) in items.chunks(chunk).enumerate().skip(1) {
            handles.push(scope.spawn(move || {
                slice
                    .iter()
                    .enumerate()
                    .map(|(i, t)| f(c * chunk + i, t))
                    .collect::<Vec<R>>()
            }));
        }
        // first chunk on the calling thread, overlapping the workers
        out.push(items[..chunk].iter().enumerate().map(|(i, t)| f(i, t)).collect());
        for h in handles {
            out.push(h.join().expect("par_map worker panicked"));
        }
    });
    out.into_iter().flatten().collect()
}

/// Parallel, order-preserving map with exclusive access to each item.
///
/// Items are `&mut` — the canonical use is one pre-split RNG or scratch
/// buffer per device, mutated in place while producing a result.
pub fn par_map_mut<T, R, F>(par: Parallelism, items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let threads = par.threads().min(items.len());
    if threads <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let mut out: Vec<Vec<R>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let (first, mut rest) = items.split_at_mut(chunk);
        let mut handles = Vec::with_capacity(threads - 1);
        let mut offset = chunk;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let start = offset;
            offset += take;
            handles.push(scope.spawn(move || {
                head.iter_mut()
                    .enumerate()
                    .map(|(i, t)| f(start + i, t))
                    .collect::<Vec<R>>()
            }));
        }
        out.push(first.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect());
        for h in handles {
            out.push(h.join().expect("par_map_mut worker panicked"));
        }
    });
    out.into_iter().flatten().collect()
}

/// Parallel for over an index range `0..n`.
pub fn par_for<F>(par: Parallelism, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = par.threads().min(n);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::with_capacity(threads - 1);
        let mut start = chunk;
        while start < n {
            let end = (start + chunk).min(n);
            handles.push(scope.spawn(move || {
                for i in start..end {
                    f(i);
                }
            }));
            start = end;
        }
        for i in 0..chunk {
            f(i);
        }
        for h in handles {
            h.join().expect("par_for worker panicked");
        }
    });
}

/// Parallel for over disjoint `chunk_len`-sized windows of a mutable slice —
/// the primitive behind row-parallel matrix fills (`chunk_len` = row width).
///
/// `f(chunk_index, chunk)` receives the same windows `data.chunks_mut(
/// chunk_len)` would yield, in chunk order within each worker; the final
/// window may be shorter when `chunk_len` does not divide `data.len()`.
pub fn par_chunks_mut<T, F>(par: Parallelism, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = par.threads().min(n_chunks);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    // whole chunks per worker so no window straddles a thread boundary
    let per_thread = n_chunks.div_ceil(threads);
    let block = per_thread * chunk_len;
    std::thread::scope(|scope| {
        let f = &f;
        let split = block.min(data.len());
        let (first, mut rest) = data.split_at_mut(split);
        let mut handles = Vec::with_capacity(threads - 1);
        let mut next_chunk = per_thread;
        while !rest.is_empty() {
            let take = block.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let start = next_chunk;
            next_chunk += head.len().div_ceil(chunk_len);
            handles.push(scope.spawn(move || {
                for (i, c) in head.chunks_mut(chunk_len).enumerate() {
                    f(start + i, c);
                }
            }));
        }
        for (i, c) in first.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        for h in handles {
            h.join().expect("par_chunks_mut worker panicked");
        }
    });
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

/// Lifetime-erased pointer to a dispatch closure.
///
/// SAFETY: [`Pool::dispatch`] blocks until every task index of its batch has
/// completed, so the referent strictly outlives every dereference; workers
/// holding the batch `Arc` after completion only touch its atomics, never
/// this pointer.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync + 'static));

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> TaskRef {
    // SAFETY: only lengthens the trait object's lifetime bound; the pointer
    // is dereferenced exclusively while the dispatching call is blocked in
    // `Batch::wait` (see `TaskRef`).
    let long: &'static (dyn Fn(usize) + Sync + 'static) = unsafe {
        std::mem::transmute::<
            &'a (dyn Fn(usize) + Sync + 'a),
            &'static (dyn Fn(usize) + Sync + 'static),
        >(f)
    };
    TaskRef(long as *const _)
}

/// One dispatched family of task indices `0..total`, claimed atomically by
/// workers and the dispatching caller alike.
struct Batch {
    task: TaskRef,
    total: usize,
    next: AtomicUsize,
    done: Mutex<usize>,
    done_cv: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Batch {
    fn claim(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        if i < self.total {
            Some(i)
        } else {
            None
        }
    }

    /// Claim and run task indices until the batch is drained.
    fn work(&self) {
        while let Some(i) = self.claim() {
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // SAFETY: a successfully claimed index implies the batch is
                // not complete, so the dispatcher is still blocked and the
                // closure is alive (see `TaskRef`).
                (unsafe { &*self.task.0 })(i)
            }));
            if let Err(payload) = run {
                *self.panic.lock().unwrap() = Some(payload);
            }
            let mut done = self.done.lock().unwrap();
            *done += 1;
            if *done == self.total {
                self.done_cv.notify_all();
            }
        }
    }

    fn wait(&self) {
        let mut done = self.done.lock().unwrap();
        while *done < self.total {
            done = self.done_cv.wait(done).unwrap();
        }
    }
}

/// Shared injector the parked workers wait on: FIFO of in-flight batches plus
/// the shutdown flag.
struct Injector {
    queue: Mutex<(VecDeque<Arc<Batch>>, bool)>,
    cv: Condvar,
}

fn worker_loop(inj: Arc<Injector>) {
    loop {
        let batch = {
            let mut state = inj.queue.lock().unwrap();
            loop {
                if let Some(b) = state.0.front() {
                    break Arc::clone(b);
                }
                if state.1 {
                    return;
                }
                state = inj.cv.wait(state).unwrap();
            }
        };
        batch.work();
        // Fully claimed: pop it if it is still at the front so later waits
        // don't busy-spin over an exhausted batch.
        let mut state = inj.queue.lock().unwrap();
        if state.0.front().is_some_and(|b| Arc::ptr_eq(b, &batch)) {
            state.0.pop_front();
        }
    }
}

/// The spawned workers plus their join handles; dropping the last [`Pool`]
/// handle shuts the workers down and joins them.
struct PoolCore {
    injector: Arc<Injector>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        self.injector.queue.lock().unwrap().1 = true;
        self.injector.cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

#[derive(Clone)]
enum Mode {
    /// Everything on the calling thread.
    Serial,
    /// Per-call scoped spawns — the pre-pool engine, kept as a fallback.
    Scoped,
    /// Persistent parked workers.
    Persistent(Arc<PoolCore>),
}

/// A reusable worker-thread handle with the same chunked `par_map`/`par_for`
/// API as the free functions.
///
/// `Pool::new(threads)` spawns `threads − 1` persistent workers once; the
/// handle is cheaply cloneable (`Arc` inside) and `Send + Sync`, so one pool
/// can serve the gradient oracle, per-device compression and the
/// pairwise-distance aggregation rules of a whole training run. The workers
/// shut down when the last clone drops.
///
/// Chunk boundaries and per-item evaluation order are identical to the
/// scoped free functions, so a `Pool` upholds the module's bit-identical
/// determinism contract by construction.
pub struct Pool {
    mode: Mode,
    threads: usize,
}

impl Clone for Pool {
    fn clone(&self) -> Self {
        Pool { mode: self.mode.clone(), threads: self.threads }
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mode = match self.mode {
            Mode::Serial => "serial",
            Mode::Scoped => "scoped",
            Mode::Persistent(_) => "persistent",
        };
        write!(f, "Pool({mode}, threads={})", self.threads)
    }
}

impl Default for Pool {
    /// A serial pool — mirrors `TrainConfig::threads = 1`.
    fn default() -> Self {
        Pool::serial()
    }
}

impl Pool {
    /// Persistent pool with `threads` workers total (the calling thread
    /// counts as one); `0` resolves to all available cores, `1` degrades to
    /// [`Pool::serial`] and spawns nothing.
    pub fn new(threads: usize) -> Pool {
        let t = Parallelism::new(threads).threads();
        if t <= 1 {
            return Pool::serial();
        }
        let injector =
            Arc::new(Injector { queue: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() });
        let handles = (0..t - 1)
            .map(|w| {
                let inj = Arc::clone(&injector);
                std::thread::Builder::new()
                    .name(format!("lad-pool-{w}"))
                    .spawn(move || worker_loop(inj))
                    .expect("spawning pool worker failed")
            })
            .collect();
        Pool {
            mode: Mode::Persistent(Arc::new(PoolCore { injector, handles: Mutex::new(handles) })),
            threads: t,
        }
    }

    /// Everything on the calling thread; spawns nothing, ever.
    pub fn serial() -> Pool {
        Pool { mode: Mode::Serial, threads: 1 }
    }

    /// The scoped-spawn fallback behind the pool API: every call spawns and
    /// joins its own scoped workers (exactly the free functions). Useful
    /// where a persistent pool must not outlive a call site.
    pub fn scoped(par: Parallelism) -> Pool {
        if par.is_serial() {
            Pool::serial()
        } else {
            Pool { mode: Mode::Scoped, threads: par.threads() }
        }
    }

    /// Worker budget (always ≥ 1, counting the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when every primitive runs on the calling thread.
    pub fn is_serial(&self) -> bool {
        self.threads <= 1
    }

    /// The equivalent thread budget, for APIs still taking [`Parallelism`].
    pub fn parallelism(&self) -> Parallelism {
        Parallelism::new(self.threads)
    }

    /// Borrow a handle onto the **same workers** with a capped chunking
    /// width: the returned pool dispatches to this pool's worker set but
    /// splits each call into at most `width` chunks. `0` means the full
    /// budget; widths above it clamp down; `1` degrades to
    /// [`Pool::serial`]. Because chunk boundaries are a pure scheduling
    /// choice, a borrowed handle produces bit-identical results to any
    /// other width — it only bounds how much of the shared pool one stage
    /// can occupy at a time.
    ///
    /// Caveat: on a [`Pool::scoped`] handle there is no persistent worker
    /// set to share — the borrow caps the *width* of each call's scoped
    /// spawns, but concurrent borrowers still spawn their own threads
    /// (up to branches × width live). Use a persistent pool
    /// ([`Pool::new`] / [`Pool::budgeted`]) when the total must be a hard
    /// bound.
    pub fn borrow(&self, width: usize) -> Pool {
        let w = if width == 0 { self.threads } else { width.min(self.threads) };
        if w <= 1 {
            return Pool::serial();
        }
        Pool { mode: self.mode.clone(), threads: w }
    }

    /// Build a two-level budget: one pool of `total` workers (resolved like
    /// [`Pool::new`]) shared between an outer fan-out of `branches` tasks
    /// and each branch's inner stages. The outer level dispatches branches
    /// onto [`PoolBudget::outer`]; each branch runs its parallel stages on
    /// [`PoolBudget::inner`], a borrowed handle capped at
    /// `⌈total / min(branches, total)⌉` so the fan-out no longer
    /// oversubscribes small machines at `branches × total` threads (the
    /// pre-budget failure mode of the figure sweeps). Nested dispatch onto
    /// the shared pool is deadlock-free (callers help drain their own
    /// batches), and results are bit-identical to any other thread split.
    pub fn budgeted(total: usize, branches: usize) -> PoolBudget {
        let pool = Pool::new(total);
        let t = pool.threads();
        let outer = branches.clamp(1, t);
        PoolBudget { inner_width: t.div_ceil(outer), pool }
    }

    /// Dispatch `total` task indices onto the persistent workers; the caller
    /// helps drain the batch, then blocks until every index completed.
    fn dispatch(&self, core: &PoolCore, total: usize, task: &(dyn Fn(usize) + Sync)) {
        let batch = Arc::new(Batch {
            task: erase(task),
            total,
            next: AtomicUsize::new(0),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        core.injector.queue.lock().unwrap().0.push_back(Arc::clone(&batch));
        core.injector.cv.notify_all();
        batch.work();
        batch.wait();
        let mut state = core.injector.queue.lock().unwrap();
        if let Some(pos) = state.0.iter().position(|b| Arc::ptr_eq(b, &batch)) {
            state.0.remove(pos);
        }
        drop(state);
        if let Some(payload) = batch.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Pool counterpart of [`par_map`]: order-preserving map over a shared
    /// slice, chunked exactly like the free function.
    pub fn par_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let threads = self.threads.min(items.len());
        if threads <= 1 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let core = match &self.mode {
            Mode::Scoped => return par_map(self.parallelism(), items, f),
            Mode::Persistent(core) => core,
            Mode::Serial => unreachable!("serial pools have threads == 1"),
        };
        let chunk = items.len().div_ceil(threads);
        let n_chunks = items.len().div_ceil(chunk);
        let slots: Vec<Mutex<Vec<R>>> = (0..n_chunks).map(|_| Mutex::new(Vec::new())).collect();
        self.dispatch(core, n_chunks, &|c| {
            let start = c * chunk;
            let end = (start + chunk).min(items.len());
            let out: Vec<R> =
                items[start..end].iter().enumerate().map(|(i, t)| f(start + i, t)).collect();
            *slots[c].lock().unwrap() = out;
        });
        slots.into_iter().flat_map(|s| s.into_inner().unwrap()).collect()
    }

    /// Pool counterpart of [`par_map_mut`]: exclusive access to each item.
    pub fn par_map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let threads = self.threads.min(items.len());
        if threads <= 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let core = match &self.mode {
            Mode::Scoped => return par_map_mut(self.parallelism(), items, f),
            Mode::Persistent(core) => core,
            Mode::Serial => unreachable!("serial pools have threads == 1"),
        };
        let chunk = items.len().div_ceil(threads);
        let parts: Vec<Mutex<(usize, &mut [T])>> = {
            let mut v = Vec::new();
            let mut start = 0;
            for c in items.chunks_mut(chunk) {
                let s = start;
                start += c.len();
                v.push(Mutex::new((s, c)));
            }
            v
        };
        let slots: Vec<Mutex<Vec<R>>> = (0..parts.len()).map(|_| Mutex::new(Vec::new())).collect();
        self.dispatch(core, parts.len(), &|c| {
            let mut part = parts[c].lock().unwrap();
            let start = part.0;
            let out: Vec<R> =
                part.1.iter_mut().enumerate().map(|(i, t)| f(start + i, t)).collect();
            *slots[c].lock().unwrap() = out;
        });
        slots.into_iter().flat_map(|s| s.into_inner().unwrap()).collect()
    }

    /// Pool counterpart of [`par_for`].
    pub fn par_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let threads = self.threads.min(n);
        if threads <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let core = match &self.mode {
            Mode::Scoped => return par_for(self.parallelism(), n, f),
            Mode::Persistent(core) => core,
            Mode::Serial => unreachable!("serial pools have threads == 1"),
        };
        let chunk = n.div_ceil(threads);
        let n_chunks = n.div_ceil(chunk);
        self.dispatch(core, n_chunks, &|c| {
            let start = c * chunk;
            let end = (start + chunk).min(n);
            for i in start..end {
                f(i);
            }
        });
    }

    /// Pool counterpart of [`par_chunks_mut`]: disjoint `chunk_len` windows
    /// of a mutable slice, whole windows per task.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        if data.is_empty() {
            return;
        }
        let n_chunks = data.len().div_ceil(chunk_len);
        let threads = self.threads.min(n_chunks);
        if threads <= 1 {
            for (i, c) in data.chunks_mut(chunk_len).enumerate() {
                f(i, c);
            }
            return;
        }
        let core = match &self.mode {
            Mode::Scoped => return par_chunks_mut(self.parallelism(), data, chunk_len, f),
            Mode::Persistent(core) => core,
            Mode::Serial => unreachable!("serial pools have threads == 1"),
        };
        let per_thread = n_chunks.div_ceil(threads);
        let block = per_thread * chunk_len;
        let blocks: Vec<Mutex<(usize, &mut [T])>> = {
            let mut v = Vec::new();
            let mut next_chunk = 0;
            for b in data.chunks_mut(block) {
                let s = next_chunk;
                next_chunk += b.len().div_ceil(chunk_len);
                v.push(Mutex::new((s, b)));
            }
            v
        };
        self.dispatch(core, blocks.len(), &|c| {
            let mut part = blocks[c].lock().unwrap();
            let start = part.0;
            for (i, w) in part.1.chunks_mut(chunk_len).enumerate() {
                f(start + i, w);
            }
        });
    }
}

/// A two-level thread budget over one shared worker set (see
/// [`Pool::budgeted`]): the outer fan-out and every branch's inner stages
/// draw from the same `total` workers, so total live parallelism is bounded
/// by the pool width no matter how many branches run concurrently.
///
/// The handle is cheap to clone (the pool is `Arc`-backed) and the workers
/// shut down when the last clone — outer or borrowed inner — drops.
#[derive(Debug, Clone)]
pub struct PoolBudget {
    pool: Pool,
    inner_width: usize,
}

impl PoolBudget {
    /// The shared pool: fan the outer branches out on this handle.
    pub fn outer(&self) -> &Pool {
        &self.pool
    }

    /// A capped handle for one branch's inner stages (same workers,
    /// chunking width `⌈total / branches⌉`).
    pub fn inner(&self) -> Pool {
        self.pool.borrow(self.inner_width)
    }

    /// [`PoolBudget::inner`] additionally capped at `width` — the hook for
    /// per-branch configuration like `TrainConfig::threads`. `0` keeps the
    /// full inner slice.
    pub fn inner_capped(&self, width: usize) -> Pool {
        if width == 0 {
            self.inner()
        } else {
            self.pool.borrow(self.inner_width.min(width))
        }
    }

    /// The inner chunking width (≥ 1; exposed for tests and bench labels).
    pub fn inner_width(&self) -> usize {
        self.inner_width
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallelism_resolution() {
        assert!(Parallelism::auto().threads() >= 1);
        assert_eq!(Parallelism::serial().threads(), 1);
        assert!(Parallelism::serial().is_serial());
        assert_eq!(Parallelism::new(5).threads(), 5);
        assert_eq!(Parallelism::new(0).threads(), available_threads());
    }

    #[test]
    fn par_map_preserves_order_and_matches_serial() {
        let items: Vec<u64> = (0..257).collect();
        let serial = par_map(Parallelism::serial(), &items, |i, &x| x * 3 + i as u64);
        for threads in [2usize, 3, 8, 300] {
            let par = par_map(Parallelism::new(threads), &items, |i, &x| x * 3 + i as u64);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn par_map_edge_sizes() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(Parallelism::new(4), &empty, |_, &x| x).is_empty());
        assert_eq!(par_map(Parallelism::new(4), &[7u32], |i, &x| (i, x)), vec![(0, 7)]);
    }

    #[test]
    fn par_map_mut_gives_exclusive_state_per_item() {
        let mut counters = vec![0u64; 100];
        let out = par_map_mut(Parallelism::new(7), &mut counters, |i, c| {
            *c += i as u64;
            *c * 2
        });
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<u64>>());
        assert_eq!(counters, (0..100).collect::<Vec<u64>>());
    }

    #[test]
    fn par_for_visits_every_index_once() {
        let n = 501;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_for(Parallelism::new(6), n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_chunks_mut_matches_serial_chunking() {
        // rows*cols with a ragged tail chunk
        for (len, chunk_len) in [(12 * 7, 7), (100, 9), (5, 8), (8, 8)] {
            let mut a: Vec<usize> = vec![0; len];
            let mut b: Vec<usize> = vec![0; len];
            let fill = |i: usize, c: &mut [usize]| {
                for (j, v) in c.iter_mut().enumerate() {
                    *v = i * 1000 + j;
                }
            };
            for (i, c) in a.chunks_mut(chunk_len).enumerate() {
                fill(i, c);
            }
            par_chunks_mut(Parallelism::new(4), &mut b, chunk_len, fill);
            assert_eq!(a, b, "len={len} chunk_len={chunk_len}");
        }
    }

    #[test]
    fn more_threads_than_items_is_fine() {
        let items = vec![1u32, 2, 3];
        assert_eq!(par_map(Parallelism::new(64), &items, |_, &x| x + 1), vec![2, 3, 4]);
        let mut data = vec![0u8; 3];
        par_chunks_mut(Parallelism::new(64), &mut data, 1, |i, c| c[0] = i as u8);
        assert_eq!(data, vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn worker_panic_propagates() {
        let items: Vec<usize> = (0..64).collect();
        par_map(Parallelism::new(4), &items, |_, &x| {
            assert!(x != 63, "boom");
            x
        });
    }

    #[test]
    fn pool_resolution_and_modes() {
        assert!(Pool::new(1).is_serial());
        assert!(Pool::serial().is_serial());
        assert!(Pool::scoped(Parallelism::serial()).is_serial());
        assert_eq!(Pool::new(3).threads(), 3);
        assert_eq!(Pool::scoped(Parallelism::new(5)).threads(), 5);
        assert_eq!(Pool::new(0).threads(), available_threads());
        assert_eq!(Pool::default().threads(), 1);
        assert_eq!(Pool::new(4).parallelism().threads(), 4);
    }

    #[test]
    fn pool_par_map_matches_free_function_across_modes() {
        let items: Vec<u64> = (0..257).collect();
        let want = par_map(Parallelism::serial(), &items, |i, &x| x * 3 + i as u64);
        for pool in [Pool::serial(), Pool::scoped(Parallelism::new(3)), Pool::new(4)] {
            let got = pool.par_map(&items, |i, &x| x * 3 + i as u64);
            assert_eq!(got, want, "{pool:?}");
        }
    }

    #[test]
    fn pool_is_reusable_across_many_calls() {
        // the persistent-worker point: many small dispatches on one pool
        let pool = Pool::new(4);
        let items: Vec<u32> = (0..37).collect();
        let want: Vec<u32> = items.iter().map(|&x| x + 1).collect();
        for _ in 0..200 {
            assert_eq!(pool.par_map(&items, |_, &x| x + 1), want);
        }
    }

    #[test]
    fn pool_par_map_mut_and_par_for_and_chunks() {
        let pool = Pool::new(5);
        let mut counters = vec![0u64; 100];
        let out = pool.par_map_mut(&mut counters, |i, c| {
            *c += i as u64;
            *c * 2
        });
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<u64>>());
        assert_eq!(counters, (0..100).collect::<Vec<u64>>());

        let n = 501;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.par_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));

        for (len, chunk_len) in [(12 * 7, 7), (100, 9), (5, 8), (8, 8)] {
            let mut a: Vec<usize> = vec![0; len];
            let mut b: Vec<usize> = vec![0; len];
            let fill = |i: usize, c: &mut [usize]| {
                for (j, v) in c.iter_mut().enumerate() {
                    *v = i * 1000 + j;
                }
            };
            for (i, c) in a.chunks_mut(chunk_len).enumerate() {
                fill(i, c);
            }
            pool.par_chunks_mut(&mut b, chunk_len, fill);
            assert_eq!(a, b, "len={len} chunk_len={chunk_len}");
        }
    }

    #[test]
    fn pool_clones_share_workers_and_outlive_each_other() {
        let pool = Pool::new(3);
        let clone = pool.clone();
        drop(pool);
        let items = vec![1u32, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(clone.par_map(&items, |_, &x| x * 2)[7], 16);
    }

    #[test]
    fn pool_nested_dispatch_does_not_deadlock() {
        // a pool task dispatching onto the same pool must complete (the
        // caller helps drain its own batch instead of blocking)
        let pool = Pool::new(2);
        let outer: Vec<usize> = (0..4).collect();
        let got = pool.par_map(&outer, |_, &i| {
            let inner: Vec<usize> = (0..8).collect();
            pool.par_map(&inner, |_, &j| i * 100 + j).iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..4).map(|i| (0..8).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn borrow_caps_width_and_shares_workers() {
        let pool = Pool::new(4);
        assert_eq!(pool.borrow(0).threads(), 4, "0 = full budget");
        assert_eq!(pool.borrow(9).threads(), 4, "clamped to the pool");
        assert_eq!(pool.borrow(2).threads(), 2);
        assert!(pool.borrow(1).is_serial());
        // borrowed handles stay functional after the original drops
        let narrow = pool.borrow(2);
        drop(pool);
        let items: Vec<u32> = (0..64).collect();
        let want: Vec<u32> = items.iter().map(|&x| x * 2).collect();
        assert_eq!(narrow.par_map(&items, |_, &x| x * 2), want);
        // borrowing from serial/scoped pools keeps their semantics
        assert!(Pool::serial().borrow(8).is_serial());
        assert_eq!(Pool::scoped(Parallelism::new(6)).borrow(3).threads(), 3);
    }

    #[test]
    fn budgeted_splits_total_across_levels() {
        let b = Pool::budgeted(8, 4);
        assert_eq!(b.outer().threads(), 8);
        assert_eq!(b.inner_width(), 2);
        assert_eq!(b.inner().threads(), 2);
        assert_eq!(b.inner_capped(1).threads(), 1);
        assert_eq!(b.inner_capped(0).threads(), 2);
        assert_eq!(b.inner_capped(64).threads(), 2);
        // more branches than workers: inner degrades to serial
        let wide = Pool::budgeted(4, 100);
        assert_eq!(wide.inner_width(), 1);
        assert!(wide.inner().is_serial());
        // serial total: everything serial
        let serial = Pool::budgeted(1, 10);
        assert!(serial.outer().is_serial() && serial.inner().is_serial());
        // few branches, many workers: inner gets the surplus
        let fat = Pool::budgeted(9, 2);
        assert_eq!(fat.inner_width(), 5);
    }

    #[test]
    fn budgeted_nested_fanout_matches_serial_reference() {
        // the run_figure shape: outer branches each running inner stages on
        // a borrowed slice of the same pool — results must match the fully
        // serial evaluation exactly
        let budget = Pool::budgeted(4, 3);
        let branches: Vec<u64> = (0..6).collect();
        let inner_items: Vec<u64> = (0..40).collect();
        let got = budget.outer().par_map(&branches, |_, &b| {
            let inner = budget.inner();
            inner.par_map(&inner_items, |_, &x| b * 1000 + x * 3).iter().sum::<u64>()
        });
        let want: Vec<u64> =
            branches.iter().map(|&b| inner_items.iter().map(|&x| b * 1000 + x * 3).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic(expected = "pool boom")]
    fn pool_task_panic_propagates_to_caller() {
        let pool = Pool::new(4);
        let items: Vec<usize> = (0..64).collect();
        pool.par_map(&items, |_, &x| {
            assert!(x != 63, "pool boom");
            x
        });
    }
}
