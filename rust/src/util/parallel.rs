//! Zero-dependency data-parallel execution engine (no rayon).
//!
//! Built entirely on [`std::thread::scope`]: each primitive splits its input
//! into at most [`Parallelism::threads`] contiguous chunks, spawns one scoped
//! worker per extra chunk, processes the first chunk on the calling thread,
//! and joins in order — so results are always returned in input order and no
//! work queue, channel or allocation-per-item is needed.
//!
//! # Determinism contract
//!
//! Every primitive here is a *pure scheduler*: the closure is applied to the
//! same items, in the same per-item state, regardless of the thread count.
//! Callers keep bit-identical results across `threads = 1` and `threads = N`
//! by never sharing mutable state between items — in particular, seeded RNG
//! streams must be pre-split per item ([`crate::util::rng::Rng::split`])
//! rather than shared. `rust/tests/parallel_determinism.rs` pins this
//! contract end-to-end for the LAD / Com-LAD training loop.
//!
//! # Panics
//!
//! A panic inside a worker closure is propagated to the caller (the scope
//! join panics), matching the behaviour of the serial fallback.

/// How many worker threads a parallel stage may use.
///
/// `Parallelism` is a plain `Copy` value (not a pool): threads are scoped to
/// each call, so nesting and concurrent use from multiple tests are safe.
/// `0` means "all available cores" at construction time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
}

impl Parallelism {
    /// `threads` workers; `0` resolves to [`available_threads`].
    pub fn new(threads: usize) -> Self {
        Parallelism { threads: if threads == 0 { available_threads() } else { threads } }
    }

    /// All available cores.
    pub fn auto() -> Self {
        Parallelism::new(0)
    }

    /// Exactly one thread (the calling one) — the serial fallback.
    pub fn serial() -> Self {
        Parallelism { threads: 1 }
    }

    /// Resolved worker count (always ≥ 1).
    pub fn threads(&self) -> usize {
        self.threads.max(1)
    }

    /// True when no worker threads would be spawned.
    pub fn is_serial(&self) -> bool {
        self.threads() == 1
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::auto()
    }
}

/// Cores visible to this process (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel, order-preserving map over a shared slice.
///
/// `f(index, item)` runs once per item; the result vector matches the input
/// order exactly.
pub fn par_map<T, R, F>(par: Parallelism, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = par.threads().min(items.len());
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let mut out: Vec<Vec<R>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::with_capacity(threads - 1);
        for (c, slice) in items.chunks(chunk).enumerate().skip(1) {
            handles.push(scope.spawn(move || {
                slice
                    .iter()
                    .enumerate()
                    .map(|(i, t)| f(c * chunk + i, t))
                    .collect::<Vec<R>>()
            }));
        }
        // first chunk on the calling thread, overlapping the workers
        out.push(items[..chunk].iter().enumerate().map(|(i, t)| f(i, t)).collect());
        for h in handles {
            out.push(h.join().expect("par_map worker panicked"));
        }
    });
    out.into_iter().flatten().collect()
}

/// Parallel, order-preserving map with exclusive access to each item.
///
/// Items are `&mut` — the canonical use is one pre-split RNG or scratch
/// buffer per device, mutated in place while producing a result.
pub fn par_map_mut<T, R, F>(par: Parallelism, items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let threads = par.threads().min(items.len());
    if threads <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let mut out: Vec<Vec<R>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let (first, mut rest) = items.split_at_mut(chunk);
        let mut handles = Vec::with_capacity(threads - 1);
        let mut offset = chunk;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let start = offset;
            offset += take;
            handles.push(scope.spawn(move || {
                head.iter_mut()
                    .enumerate()
                    .map(|(i, t)| f(start + i, t))
                    .collect::<Vec<R>>()
            }));
        }
        out.push(first.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect());
        for h in handles {
            out.push(h.join().expect("par_map_mut worker panicked"));
        }
    });
    out.into_iter().flatten().collect()
}

/// Parallel for over an index range `0..n`.
pub fn par_for<F>(par: Parallelism, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = par.threads().min(n);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::with_capacity(threads - 1);
        let mut start = chunk;
        while start < n {
            let end = (start + chunk).min(n);
            handles.push(scope.spawn(move || {
                for i in start..end {
                    f(i);
                }
            }));
            start = end;
        }
        for i in 0..chunk {
            f(i);
        }
        for h in handles {
            h.join().expect("par_for worker panicked");
        }
    });
}

/// Parallel for over disjoint `chunk_len`-sized windows of a mutable slice —
/// the primitive behind row-parallel matrix fills (`chunk_len` = row width).
///
/// `f(chunk_index, chunk)` receives the same windows `data.chunks_mut(
/// chunk_len)` would yield, in chunk order within each worker; the final
/// window may be shorter when `chunk_len` does not divide `data.len()`.
pub fn par_chunks_mut<T, F>(par: Parallelism, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = par.threads().min(n_chunks);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    // whole chunks per worker so no window straddles a thread boundary
    let per_thread = n_chunks.div_ceil(threads);
    let block = per_thread * chunk_len;
    std::thread::scope(|scope| {
        let f = &f;
        let split = block.min(data.len());
        let (first, mut rest) = data.split_at_mut(split);
        let mut handles = Vec::with_capacity(threads - 1);
        let mut next_chunk = per_thread;
        while !rest.is_empty() {
            let take = block.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let start = next_chunk;
            next_chunk += head.len().div_ceil(chunk_len);
            handles.push(scope.spawn(move || {
                for (i, c) in head.chunks_mut(chunk_len).enumerate() {
                    f(start + i, c);
                }
            }));
        }
        for (i, c) in first.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        for h in handles {
            h.join().expect("par_chunks_mut worker panicked");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallelism_resolution() {
        assert!(Parallelism::auto().threads() >= 1);
        assert_eq!(Parallelism::serial().threads(), 1);
        assert!(Parallelism::serial().is_serial());
        assert_eq!(Parallelism::new(5).threads(), 5);
        assert_eq!(Parallelism::new(0).threads(), available_threads());
    }

    #[test]
    fn par_map_preserves_order_and_matches_serial() {
        let items: Vec<u64> = (0..257).collect();
        let serial = par_map(Parallelism::serial(), &items, |i, &x| x * 3 + i as u64);
        for threads in [2usize, 3, 8, 300] {
            let par = par_map(Parallelism::new(threads), &items, |i, &x| x * 3 + i as u64);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn par_map_edge_sizes() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(Parallelism::new(4), &empty, |_, &x| x).is_empty());
        assert_eq!(par_map(Parallelism::new(4), &[7u32], |i, &x| (i, x)), vec![(0, 7)]);
    }

    #[test]
    fn par_map_mut_gives_exclusive_state_per_item() {
        let mut counters = vec![0u64; 100];
        let out = par_map_mut(Parallelism::new(7), &mut counters, |i, c| {
            *c += i as u64;
            *c * 2
        });
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<u64>>());
        assert_eq!(counters, (0..100).collect::<Vec<u64>>());
    }

    #[test]
    fn par_for_visits_every_index_once() {
        let n = 501;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_for(Parallelism::new(6), n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_chunks_mut_matches_serial_chunking() {
        // rows*cols with a ragged tail chunk
        for (len, chunk_len) in [(12 * 7, 7), (100, 9), (5, 8), (8, 8)] {
            let mut a: Vec<usize> = vec![0; len];
            let mut b: Vec<usize> = vec![0; len];
            let fill = |i: usize, c: &mut [usize]| {
                for (j, v) in c.iter_mut().enumerate() {
                    *v = i * 1000 + j;
                }
            };
            for (i, c) in a.chunks_mut(chunk_len).enumerate() {
                fill(i, c);
            }
            par_chunks_mut(Parallelism::new(4), &mut b, chunk_len, fill);
            assert_eq!(a, b, "len={len} chunk_len={chunk_len}");
        }
    }

    #[test]
    fn more_threads_than_items_is_fine() {
        let items = vec![1u32, 2, 3];
        assert_eq!(par_map(Parallelism::new(64), &items, |_, &x| x + 1), vec![2, 3, 4]);
        let mut data = vec![0u8; 3];
        par_chunks_mut(Parallelism::new(64), &mut data, 1, |i, c| c[0] = i as u8);
        assert_eq!(data, vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn worker_panic_propagates() {
        let items: Vec<usize> = (0..64).collect();
        par_map(Parallelism::new(4), &items, |_, &x| {
            assert!(x != 63, "boom");
            x
        });
    }
}
