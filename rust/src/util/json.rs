//! Minimal JSON value, parser and writer (serde is unavailable offline).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for metric dumps. Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation (diff-friendly metric dumps).
    pub fn to_pretty_string(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 character
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let doc = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[2].as_str(), Some("x\ny"));
        // serialize and reparse
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_roundtrip() {
        let doc = r#"{"a": 1, "b": [true, {"c": "x"}, []], "d": {}}"#;
        let v = parse(doc).unwrap();
        let pretty = v.to_pretty_string();
        assert!(pretty.contains("\n  "), "indented: {pretty}");
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse(r#"{"a": 1} x"#).is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("quote\" slash\\ nl\n".into());
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
