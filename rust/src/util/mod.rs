//! Dependency-free substrate utilities: RNG, vector/matrix math, JSON,
//! CSV, timing and summary statistics.

pub mod csv;
pub mod json;
pub mod math;
pub mod rng;
pub mod stats;
pub mod timer;

pub use math::Mat;
pub use rng::Rng;
