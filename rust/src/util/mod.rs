//! Dependency-free substrate utilities: RNG, vector/matrix math, the
//! scoped-thread parallel engine, JSON, CSV, timing and summary statistics.

pub mod csv;
pub mod json;
pub mod math;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod timer;

pub use math::Mat;
pub use parallel::{Parallelism, Pool};
pub use rng::Rng;
