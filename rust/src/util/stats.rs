//! Streaming summary statistics (Welford) and simple quantiles.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Quantile of a sample (linear interpolation); sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median convenience.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }
}
