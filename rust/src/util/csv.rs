//! CSV writer for metric traces and experiment series (results/*.csv).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Columnar series writer: header + rows of f64.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.out, "{}", line.join(","))
    }

    /// Row with a leading string label.
    pub fn labeled_row(&mut self, label: &str, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len() + 1, self.cols, "csv row arity mismatch");
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.out, "{},{}", escape(label), line.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Quote a CSV cell when it contains a delimiter, quote or newline (also
/// used by the sweep sink's pivot export).
pub(crate) fn escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("lad_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["iter", "loss"]).unwrap();
            w.row(&[0.0, 1.5]).unwrap();
            w.row(&[1.0, 1.25]).unwrap();
            w.flush().unwrap();
        }
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body, "iter,loss\n0,1.5\n1,1.25\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn escapes_labels() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("a,b"), "\"a,b\"");
        assert_eq!(escape("q\"x"), "\"q\"\"x\"");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let dir = std::env::temp_dir().join("lad_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }
}
