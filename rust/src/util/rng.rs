//! Deterministic, seedable PRNG (no `rand` crate in the offline sandbox).
//!
//! Core generator is PCG-XSH-RR 64/32 (O'Neill 2014) seeded through
//! SplitMix64; normal variates via Box–Muller with a cached spare;
//! permutations via Fisher–Yates. All experiment randomness flows through
//! this module so every run is reproducible from a single `u64` seed.

/// SplitMix64 step — used for seed expansion and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second Box–Muller variate
    spare_gauss: Option<f64>,
}

/// A serializable snapshot of an [`Rng`]'s complete state — the PCG state
/// and increment words plus the cached Box–Muller spare. Restoring it with
/// [`Rng::restore`] continues the stream bit-identically from the capture
/// point, which is what lets checkpoints resume a run's randomness and lets
/// the wire hand a compression-stream cursor between leader and worker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    pub state: u64,
    pub inc: u64,
    pub spare_gauss: Option<f64>,
}

impl Rng {
    /// Construct from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state, inc, spare_gauss: None };
        rng.next_u32(); // advance away from the seeding artifacts
        rng
    }

    /// Capture the generator's full state for checkpointing or a wire
    /// hand-off. Non-consuming: the stream continues as if never observed.
    pub fn save_state(&self) -> RngState {
        RngState { state: self.state, inc: self.inc, spare_gauss: self.spare_gauss }
    }

    /// Rebuild a generator from a [`RngState`] snapshot. The restored
    /// stream is bit-identical to the original from the capture point on.
    pub fn restore(st: RngState) -> Rng {
        Rng { state: st.state, inc: st.inc, spare_gauss: st.spare_gauss }
    }

    /// Derive an independent child stream (e.g. one per device).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Pre-split `n` independent child streams **without advancing** this
    /// generator.
    ///
    /// Unlike repeated [`Rng::fork`] calls, splitting is non-consuming: the
    /// parent stream continues exactly as if `split` had never been called.
    /// This is the primitive behind deterministic parallelism — give every
    /// device/chunk its own stream up front, and serial and multi-threaded
    /// execution consume identical randomness (see
    /// [`crate::util::parallel`]). The derived seeds are salted so the
    /// children do not replay the parent's own output.
    pub fn split(&self, n: usize) -> Vec<Rng> {
        self.split_seeds(n).into_iter().map(Rng::new).collect()
    }

    /// The seeds [`Rng::split`] would construct its child streams from,
    /// without building the streams. `Rng::new(split_seeds(n)[i])` is
    /// bit-identical to `split(n)[i]`, which is what lets a leader hand
    /// device `i` its private stream over the wire as a single `u64`
    /// (`net::wire::Msg::Hello`) while keeping its own copy.
    pub fn split_seeds(&self, n: usize) -> Vec<u64> {
        let mut probe = self.clone();
        let base = probe.next_u64() ^ 0xD1B5_4A32_D192_ED03;
        (0..n as u64).map(|i| base ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect()
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller, cached spare).
    pub fn gauss(&mut self) -> f64 {
        if let Some(s) = self.spare_gauss.take() {
            return s;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_gauss = Some(r * s);
            return r * c;
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// k distinct indices drawn uniformly from 0..n (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }

    /// Vector of iid standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn choose_k_distinct_and_uniformish() {
        let mut r = Rng::new(9);
        let mut hits = vec![0usize; 20];
        for _ in 0..10_000 {
            let ks = r.choose_k(20, 5);
            let mut set = std::collections::HashSet::new();
            for k in &ks {
                assert!(set.insert(*k));
                hits[*k] += 1;
            }
        }
        // each index expected 2500 times
        for h in hits {
            assert!((h as f64 - 2500.0).abs() < 300.0, "hits {h}");
        }
    }

    #[test]
    fn split_does_not_advance_parent_and_streams_are_independent() {
        let parent = Rng::new(77);
        let mut a = parent.clone();
        let streams = parent.split(4);
        let mut b = parent.clone();
        // parent untouched by split
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // children pairwise (and vs parent) decorrelated
        let mut all = streams;
        all.push(parent.clone());
        for i in 0..all.len() {
            for j in i + 1..all.len() {
                let (mut x, mut y) = (all[i].clone(), all[j].clone());
                let same = (0..64).filter(|_| x.next_u32() == y.next_u32()).count();
                assert!(same < 4, "streams {i},{j} correlated");
            }
        }
        // and deterministic: same parent state ⇒ same children
        let again = Rng::new(77).split(4);
        let first = Rng::new(77).split(4);
        for (p, q) in again.iter().zip(&first) {
            let (mut p, mut q) = (p.clone(), q.clone());
            assert_eq!(p.next_u64(), q.next_u64());
        }
    }

    #[test]
    fn split_seeds_reconstruct_split_streams() {
        let parent = Rng::new(2024);
        let streams = parent.split(6);
        let seeds = parent.split_seeds(6);
        for (s, seed) in streams.iter().zip(seeds) {
            let (mut a, mut b) = (s.clone(), Rng::new(seed));
            for _ in 0..16 {
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }
    }

    #[test]
    fn save_restore_continues_the_stream_bit_identically() {
        let mut r = Rng::new(314);
        // consume an odd number of gaussians so a spare is cached
        let _ = r.gauss();
        let snap = r.save_state();
        assert!(snap.spare_gauss.is_some(), "spare should be cached");
        let mut back = Rng::restore(snap);
        let mut orig = r.clone();
        // the cached spare is replayed first, then the raw stream agrees
        assert_eq!(orig.gauss().to_bits(), back.gauss().to_bits());
        for _ in 0..64 {
            assert_eq!(orig.next_u64(), back.next_u64());
        }
        // save_state is non-consuming
        let snap2 = r.save_state();
        assert_eq!(snap, snap2);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(123);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
