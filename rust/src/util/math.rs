//! Small dense vector/matrix kernels used on the coordinator hot path.
//!
//! Gradients are `&[f32]`; per-subset gradient matrices are row-major
//! [`Mat`]. Everything here is allocation-conscious: the training loop calls
//! these per iteration per device.
//!
//! # The kernel tier ladder
//!
//! Each hot kernel (`dot`, `norm_sq`, `dist_sq`, `axpy`, `scale`) exists in
//! up to three tiers, all implementing one **lane contract** (below) so that
//! every tier produces bit-identical results and swapping tiers can never
//! change a training trace:
//!
//! * [`Tier::Scalar`] — the portable reference in [`scalar`], always
//!   compiled, and the only tier on non-x86-64 targets or without
//!   `--features simd`;
//! * [`Tier::Sse2`] — SSE2 intrinsics (baseline on every x86-64 CPU, no
//!   detection needed), implementing the widened contract with register
//!   pairs;
//! * [`Tier::Avx2Fma`] — AVX2 intrinsics compiled with
//!   `#[target_feature(enable = "avx2,fma")]`, selected only when the
//!   running CPU reports both feature bits (they ship together on every
//!   AVX2-era core). The kernels deliberately use *separate* multiply and
//!   add instructions — a fused `vfmadd` rounds once where the contract
//!   rounds twice, which would break cross-tier bit-identity; enabling the
//!   `fma` target feature is still safe because rustc never auto-contracts
//!   float expressions.
//!
//! # Runtime dispatch
//!
//! With `--features simd` on x86-64 the widest safe tier is selected **once
//! per process**: the first kernel call runs `is_x86_feature_detected!`,
//! resolves the optional `LAD_SIMD_TIER` override (values `scalar`, `sse2`,
//! `avx2`; requests above what the CPU supports clamp down with a note on
//! stderr — used by CI to pin each tier), and publishes a `&'static`
//! function-pointer table through an `AtomicPtr`. Every later call is one
//! relaxed load plus an indirect call — no per-call feature detection and no
//! tier branching. Without the feature (or off x86-64) the public functions
//! compile straight to the scalar reference and the dispatcher does not
//! exist.
//!
//! Per-tier kernels stay reachable for tests and benches through the
//! [`Tier`] methods ([`Tier::dot`], …); [`active_tier`], [`compiled_tiers`]
//! and [`detected_tiers`] report what the dispatcher can and did pick.
//!
//! # The lane contract (widened: 8 f32 / 4 f64 lanes)
//!
//! * `dot` runs 8 independent f32 lanes, lane `k` accumulating elements
//!   `8·i + k` in index order; reduction folds the high half onto the low
//!   (`m[k] = l[k] + l[k+4]`) and then sums `((m0 + m1) + m2) + m3`;
//!   remaining elements (< 8) are added sequentially afterwards.
//! * `norm_sq` / `dist_sq` accumulate f64 squares in 4 independent lanes,
//!   lane `k` taking elements `4·i + k` (for `dist_sq` the difference is
//!   taken in f32 first, then widened — the numerically stable
//!   subtract-first form); reduction is `(l0 + l2) + (l1 + l3)`; remaining
//!   elements (< 4) are squared and added sequentially afterwards.
//! * `axpy` / `scale` are element-wise and trivially identical per element
//!   at any vector width.
//!
//! PR 2's contract was 4 f32 / 2 f64 lanes; the widening (so one AVX2
//! register is one lane set) shifts absolute trace values by ~1 ulp while
//! every invariant and equality pin in the test suite holds. Cross-tier
//! bit-identity is pinned by `tier_kernels_match_scalar_reference` below and
//! fuzzed in `rust/tests/fuzz_determinism.rs`.

/// Row-major dense f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Mat { rows: rows.len(), cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = self · x  (rows×cols · cols).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }
}

/// Portable reference kernels, always compiled — the definition of the lane
/// contract. The public free functions run these directly unless the `simd`
/// feature installs the dispatcher; every intrinsics tier is tested against
/// this module bit-for-bit.
pub mod scalar {
    /// Dot product: 8 f32 lanes + high-onto-low fold + sequential remainder
    /// (lane contract).
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; 8];
        let chunks = a.len() / 8;
        for i in 0..chunks {
            let j = i * 8;
            for (k, l) in acc.iter_mut().enumerate() {
                *l += a[j + k] * b[j + k];
            }
        }
        let m = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
        let mut s = ((m[0] + m[1]) + m[2]) + m[3];
        for j in chunks * 8..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    /// Squared norm: 4 f64 lanes over elements `4i + k` + sequential tail.
    #[inline]
    pub fn norm_sq(x: &[f32]) -> f64 {
        let mut acc = [0.0f64; 4];
        let blocks = x.len() / 4;
        for i in 0..blocks {
            let j = i * 4;
            for (k, l) in acc.iter_mut().enumerate() {
                let v = x[j + k] as f64;
                *l += v * v;
            }
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        for j in blocks * 4..x.len() {
            let v = x[j] as f64;
            s += v * v;
        }
        s
    }

    /// Squared distance: f32 subtraction first, then the [`norm_sq`] lane
    /// scheme on the differences.
    #[inline]
    pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f64; 4];
        let blocks = a.len() / 4;
        for i in 0..blocks {
            let j = i * 4;
            for (k, l) in acc.iter_mut().enumerate() {
                let d = (a[j + k] - b[j + k]) as f64;
                *l += d * d;
            }
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        for j in blocks * 4..a.len() {
            let d = (a[j] - b[j]) as f64;
            s += d * d;
        }
        s
    }

    /// y += alpha * x (element-wise).
    #[inline]
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * *xi;
        }
    }

    /// x *= alpha (element-wise).
    #[inline]
    pub fn scale(x: &mut [f32], alpha: f32) {
        for xi in x.iter_mut() {
            *xi *= alpha;
        }
    }
}

/// SSE2 tier (baseline on x86-64, no runtime detection needed). The widened
/// 8 f32 / 4 f64 lane contract is implemented with register *pairs*: two
/// `__m128` accumulators carry f32 lanes 0–3 / 4–7, two `__m128d`
/// accumulators carry f64 lanes 0–1 / 2–3, and the reductions mirror the
/// scalar fold exactly, so results are bit-identical.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod sse2 {
    use std::arch::x86_64::{
        _mm_add_pd, _mm_add_ps, _mm_cvtps_pd, _mm_loadu_ps, _mm_movehl_ps, _mm_mul_pd,
        _mm_mul_ps, _mm_set1_ps, _mm_setzero_pd, _mm_setzero_ps, _mm_storeu_pd, _mm_storeu_ps,
        _mm_sub_ps,
    };

    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        // SAFETY: unaligned loads within slice bounds (8·chunks ≤ len).
        unsafe {
            let mut lo = _mm_setzero_ps(); // f32 lanes 0..4
            let mut hi = _mm_setzero_ps(); // f32 lanes 4..8
            for i in 0..chunks {
                let j = 8 * i;
                let a0 = _mm_loadu_ps(a.as_ptr().add(j));
                let b0 = _mm_loadu_ps(b.as_ptr().add(j));
                lo = _mm_add_ps(lo, _mm_mul_ps(a0, b0));
                let a1 = _mm_loadu_ps(a.as_ptr().add(j + 4));
                let b1 = _mm_loadu_ps(b.as_ptr().add(j + 4));
                hi = _mm_add_ps(hi, _mm_mul_ps(a1, b1));
            }
            // contract fold: m[k] = l[k] + l[k+4], then ((m0+m1)+m2)+m3
            let mut m = [0.0f32; 4];
            _mm_storeu_ps(m.as_mut_ptr(), _mm_add_ps(lo, hi));
            let mut s = ((m[0] + m[1]) + m[2]) + m[3];
            for j in chunks * 8..a.len() {
                s += a[j] * b[j];
            }
            s
        }
    }

    #[inline]
    pub fn norm_sq(x: &[f32]) -> f64 {
        let blocks = x.len() / 4;
        // SAFETY: unaligned loads within slice bounds (4·blocks ≤ len).
        unsafe {
            let mut lo = _mm_setzero_pd(); // f64 lanes 0..2
            let mut hi = _mm_setzero_pd(); // f64 lanes 2..4
            for i in 0..blocks {
                let v = _mm_loadu_ps(x.as_ptr().add(4 * i));
                let v01 = _mm_cvtps_pd(v);
                let v23 = _mm_cvtps_pd(_mm_movehl_ps(v, v));
                lo = _mm_add_pd(lo, _mm_mul_pd(v01, v01));
                hi = _mm_add_pd(hi, _mm_mul_pd(v23, v23));
            }
            // contract fold: (l0+l2) + (l1+l3)
            let mut m = [0.0f64; 2];
            _mm_storeu_pd(m.as_mut_ptr(), _mm_add_pd(lo, hi));
            let mut s = m[0] + m[1];
            for j in blocks * 4..x.len() {
                let v = x[j] as f64;
                s += v * v;
            }
            s
        }
    }

    #[inline]
    pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let blocks = a.len() / 4;
        // SAFETY: unaligned loads within slice bounds (4·blocks ≤ len).
        unsafe {
            let mut lo = _mm_setzero_pd();
            let mut hi = _mm_setzero_pd();
            for i in 0..blocks {
                let va = _mm_loadu_ps(a.as_ptr().add(4 * i));
                let vb = _mm_loadu_ps(b.as_ptr().add(4 * i));
                let d = _mm_sub_ps(va, vb);
                let d01 = _mm_cvtps_pd(d);
                let d23 = _mm_cvtps_pd(_mm_movehl_ps(d, d));
                lo = _mm_add_pd(lo, _mm_mul_pd(d01, d01));
                hi = _mm_add_pd(hi, _mm_mul_pd(d23, d23));
            }
            let mut m = [0.0f64; 2];
            _mm_storeu_pd(m.as_mut_ptr(), _mm_add_pd(lo, hi));
            let mut s = m[0] + m[1];
            for j in blocks * 4..a.len() {
                let d = (a[j] - b[j]) as f64;
                s += d * d;
            }
            s
        }
    }

    #[inline]
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let chunks = x.len() / 4;
        // SAFETY: unaligned loads/stores within slice bounds (4·chunks ≤ len).
        unsafe {
            let va = _mm_set1_ps(alpha);
            for i in 0..chunks {
                let j = 4 * i;
                let vx = _mm_loadu_ps(x.as_ptr().add(j));
                let vy = _mm_loadu_ps(y.as_ptr().add(j));
                _mm_storeu_ps(y.as_mut_ptr().add(j), _mm_add_ps(vy, _mm_mul_ps(va, vx)));
            }
        }
        for j in chunks * 4..x.len() {
            y[j] += alpha * x[j];
        }
    }

    #[inline]
    pub fn scale(x: &mut [f32], alpha: f32) {
        let chunks = x.len() / 4;
        // SAFETY: unaligned loads/stores within slice bounds (4·chunks ≤ len).
        unsafe {
            let va = _mm_set1_ps(alpha);
            for i in 0..chunks {
                let j = 4 * i;
                let vx = _mm_loadu_ps(x.as_ptr().add(j));
                _mm_storeu_ps(x.as_mut_ptr().add(j), _mm_mul_ps(vx, va));
            }
        }
        for j in chunks * 4..x.len() {
            x[j] *= alpha;
        }
    }
}

/// AVX2+FMA tier: one 256-bit register is one full lane set (8 f32 lanes in
/// a `__m256`, 4 f64 lanes in a `__m256d`), and the high-onto-low reductions
/// are literal `vextractf128` + add — the widened contract was chosen so
/// this tier is the natural one.
///
/// Every function is `unsafe` with `#[target_feature(enable = "avx2,fma")]`:
/// callers must guarantee the CPU supports both features (the dispatcher
/// only installs this table after `is_x86_feature_detected!` confirms them).
/// Accumulating kernels use separate `vmulps`/`vaddps` rather than fused
/// `vfmadd` on purpose: FMA's single rounding would diverge from the scalar
/// mirror and break the cross-tier bit-identity pledge. rustc performs no
/// automatic contraction, so the `fma` feature bit only helps instruction
/// scheduling here.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_add_ps, _mm256_castpd256_pd128, _mm256_castps256_ps128,
        _mm256_cvtps_pd, _mm256_extractf128_pd, _mm256_extractf128_ps, _mm256_loadu_ps,
        _mm256_mul_pd, _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_pd, _mm256_setzero_ps,
        _mm256_storeu_ps, _mm_add_pd, _mm_add_ps, _mm_loadu_ps, _mm_storeu_pd, _mm_storeu_ps,
        _mm_sub_ps,
    };

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps(); // f32 lanes 0..8
        for i in 0..chunks {
            let j = 8 * i;
            // SAFETY (fn contract): unaligned loads within bounds (8·chunks ≤ len)
            let va = _mm256_loadu_ps(a.as_ptr().add(j));
            let vb = _mm256_loadu_ps(b.as_ptr().add(j));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        // contract fold: m[k] = l[k] + l[k+4], then ((m0+m1)+m2)+m3
        let fold = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
        let mut m = [0.0f32; 4];
        _mm_storeu_ps(m.as_mut_ptr(), fold);
        let mut s = ((m[0] + m[1]) + m[2]) + m[3];
        for j in chunks * 8..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn norm_sq(x: &[f32]) -> f64 {
        let blocks = x.len() / 4;
        let mut acc = _mm256_setzero_pd(); // f64 lanes 0..4
        for i in 0..blocks {
            // SAFETY (fn contract): 4-float load within bounds (4·blocks ≤ len)
            let v = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(4 * i)));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(v, v));
        }
        // contract fold: (l0+l2) + (l1+l3)
        let fold = _mm_add_pd(_mm256_castpd256_pd128(acc), _mm256_extractf128_pd::<1>(acc));
        let mut m = [0.0f64; 2];
        _mm_storeu_pd(m.as_mut_ptr(), fold);
        let mut s = m[0] + m[1];
        for j in blocks * 4..x.len() {
            let v = x[j] as f64;
            s += v * v;
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let blocks = a.len() / 4;
        let mut acc = _mm256_setzero_pd();
        for i in 0..blocks {
            // SAFETY (fn contract): 4-float loads within bounds (4·blocks ≤ len)
            let va = _mm_loadu_ps(a.as_ptr().add(4 * i));
            let vb = _mm_loadu_ps(b.as_ptr().add(4 * i));
            let d = _mm256_cvtps_pd(_mm_sub_ps(va, vb));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d));
        }
        let fold = _mm_add_pd(_mm256_castpd256_pd128(acc), _mm256_extractf128_pd::<1>(acc));
        let mut m = [0.0f64; 2];
        _mm_storeu_pd(m.as_mut_ptr(), fold);
        let mut s = m[0] + m[1];
        for j in blocks * 4..a.len() {
            let d = (a[j] - b[j]) as f64;
            s += d * d;
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let chunks = x.len() / 8;
        let va = _mm256_set1_ps(alpha);
        for i in 0..chunks {
            let j = 8 * i;
            // SAFETY (fn contract): unaligned 8-float ops within bounds
            let vx = _mm256_loadu_ps(x.as_ptr().add(j));
            let vy = _mm256_loadu_ps(y.as_ptr().add(j));
            _mm256_storeu_ps(y.as_mut_ptr().add(j), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
        }
        for j in chunks * 8..x.len() {
            y[j] += alpha * x[j];
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale(x: &mut [f32], alpha: f32) {
        let chunks = x.len() / 8;
        let va = _mm256_set1_ps(alpha);
        for i in 0..chunks {
            let j = 8 * i;
            // SAFETY (fn contract): unaligned 8-float ops within bounds
            let vx = _mm256_loadu_ps(x.as_ptr().add(j));
            _mm256_storeu_ps(x.as_mut_ptr().add(j), _mm256_mul_ps(vx, va));
        }
        for j in chunks * 8..x.len() {
            x[j] *= alpha;
        }
    }
}

// ---------------------------------------------------------------------------
// Tier registry + runtime dispatch
// ---------------------------------------------------------------------------

/// One kernel backend tier. Ordered narrowest to widest; the dispatcher
/// picks the widest [`detected`](detected_tiers) tier unless `LAD_SIMD_TIER`
/// pins a narrower one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum Tier {
    /// Portable reference ([`scalar`]) — always available.
    Scalar = 0,
    /// SSE2 intrinsics — compiled under `--features simd` on x86-64
    /// (baseline, always CPU-supported there).
    Sse2 = 1,
    /// AVX2 intrinsics behind `avx2`+`fma` runtime detection.
    Avx2Fma = 2,
}

/// Per-tier kernel entry points. Scalar and SSE2 entries are safe functions
/// coerced to `unsafe fn`; the AVX2 entries genuinely require the feature
/// bits, which is why the whole table is threaded through `unsafe fn`
/// pointers and every call site documents the detection invariant.
struct Kernels {
    dot: unsafe fn(&[f32], &[f32]) -> f32,
    norm_sq: unsafe fn(&[f32]) -> f64,
    dist_sq: unsafe fn(&[f32], &[f32]) -> f64,
    axpy: unsafe fn(f32, &[f32], &mut [f32]),
    scale: unsafe fn(&mut [f32], f32),
}

static SCALAR_KERNELS: Kernels = Kernels {
    dot: scalar::dot,
    norm_sq: scalar::norm_sq,
    dist_sq: scalar::dist_sq,
    axpy: scalar::axpy,
    scale: scalar::scale,
};

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
static SSE2_KERNELS: Kernels = Kernels {
    dot: sse2::dot,
    norm_sq: sse2::norm_sq,
    dist_sq: sse2::dist_sq,
    axpy: sse2::axpy,
    scale: sse2::scale,
};

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
static AVX2_KERNELS: Kernels = Kernels {
    dot: avx2::dot,
    norm_sq: avx2::norm_sq,
    dist_sq: avx2::dist_sq,
    axpy: avx2::axpy,
    scale: avx2::scale,
};

/// True when the intrinsics tiers are compiled into this binary (the scalar
/// reference is always present; which tier actually runs is
/// [`active_tier`]).
pub const SIMD_ACTIVE: bool = cfg!(all(feature = "simd", target_arch = "x86_64"));

impl Tier {
    /// Stable lowercase name (also the `LAD_SIMD_TIER` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Sse2 => "sse2",
            Tier::Avx2Fma => "avx2",
        }
    }

    /// Parse a `LAD_SIMD_TIER` request (case-insensitive; `avx2`,
    /// `avx2fma` and `avx2+fma` all mean [`Tier::Avx2Fma`]).
    pub fn parse(s: &str) -> Option<Tier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Tier::Scalar),
            "sse2" => Some(Tier::Sse2),
            "avx2" | "avx2fma" | "avx2+fma" => Some(Tier::Avx2Fma),
            _ => None,
        }
    }

    /// Whether this tier's kernels are compiled into the binary.
    pub fn is_compiled(self) -> bool {
        self == Tier::Scalar || SIMD_ACTIVE
    }

    /// Whether this tier is compiled **and** the running CPU supports it —
    /// i.e. it is safe for the dispatcher (or a test) to execute.
    pub fn is_supported(self) -> bool {
        match self {
            Tier::Scalar => true,
            Tier::Sse2 => SIMD_ACTIVE,
            Tier::Avx2Fma => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                {
                    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
                }
                #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
                {
                    false
                }
            }
        }
    }

    fn kernels(self) -> &'static Kernels {
        match self {
            Tier::Scalar => &SCALAR_KERNELS,
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Tier::Sse2 => &SSE2_KERNELS,
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Tier::Avx2Fma => &AVX2_KERNELS,
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            _ => unreachable!("intrinsics tier not compiled (guarded by is_supported)"),
        }
    }

    /// Run this tier's `dot` directly (tests/benches). Panics if the tier is
    /// not supported on this binary + CPU.
    pub fn dot(self, a: &[f32], b: &[f32]) -> f32 {
        assert!(self.is_supported(), "tier {} not supported here", self.name());
        // SAFETY: is_supported() checked the CPU feature bits for this tier.
        unsafe { (self.kernels().dot)(a, b) }
    }

    /// Per-tier `norm_sq` (see [`Tier::dot`]).
    pub fn norm_sq(self, x: &[f32]) -> f64 {
        assert!(self.is_supported(), "tier {} not supported here", self.name());
        // SAFETY: is_supported() checked the CPU feature bits for this tier.
        unsafe { (self.kernels().norm_sq)(x) }
    }

    /// Per-tier `dist_sq` (see [`Tier::dot`]).
    pub fn dist_sq(self, a: &[f32], b: &[f32]) -> f64 {
        assert!(self.is_supported(), "tier {} not supported here", self.name());
        // SAFETY: is_supported() checked the CPU feature bits for this tier.
        unsafe { (self.kernels().dist_sq)(a, b) }
    }

    /// Per-tier `axpy` (see [`Tier::dot`]).
    pub fn axpy(self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert!(self.is_supported(), "tier {} not supported here", self.name());
        // SAFETY: is_supported() checked the CPU feature bits for this tier.
        unsafe { (self.kernels().axpy)(alpha, x, y) }
    }

    /// Per-tier `scale` (see [`Tier::dot`]).
    pub fn scale(self, x: &mut [f32], alpha: f32) {
        assert!(self.is_supported(), "tier {} not supported here", self.name());
        // SAFETY: is_supported() checked the CPU feature bits for this tier.
        unsafe { (self.kernels().scale)(x, alpha) }
    }

    /// Resolve this tier's table with the support check paid once, for
    /// call-in-a-loop uses (benches). Panics if unsupported, like
    /// [`Tier::dot`].
    pub fn kernels_checked(self) -> TierKernels {
        assert!(self.is_supported(), "tier {} not supported here", self.name());
        TierKernels { table: self.kernels() }
    }
}

/// Handle to one tier's kernel table with the support check paid **once**
/// at construction ([`Tier::kernels_checked`]): each call is a bare
/// indirect call, matching what the dispatched free functions cost — the
/// right entry point for per-tier micro-benches, where [`Tier::dot`]'s
/// per-call assert would inflate small-Q timings.
#[derive(Clone, Copy)]
pub struct TierKernels {
    table: &'static Kernels,
}

impl TierKernels {
    /// See [`Tier::dot`].
    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: construction verified CPU support for this tier, and CPU
        // feature bits never change over a process lifetime.
        unsafe { (self.table.dot)(a, b) }
    }

    /// See [`Tier::norm_sq`].
    #[inline]
    pub fn norm_sq(&self, x: &[f32]) -> f64 {
        // SAFETY: construction verified CPU support (see `dot`).
        unsafe { (self.table.norm_sq)(x) }
    }

    /// See [`Tier::dist_sq`].
    #[inline]
    pub fn dist_sq(&self, a: &[f32], b: &[f32]) -> f64 {
        // SAFETY: construction verified CPU support (see `dot`).
        unsafe { (self.table.dist_sq)(a, b) }
    }

    /// See [`Tier::axpy`].
    #[inline]
    pub fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        // SAFETY: construction verified CPU support (see `dot`).
        unsafe { (self.table.axpy)(alpha, x, y) }
    }

    /// See [`Tier::scale`].
    #[inline]
    pub fn scale(&self, x: &mut [f32], alpha: f32) {
        // SAFETY: construction verified CPU support (see `dot`).
        unsafe { (self.table.scale)(x, alpha) }
    }
}

/// The tiers compiled into this binary, narrowest first.
pub fn compiled_tiers() -> &'static [Tier] {
    if SIMD_ACTIVE {
        &[Tier::Scalar, Tier::Sse2, Tier::Avx2Fma]
    } else {
        &[Tier::Scalar]
    }
}

/// The compiled tiers the running CPU can execute, narrowest first.
pub fn detected_tiers() -> Vec<Tier> {
    compiled_tiers().iter().copied().filter(|t| t.is_supported()).collect()
}

/// The tier the dispatcher selected (widest detected, unless
/// `LAD_SIMD_TIER` pinned a narrower one). Always [`Tier::Scalar`] without
/// `--features simd` on x86-64. Forces dispatcher initialization.
pub fn active_tier() -> Tier {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        dispatch::active_tier()
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        Tier::Scalar
    }
}

/// Once-per-process tier selection and the cached function-pointer table.
/// Hot path: one relaxed `AtomicPtr` load + indirect call per kernel
/// invocation; the slow init runs feature detection and the env override at
/// the first call (idempotent — a racing second init stores the same
/// pointers).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod dispatch {
    use super::{Kernels, Tier, AVX2_KERNELS, SCALAR_KERNELS, SSE2_KERNELS};
    use std::sync::atomic::{AtomicPtr, AtomicU8, Ordering};

    static ACTIVE: AtomicPtr<Kernels> = AtomicPtr::new(std::ptr::null_mut());
    static ACTIVE_TIER: AtomicU8 = AtomicU8::new(u8::MAX);

    #[inline]
    pub fn active() -> &'static Kernels {
        let p = ACTIVE.load(Ordering::Relaxed);
        if p.is_null() {
            init()
        } else {
            // SAFETY: a non-null pointer was stored by init() and always
            // references one of the three 'static kernel tables.
            unsafe { &*p }
        }
    }

    pub fn active_tier() -> Tier {
        let t = ACTIVE_TIER.load(Ordering::Relaxed);
        if t == u8::MAX {
            init();
        }
        match ACTIVE_TIER.load(Ordering::Relaxed) {
            0 => Tier::Scalar,
            1 => Tier::Sse2,
            _ => Tier::Avx2Fma,
        }
    }

    #[cold]
    fn init() -> &'static Kernels {
        let tier = select_tier();
        let table: &'static Kernels = match tier {
            Tier::Scalar => &SCALAR_KERNELS,
            Tier::Sse2 => &SSE2_KERNELS,
            Tier::Avx2Fma => &AVX2_KERNELS,
        };
        ACTIVE_TIER.store(tier as u8, Ordering::Relaxed);
        ACTIVE.store(table as *const Kernels as *mut Kernels, Ordering::Relaxed);
        table
    }

    /// Widest CPU-supported tier, clamped by a `LAD_SIMD_TIER` request if
    /// one is set. Malformed or too-wide requests keep the process running
    /// (scientific sweeps should not die over an env typo) but say so once
    /// on stderr.
    fn select_tier() -> Tier {
        let widest =
            if Tier::Avx2Fma.is_supported() { Tier::Avx2Fma } else { Tier::Sse2 };
        match std::env::var("LAD_SIMD_TIER") {
            Err(_) => widest,
            Ok(raw) => match Tier::parse(&raw) {
                None => {
                    eprintln!(
                        "lad: LAD_SIMD_TIER={raw:?} not one of scalar|sse2|avx2; \
                         using {}",
                        widest.name()
                    );
                    widest
                }
                Some(req) if req <= widest => req,
                Some(req) => {
                    eprintln!(
                        "lad: LAD_SIMD_TIER={} exceeds CPU support; clamping to {}",
                        req.name(),
                        widest.name()
                    );
                    widest
                }
            },
        }
    }
}

/// Dot product (8-lane contract; tier-dispatched under `--features simd`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // SAFETY: the dispatch table only holds intrinsics tiers the CPU
        // passed feature detection for (see `dispatch`).
        unsafe { (dispatch::active().dot)(a, b) }
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        scalar::dot(a, b)
    }
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // SAFETY: dispatch table is detection-gated (see `dispatch`).
        unsafe { (dispatch::active().axpy)(alpha, x, y) }
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        scalar::axpy(alpha, x, y)
    }
}

/// x *= alpha.
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // SAFETY: dispatch table is detection-gated (see `dispatch`).
        unsafe { (dispatch::active().scale)(x, alpha) }
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        scalar::scale(x, alpha)
    }
}

/// out = a - b.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Squared Euclidean norm (f64 accumulation, 4-lane contract).
#[inline]
pub fn norm_sq(x: &[f32]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // SAFETY: dispatch table is detection-gated (see `dispatch`).
        unsafe { (dispatch::active().norm_sq)(x) }
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        scalar::norm_sq(x)
    }
}

/// Euclidean norm.
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// Squared Euclidean distance (no allocation, 4-lane contract).
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // SAFETY: dispatch table is detection-gated (see `dispatch`).
        unsafe { (dispatch::active().dist_sq)(a, b) }
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        scalar::dist_sq(a, b)
    }
}

/// Coordinate-wise mean of a family of equal-length vectors.
pub fn mean_of(vectors: &[&[f32]]) -> Vec<f32> {
    assert!(!vectors.is_empty());
    let q = vectors[0].len();
    let mut out = vec![0.0f32; q];
    for v in vectors {
        axpy(1.0, v, &mut out);
    }
    scale(&mut out, 1.0 / vectors.len() as f32);
    out
}

/// Relative L2 error between two vectors (for runtime-vs-native parity).
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let d = dist_sq(a, b).sqrt();
    let n = norm(b).max(1e-30);
    d / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..103).map(|i| (103 - i) as f32 * 0.5).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2 * naive.abs().max(1.0));
    }

    #[test]
    fn axpy_scale_sub() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
        assert_eq!(sub(&y, &x), vec![5.0, 10.0, 15.0]);
    }

    #[test]
    fn matvec_small() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut y = vec![0.0; 2];
        m.matvec(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn norms_and_dist() {
        let a = vec![3.0, 4.0];
        assert!((norm(&a) - 5.0).abs() < 1e-9);
        assert!((dist_sq(&a, &[0.0, 0.0]) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn mean_of_vectors() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let m = mean_of(&[&a, &b]);
        assert_eq!(m, vec![2.0, 4.0]);
    }

    #[test]
    fn mat_row_access() {
        let mut m = Mat::zeros(3, 2);
        m.row_mut(1).copy_from_slice(&[5.0, 6.0]);
        assert_eq!(m.row(1), &[5.0, 6.0]);
        assert_eq!(m.row(0), &[0.0, 0.0]);
    }

    #[test]
    fn tier_registry_is_consistent() {
        assert!(compiled_tiers().contains(&Tier::Scalar));
        let detected = detected_tiers();
        assert!(detected.contains(&Tier::Scalar));
        for t in &detected {
            assert!(t.is_compiled() && t.is_supported(), "{t:?}");
        }
        // the dispatcher's pick must be executable
        let active = active_tier();
        assert!(detected.contains(&active), "active {active:?} not in {detected:?}");
        // ordering: the ladder is monotone narrow → wide
        assert!(Tier::Scalar < Tier::Sse2 && Tier::Sse2 < Tier::Avx2Fma);
        // the check-once handle runs the same kernels as the per-call API
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = [0.5f32, -1.0, 2.0, -3.0, 0.25];
        for t in detected {
            let k = t.kernels_checked();
            assert_eq!(k.dot(&a, &b).to_bits(), t.dot(&a, &b).to_bits(), "{t:?}");
            assert_eq!(k.dist_sq(&a, &b).to_bits(), t.dist_sq(&a, &b).to_bits(), "{t:?}");
        }
    }

    #[test]
    fn tier_parse_round_trips() {
        for t in [Tier::Scalar, Tier::Sse2, Tier::Avx2Fma] {
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
        assert_eq!(Tier::parse("AVX2+FMA"), Some(Tier::Avx2Fma));
        assert_eq!(Tier::parse(" sse2 "), Some(Tier::Sse2));
        assert_eq!(Tier::parse("neon"), None);
        assert_eq!(Tier::parse(""), None);
    }

    /// The cross-tier equivalence pin: every tier the CPU can execute must
    /// agree bit-for-bit with the scalar reference on awkward lengths
    /// (remainder paths included). Only the scalar row runs without
    /// `--features simd`; the CI simd jobs make this the real ladder check.
    #[test]
    fn tier_kernels_match_scalar_reference() {
        let mut rng = crate::util::rng::Rng::new(0x51_AD);
        for len in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 31, 64, 100, 103, 1021] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal(0.0, 3.0) as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal(1.0, 2.0) as f32).collect();
            for tier in detected_tiers() {
                let n = tier.name();
                assert_eq!(
                    tier.dot(&a, &b).to_bits(),
                    scalar::dot(&a, &b).to_bits(),
                    "{n} dot len={len}"
                );
                assert_eq!(
                    tier.norm_sq(&a).to_bits(),
                    scalar::norm_sq(&a).to_bits(),
                    "{n} norm len={len}"
                );
                assert_eq!(
                    tier.dist_sq(&a, &b).to_bits(),
                    scalar::dist_sq(&a, &b).to_bits(),
                    "{n} dist len={len}"
                );
                let mut y1 = b.clone();
                let mut y2 = b.clone();
                tier.axpy(0.37, &a, &mut y1);
                scalar::axpy(0.37, &a, &mut y2);
                assert_eq!(y1, y2, "{n} axpy len={len}");
                let mut x1 = a.clone();
                let mut x2 = a.clone();
                tier.scale(&mut x1, -1.25);
                scalar::scale(&mut x2, -1.25);
                assert_eq!(x1, x2, "{n} scale len={len}");
            }
            // and the dispatched free functions match whatever tier is active
            assert_eq!(dot(&a, &b).to_bits(), scalar::dot(&a, &b).to_bits(), "free dot {len}");
            assert_eq!(
                dist_sq(&a, &b).to_bits(),
                scalar::dist_sq(&a, &b).to_bits(),
                "free dist {len}"
            );
        }
    }
}
