//! Small dense vector/matrix kernels used on the coordinator hot path.
//!
//! Gradients are `&[f32]`; per-subset gradient matrices are row-major
//! [`Mat`]. Everything here is allocation-conscious: the training loop calls
//! these per iteration per device.
//!
//! # Kernel backends and the lane contract
//!
//! Each hot kernel (`dot`, `norm_sq`, `dist_sq`, `axpy`, `scale`) has two
//! implementations selected at compile time:
//!
//! * [`scalar`] — the portable reference, always compiled;
//! * `simd_x86` — SSE2 intrinsics (`core::arch::x86_64`, baseline on every
//!   x86-64 CPU, stable Rust), compiled and used when the crate is built
//!   with `--features simd` on x86-64. On other targets the feature falls
//!   back to [`scalar`].
//!
//! Both backends follow one **lane contract**, so their results are
//! bit-identical and swapping backends can never change a training trace
//! (pinned by `active_kernels_match_scalar_reference` below and by
//! `rust/tests/fuzz_determinism.rs`):
//!
//! * f32 accumulations (`dot`) run 4 independent lanes over strided
//!   elements, reduced as `((l0 + l1) + l2) + l3`, then a sequential
//!   remainder loop;
//! * f64 accumulations of f32 inputs (`norm_sq`, `dist_sq`) run 2
//!   independent lanes (even/odd elements), reduced as `l0 + l1`, then the
//!   final odd element if any;
//! * element-wise kernels (`axpy`, `scale`) are trivially identical per
//!   element.

/// Row-major dense f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Mat { rows: rows.len(), cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = self · x  (rows×cols · cols).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }
}

/// Portable reference kernels, always compiled. The public free functions
/// dispatch here unless the `simd` feature selects the intrinsics backend;
/// equivalence tests compare the active backend against these.
pub mod scalar {
    /// Dot product: 4 f32 lanes + sequential remainder (lane contract).
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; 4];
        let chunks = a.len() / 4;
        for i in 0..chunks {
            let j = i * 4;
            acc[0] += a[j] * b[j];
            acc[1] += a[j + 1] * b[j + 1];
            acc[2] += a[j + 2] * b[j + 2];
            acc[3] += a[j + 3] * b[j + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for j in chunks * 4..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    /// Squared norm: 2 f64 lanes over even/odd elements + odd tail.
    #[inline]
    pub fn norm_sq(x: &[f32]) -> f64 {
        let mut acc = [0.0f64; 2];
        let pairs = x.len() / 2;
        for i in 0..pairs {
            let a = x[2 * i] as f64;
            let b = x[2 * i + 1] as f64;
            acc[0] += a * a;
            acc[1] += b * b;
        }
        let mut s = acc[0] + acc[1];
        if x.len() % 2 == 1 {
            let v = x[x.len() - 1] as f64;
            s += v * v;
        }
        s
    }

    /// Squared distance: f32 subtraction, then the [`norm_sq`] lane scheme.
    #[inline]
    pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f64; 2];
        let pairs = a.len() / 2;
        for i in 0..pairs {
            let d0 = (a[2 * i] - b[2 * i]) as f64;
            let d1 = (a[2 * i + 1] - b[2 * i + 1]) as f64;
            acc[0] += d0 * d0;
            acc[1] += d1 * d1;
        }
        let mut s = acc[0] + acc[1];
        if a.len() % 2 == 1 {
            let d = (a[a.len() - 1] - b[a.len() - 1]) as f64;
            s += d * d;
        }
        s
    }

    /// y += alpha * x (element-wise).
    #[inline]
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * *xi;
        }
    }

    /// x *= alpha (element-wise).
    #[inline]
    pub fn scale(x: &mut [f32], alpha: f32) {
        for xi in x.iter_mut() {
            *xi *= alpha;
        }
    }
}

/// SSE2 backend (baseline on x86-64, no runtime detection needed). Each
/// kernel reproduces the scalar lane contract exactly — same lanes, same
/// per-lane operation order, same reduction — so results are bit-identical.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd_x86 {
    use std::arch::x86_64::{
        _mm_add_pd, _mm_add_ps, _mm_cvtps_pd, _mm_loadu_ps, _mm_movehl_ps, _mm_mul_pd,
        _mm_mul_ps, _mm_set1_ps, _mm_setzero_pd, _mm_setzero_ps, _mm_storeu_pd, _mm_storeu_ps,
        _mm_sub_ps,
    };

    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 4;
        // SAFETY: unaligned loads/stores within slice bounds (4·chunks ≤ len).
        unsafe {
            let mut acc = _mm_setzero_ps();
            for i in 0..chunks {
                let j = 4 * i;
                let va = _mm_loadu_ps(a.as_ptr().add(j));
                let vb = _mm_loadu_ps(b.as_ptr().add(j));
                acc = _mm_add_ps(acc, _mm_mul_ps(va, vb));
            }
            let mut lanes = [0.0f32; 4];
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
            let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
            for j in chunks * 4..a.len() {
                s += a[j] * b[j];
            }
            s
        }
    }

    #[inline]
    pub fn norm_sq(x: &[f32]) -> f64 {
        let blocks = x.len() / 4;
        // SAFETY: unaligned loads within slice bounds (4·blocks ≤ len).
        unsafe {
            let mut acc = _mm_setzero_pd();
            for i in 0..blocks {
                let v = _mm_loadu_ps(x.as_ptr().add(4 * i));
                let lo = _mm_cvtps_pd(v);
                let hi = _mm_cvtps_pd(_mm_movehl_ps(v, v));
                acc = _mm_add_pd(acc, _mm_mul_pd(lo, lo));
                acc = _mm_add_pd(acc, _mm_mul_pd(hi, hi));
            }
            let mut lanes = [0.0f64; 2];
            _mm_storeu_pd(lanes.as_mut_ptr(), acc);
            // tail keeps the even/odd lane pattern (4·blocks is even)
            let mut i = blocks * 4;
            while i + 1 < x.len() {
                let a = x[i] as f64;
                let b = x[i + 1] as f64;
                lanes[0] += a * a;
                lanes[1] += b * b;
                i += 2;
            }
            let mut s = lanes[0] + lanes[1];
            if i < x.len() {
                let v = x[i] as f64;
                s += v * v;
            }
            s
        }
    }

    #[inline]
    pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let blocks = a.len() / 4;
        // SAFETY: unaligned loads within slice bounds (4·blocks ≤ len).
        unsafe {
            let mut acc = _mm_setzero_pd();
            for i in 0..blocks {
                let va = _mm_loadu_ps(a.as_ptr().add(4 * i));
                let vb = _mm_loadu_ps(b.as_ptr().add(4 * i));
                let d = _mm_sub_ps(va, vb);
                let lo = _mm_cvtps_pd(d);
                let hi = _mm_cvtps_pd(_mm_movehl_ps(d, d));
                acc = _mm_add_pd(acc, _mm_mul_pd(lo, lo));
                acc = _mm_add_pd(acc, _mm_mul_pd(hi, hi));
            }
            let mut lanes = [0.0f64; 2];
            _mm_storeu_pd(lanes.as_mut_ptr(), acc);
            let mut i = blocks * 4;
            while i + 1 < a.len() {
                let d0 = (a[i] - b[i]) as f64;
                let d1 = (a[i + 1] - b[i + 1]) as f64;
                lanes[0] += d0 * d0;
                lanes[1] += d1 * d1;
                i += 2;
            }
            let mut s = lanes[0] + lanes[1];
            if i < a.len() {
                let d = (a[i] - b[i]) as f64;
                s += d * d;
            }
            s
        }
    }

    #[inline]
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let chunks = x.len() / 4;
        // SAFETY: unaligned loads/stores within slice bounds (4·chunks ≤ len).
        unsafe {
            let va = _mm_set1_ps(alpha);
            for i in 0..chunks {
                let j = 4 * i;
                let vx = _mm_loadu_ps(x.as_ptr().add(j));
                let vy = _mm_loadu_ps(y.as_ptr().add(j));
                _mm_storeu_ps(y.as_mut_ptr().add(j), _mm_add_ps(vy, _mm_mul_ps(va, vx)));
            }
        }
        for j in chunks * 4..x.len() {
            y[j] += alpha * x[j];
        }
    }

    #[inline]
    pub fn scale(x: &mut [f32], alpha: f32) {
        let chunks = x.len() / 4;
        // SAFETY: unaligned loads/stores within slice bounds (4·chunks ≤ len).
        unsafe {
            let va = _mm_set1_ps(alpha);
            for i in 0..chunks {
                let j = 4 * i;
                let vx = _mm_loadu_ps(x.as_ptr().add(j));
                _mm_storeu_ps(x.as_mut_ptr().add(j), _mm_mul_ps(vx, va));
            }
        }
        for j in chunks * 4..x.len() {
            x[j] *= alpha;
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use self::simd_x86 as active;

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
use self::scalar as active;

/// True when the intrinsics backend is compiled in and active.
pub const SIMD_ACTIVE: bool = cfg!(all(feature = "simd", target_arch = "x86_64"));

/// Dot product (4-lane contract; SSE2 under `--features simd` on x86-64).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    active::dot(a, b)
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    active::axpy(alpha, x, y)
}

/// x *= alpha.
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    active::scale(x, alpha)
}

/// out = a - b.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Squared Euclidean norm (f64 accumulation, 2-lane contract).
#[inline]
pub fn norm_sq(x: &[f32]) -> f64 {
    active::norm_sq(x)
}

/// Euclidean norm.
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// Squared Euclidean distance (no allocation, 2-lane contract).
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    active::dist_sq(a, b)
}

/// Coordinate-wise mean of a family of equal-length vectors.
pub fn mean_of(vectors: &[&[f32]]) -> Vec<f32> {
    assert!(!vectors.is_empty());
    let q = vectors[0].len();
    let mut out = vec![0.0f32; q];
    for v in vectors {
        axpy(1.0, v, &mut out);
    }
    scale(&mut out, 1.0 / vectors.len() as f32);
    out
}

/// Relative L2 error between two vectors (for runtime-vs-native parity).
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let d = dist_sq(a, b).sqrt();
    let n = norm(b).max(1e-30);
    d / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..103).map(|i| (103 - i) as f32 * 0.5).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2 * naive.abs().max(1.0));
    }

    #[test]
    fn axpy_scale_sub() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
        assert_eq!(sub(&y, &x), vec![5.0, 10.0, 15.0]);
    }

    #[test]
    fn matvec_small() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut y = vec![0.0; 2];
        m.matvec(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn norms_and_dist() {
        let a = vec![3.0, 4.0];
        assert!((norm(&a) - 5.0).abs() < 1e-9);
        assert!((dist_sq(&a, &[0.0, 0.0]) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn mean_of_vectors() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let m = mean_of(&[&a, &b]);
        assert_eq!(m, vec![2.0, 4.0]);
    }

    #[test]
    fn mat_row_access() {
        let mut m = Mat::zeros(3, 2);
        m.row_mut(1).copy_from_slice(&[5.0, 6.0]);
        assert_eq!(m.row(1), &[5.0, 6.0]);
        assert_eq!(m.row(0), &[0.0, 0.0]);
    }

    /// The backend equivalence pin: whatever backend is active must agree
    /// bit-for-bit with the scalar reference on awkward lengths (remainder
    /// paths included). Trivial when `simd` is off; the real check runs
    /// under `--features simd`.
    #[test]
    fn active_kernels_match_scalar_reference() {
        let mut rng = crate::util::rng::Rng::new(0x51_AD);
        for len in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 15, 16, 17, 31, 64, 100, 103, 1021] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal(0.0, 3.0) as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal(1.0, 2.0) as f32).collect();
            assert_eq!(dot(&a, &b).to_bits(), scalar::dot(&a, &b).to_bits(), "dot len={len}");
            assert_eq!(norm_sq(&a).to_bits(), scalar::norm_sq(&a).to_bits(), "norm len={len}");
            assert_eq!(
                dist_sq(&a, &b).to_bits(),
                scalar::dist_sq(&a, &b).to_bits(),
                "dist len={len}"
            );
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            axpy(0.37, &a, &mut y1);
            scalar::axpy(0.37, &a, &mut y2);
            assert_eq!(y1, y2, "axpy len={len}");
            let mut x1 = a.clone();
            let mut x2 = a.clone();
            scale(&mut x1, -1.25);
            scalar::scale(&mut x2, -1.25);
            assert_eq!(x1, x2, "scale len={len}");
        }
    }
}
