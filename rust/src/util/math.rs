//! Small dense vector/matrix kernels used on the coordinator hot path.
//!
//! Gradients are `&[f32]`; per-subset gradient matrices are row-major
//! [`Mat`]. Everything here is allocation-conscious: the training loop calls
//! these per iteration per device.

/// Row-major dense f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Mat { rows: rows.len(), cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = self · x  (rows×cols · cols).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }
}

/// Dot product with 4-lane manual unrolling (autovectorizes well at -O3).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * *xi;
    }
}

/// x *= alpha.
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// out = a - b.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(x: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for &v in x {
        s += (v as f64) * (v as f64);
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// Squared Euclidean distance (no allocation).
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        s += d * d;
    }
    s
}

/// Coordinate-wise mean of a family of equal-length vectors.
pub fn mean_of(vectors: &[&[f32]]) -> Vec<f32> {
    assert!(!vectors.is_empty());
    let q = vectors[0].len();
    let mut out = vec![0.0f32; q];
    for v in vectors {
        axpy(1.0, v, &mut out);
    }
    scale(&mut out, 1.0 / vectors.len() as f32);
    out
}

/// Relative L2 error between two vectors (for runtime-vs-native parity).
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let d = dist_sq(a, b).sqrt();
    let n = norm(b).max(1e-30);
    d / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..103).map(|i| (103 - i) as f32 * 0.5).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2 * naive.abs().max(1.0));
    }

    #[test]
    fn axpy_scale_sub() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
        assert_eq!(sub(&y, &x), vec![5.0, 10.0, 15.0]);
    }

    #[test]
    fn matvec_small() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut y = vec![0.0; 2];
        m.matvec(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn norms_and_dist() {
        let a = vec![3.0, 4.0];
        assert!((norm(&a) - 5.0).abs() < 1e-9);
        assert!((dist_sq(&a, &[0.0, 0.0]) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn mean_of_vectors() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let m = mean_of(&[&a, &b]);
        assert_eq!(m, vec![2.0, 4.0]);
    }

    #[test]
    fn mat_row_access() {
        let mut m = Mat::zeros(3, 2);
        m.row_mut(1).copy_from_slice(&[5.0, 6.0]);
        assert_eq!(m.row(1), &[5.0, 6.0]);
        assert_eq!(m.row(0), &[0.0, 0.0]);
    }
}
