//! Native Rust gradient oracle over the §VII linear-regression workload.
//!
//! Computes residuals once per iteration, then encodes per-device messages
//! with the shared encoder — bit-identical to what the per-device
//! distributed path produces, ~N× cheaper on a single core.

use super::CodedGradOracle;
use crate::data::linreg::LinRegDataset;
use crate::util::math::{axpy, scale, Mat};
use crate::Result;

pub struct NativeLinReg {
    ds: LinRegDataset,
    /// scratch: per-subset gradient matrix reused across iterations
    scratch: Mat,
}

impl NativeLinReg {
    pub fn new(ds: LinRegDataset) -> Self {
        let scratch = Mat::zeros(ds.n(), ds.dim());
        NativeLinReg { ds, scratch }
    }

    pub fn dataset(&self) -> &LinRegDataset {
        &self.ds
    }
}

impl CodedGradOracle for NativeLinReg {
    fn n(&self) -> usize {
        self.ds.n()
    }
    fn dim(&self) -> usize {
        self.ds.dim()
    }

    fn coded_grads(
        &mut self,
        x: &[f32],
        subsets_per_device: &[Vec<usize>],
        out: &mut Mat,
    ) -> Result<()> {
        assert_eq!(out.rows, subsets_per_device.len());
        assert_eq!(out.cols, self.ds.dim());
        self.ds.grad_matrix(x, &mut self.scratch);
        for (i, subs) in subsets_per_device.iter().enumerate() {
            let row = out.row_mut(i);
            row.iter_mut().for_each(|v| *v = 0.0);
            for &k in subs {
                axpy(1.0, self.scratch.row(k), row);
            }
            scale(row, 1.0 / subs.len() as f32);
        }
        Ok(())
    }

    fn grad_matrix(&mut self, x: &[f32], out: &mut Mat) -> Result<()> {
        self.ds.grad_matrix(x, out);
        Ok(())
    }

    fn loss(&mut self, x: &[f32]) -> Result<f64> {
        Ok(self.ds.loss(x))
    }

    fn name(&self) -> &'static str {
        "native-linreg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn coded_matches_manual_encoding() {
        let mut rng = Rng::new(1);
        let ds = LinRegDataset::generate(8, 5, 0.2, &mut rng);
        let x = rng.gauss_vec(5);
        let mut oracle = NativeLinReg::new(ds.clone());
        let subsets = vec![vec![0usize, 3], vec![1, 2, 7], vec![4]];
        let mut out = Mat::zeros(3, 5);
        oracle.coded_grads(&x, &subsets, &mut out).unwrap();
        for (i, subs) in subsets.iter().enumerate() {
            let mut want = vec![0.0f32; 5];
            for &k in subs {
                let g = ds.subset_grad(k, &x);
                for j in 0..5 {
                    want[j] += g[j];
                }
            }
            for j in 0..5 {
                want[j] /= subs.len() as f32;
                assert!((out.row(i)[j] - want[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn loss_passthrough() {
        let mut rng = Rng::new(2);
        let ds = LinRegDataset::generate(5, 3, 0.0, &mut rng);
        let x = vec![0.0f32; 3];
        let mut oracle = NativeLinReg::new(ds.clone());
        assert_eq!(oracle.loss(&x).unwrap(), ds.loss(&x));
    }
}
