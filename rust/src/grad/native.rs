//! Native Rust gradient oracle over the §VII linear-regression workload.
//!
//! Computes residuals once per iteration, then encodes per-device messages
//! with the shared encoder — bit-identical to what the per-device
//! distributed path produces, ~N× cheaper on a single core.

use super::CodedGradOracle;
use crate::data::linreg::LinRegDataset;
use crate::util::math::{axpy, scale, Mat};
use crate::util::parallel::{Parallelism, Pool};
use crate::Result;

/// Below this many output elements (rows × cols) the parallel row fill is
/// all dispatch overhead; stay on the calling thread. Purely a performance
/// gate — both paths are bit-identical.
const PAR_MIN_ELEMS: usize = 4096;

pub struct NativeLinReg {
    ds: LinRegDataset,
    /// scratch: per-subset gradient matrix reused across iterations
    scratch: Mat,
    /// worker pool for the row-parallel kernels (serial by default; the
    /// trainer injects its run-wide pool via [`CodedGradOracle::set_pool`])
    pool: Pool,
}

impl NativeLinReg {
    pub fn new(ds: LinRegDataset) -> Self {
        let scratch = Mat::zeros(ds.n(), ds.dim());
        NativeLinReg { ds, scratch, pool: Pool::serial() }
    }

    /// Builder-style scoped-spawn parallelism (same effect as
    /// [`CodedGradOracle::set_parallelism`]).
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.pool = Pool::scoped(par);
        self
    }

    /// Builder-style shared worker pool (same effect as
    /// [`CodedGradOracle::set_pool`]).
    pub fn with_pool(mut self, pool: &Pool) -> Self {
        self.pool = pool.clone();
        self
    }

    pub fn dataset(&self) -> &LinRegDataset {
        &self.ds
    }

    fn effective_pool(&self, elems: usize) -> Pool {
        if elems >= PAR_MIN_ELEMS {
            self.pool.clone()
        } else {
            Pool::serial()
        }
    }
}

impl CodedGradOracle for NativeLinReg {
    fn n(&self) -> usize {
        self.ds.n()
    }
    fn dim(&self) -> usize {
        self.ds.dim()
    }

    fn coded_grads(
        &mut self,
        x: &[f32],
        subsets_per_device: &[Vec<usize>],
        out: &mut Mat,
    ) -> Result<()> {
        assert_eq!(out.rows, subsets_per_device.len());
        assert_eq!(out.cols, self.ds.dim());
        let pool = self.effective_pool(out.rows * out.cols);
        self.ds.grad_matrix_pool(x, &mut self.scratch, &pool);
        // Per-device encode: each output row only reads the shared scratch
        // matrix, so rows parallelize with no synchronization. Accumulation
        // order within a row is the subset order either way — bit-identical
        // to the serial loop.
        let cols = out.cols;
        let scratch = &self.scratch;
        pool.par_chunks_mut(&mut out.data, cols, |i, row| {
            let subs = &subsets_per_device[i];
            row.iter_mut().for_each(|v| *v = 0.0);
            for &k in subs {
                axpy(1.0, scratch.row(k), row);
            }
            scale(row, 1.0 / subs.len() as f32);
        });
        Ok(())
    }

    fn grad_matrix(&mut self, x: &[f32], out: &mut Mat) -> Result<()> {
        let pool = self.effective_pool(out.rows * out.cols);
        self.ds.grad_matrix_pool(x, out, &pool);
        Ok(())
    }

    fn loss(&mut self, x: &[f32]) -> Result<f64> {
        Ok(self.ds.loss(x))
    }

    fn name(&self) -> &'static str {
        "native-linreg"
    }

    fn set_parallelism(&mut self, par: Parallelism) {
        self.pool = Pool::scoped(par);
    }

    fn set_pool(&mut self, pool: &Pool) {
        self.pool = pool.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn coded_matches_manual_encoding() {
        let mut rng = Rng::new(1);
        let ds = LinRegDataset::generate(8, 5, 0.2, &mut rng);
        let x = rng.gauss_vec(5);
        let mut oracle = NativeLinReg::new(ds.clone());
        let subsets = vec![vec![0usize, 3], vec![1, 2, 7], vec![4]];
        let mut out = Mat::zeros(3, 5);
        oracle.coded_grads(&x, &subsets, &mut out).unwrap();
        for (i, subs) in subsets.iter().enumerate() {
            let mut want = vec![0.0f32; 5];
            for &k in subs {
                let g = ds.subset_grad(k, &x);
                for j in 0..5 {
                    want[j] += g[j];
                }
            }
            for j in 0..5 {
                want[j] /= subs.len() as f32;
                assert!((out.row(i)[j] - want[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn parallel_oracle_is_bit_identical_to_serial() {
        // sized above PAR_MIN_ELEMS so the parallel path actually engages
        let mut rng = Rng::new(3);
        let (n, q) = (64, 128);
        let ds = LinRegDataset::generate(n, q, 0.4, &mut rng);
        let x = rng.gauss_vec(q);
        let subsets: Vec<Vec<usize>> =
            (0..n).map(|i| vec![i, (i + 3) % n, (i + 17) % n]).collect();
        let pool = Pool::new(8);
        let mut serial = NativeLinReg::new(ds.clone());
        let mut threaded =
            NativeLinReg::new(ds.clone()).with_parallelism(Parallelism::new(8));
        let mut pooled = NativeLinReg::new(ds).with_pool(&pool);
        let mut a = Mat::zeros(n, q);
        let mut b = Mat::zeros(n, q);
        let mut c = Mat::zeros(n, q);
        serial.coded_grads(&x, &subsets, &mut a).unwrap();
        threaded.coded_grads(&x, &subsets, &mut b).unwrap();
        pooled.coded_grads(&x, &subsets, &mut c).unwrap();
        assert_eq!(a.data, b.data, "coded_grads diverged (scoped)");
        assert_eq!(a.data, c.data, "coded_grads diverged (pool)");
        let mut ga = Mat::zeros(n, q);
        let mut gb = Mat::zeros(n, q);
        let mut gc = Mat::zeros(n, q);
        serial.grad_matrix(&x, &mut ga).unwrap();
        threaded.grad_matrix(&x, &mut gb).unwrap();
        pooled.grad_matrix(&x, &mut gc).unwrap();
        assert_eq!(ga.data, gb.data, "grad_matrix diverged (scoped)");
        assert_eq!(ga.data, gc.data, "grad_matrix diverged (pool)");
    }

    #[test]
    fn loss_passthrough() {
        let mut rng = Rng::new(2);
        let ds = LinRegDataset::generate(5, 3, 0.0, &mut rng);
        let x = vec![0.0f32; 3];
        let mut oracle = NativeLinReg::new(ds.clone());
        assert_eq!(oracle.loss(&x).unwrap(), ds.loss(&x));
    }
}
