//! PJRT-backed gradient oracle: executes the AOT-lowered JAX + Pallas
//! artifacts produced by `python/compile/aot.py`.
//!
//! Artifacts used (names fixed by the manifest):
//! * `coded_grad`   — the fused Pallas kernel: (x[Q], Z[N,Q], y[N], A[N,N])
//!                    → coded[N,Q], where A is the per-iteration 0/1
//!                    assignment mask (rows pre-scaled by 1/dᵢ happen here
//!                    in Rust by passing A[i,k] = 1/dᵢ).
//! * `linreg_grads` — (x, Z, y) → per-subset gradient matrix G[N,Q].
//! * `linreg_loss`  — (x, Z, y) → scalar F(x).

use super::CodedGradOracle;
use crate::data::linreg::LinRegDataset;
use crate::runtime::{Runtime, TensorIn};
use crate::util::math::Mat;
use crate::Result;
use anyhow::Context;

pub struct RuntimeLinReg {
    rt: Runtime,
    ds: LinRegDataset,
    /// dense assignment mask scratch (N×N), rebuilt each iteration
    mask: Vec<f32>,
}

impl RuntimeLinReg {
    /// `rt` must contain `coded_grad`, `linreg_grads`, `linreg_loss`
    /// artifacts whose meta {n, q} match the dataset.
    pub fn new(rt: Runtime, ds: LinRegDataset) -> Result<Self> {
        for name in ["coded_grad", "linreg_grads", "linreg_loss"] {
            anyhow::ensure!(rt.has(name), "artifact {name:?} missing — run `make artifacts`");
            let meta = &rt.manifest().entries[name].meta;
            let n = *meta.get("n").context("artifact missing meta.n")? as usize;
            let q = *meta.get("q").context("artifact missing meta.q")? as usize;
            anyhow::ensure!(
                n == ds.n() && q == ds.dim(),
                "artifact {name:?} built for N={n},Q={q} but dataset is N={},Q={} — re-run `make artifacts`",
                ds.n(),
                ds.dim()
            );
        }
        let n = ds.n();
        Ok(RuntimeLinReg { rt, ds, mask: vec![0.0; n * n] })
    }

    pub fn runtime_stats(&self) -> &crate::runtime::RuntimeStats {
        &self.rt.stats
    }
}

impl CodedGradOracle for RuntimeLinReg {
    fn n(&self) -> usize {
        self.ds.n()
    }
    fn dim(&self) -> usize {
        self.ds.dim()
    }

    fn coded_grads(
        &mut self,
        x: &[f32],
        subsets_per_device: &[Vec<usize>],
        out: &mut Mat,
    ) -> Result<()> {
        let n = self.ds.n() as i64;
        let q = self.ds.dim() as i64;
        assert_eq!(subsets_per_device.len(), self.ds.n());
        // A[i, k] = 1/dᵢ when subset k assigned to device i
        self.mask.iter_mut().for_each(|v| *v = 0.0);
        for (i, subs) in subsets_per_device.iter().enumerate() {
            let w = 1.0 / subs.len() as f32;
            for &k in subs {
                self.mask[i * self.ds.n() + k] = w;
            }
        }
        let outs = self.rt.exec_f32(
            "coded_grad",
            &[
                TensorIn::F32(x, &[q]),
                TensorIn::F32(&self.ds.z.data, &[n, q]),
                TensorIn::F32(&self.ds.y, &[n]),
                TensorIn::F32(&self.mask, &[n, n]),
            ],
        )?;
        out.data.copy_from_slice(&outs[0]);
        Ok(())
    }

    fn grad_matrix(&mut self, x: &[f32], out: &mut Mat) -> Result<()> {
        let n = self.ds.n() as i64;
        let q = self.ds.dim() as i64;
        let outs = self.rt.exec_f32(
            "linreg_grads",
            &[
                TensorIn::F32(x, &[q]),
                TensorIn::F32(&self.ds.z.data, &[n, q]),
                TensorIn::F32(&self.ds.y, &[n]),
            ],
        )?;
        out.data.copy_from_slice(&outs[0]);
        Ok(())
    }

    fn loss(&mut self, x: &[f32]) -> Result<f64> {
        let n = self.ds.n() as i64;
        let q = self.ds.dim() as i64;
        let outs = self.rt.exec_f32(
            "linreg_loss",
            &[
                TensorIn::F32(x, &[q]),
                TensorIn::F32(&self.ds.z.data, &[n, q]),
                TensorIn::F32(&self.ds.y, &[n]),
            ],
        )?;
        Ok(outs[0][0] as f64)
    }

    fn name(&self) -> &'static str {
        "runtime-linreg"
    }
}
