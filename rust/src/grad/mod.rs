//! Gradient oracles — how device compute is realized.
//!
//! [`CodedGradOracle`] is the trainer's view of Layer 1/2: per iteration it
//! produces every device's coded vector (eq. 5) and the training loss.
//! Two implementations:
//!
//! * [`NativeLinReg`] — pure Rust (fast simulation path, no artifacts).
//! * [`RuntimeLinReg`] — executes the AOT artifacts: the fused Pallas
//!   `coded_grad` kernel for the coded vectors and `linreg_loss`/`
//!   linreg_grads` for diagnostics. Bit-parity with the native oracle is
//!   asserted by `rust/tests/integration_runtime.rs`.

pub mod native;
pub mod runtime_oracle;

use crate::util::math::Mat;
use crate::util::parallel::{Parallelism, Pool};
use crate::Result;

/// The trainer's gradient interface.
pub trait CodedGradOracle {
    /// Number of subsets / devices N.
    fn n(&self) -> usize;
    /// Model dimension Q.
    fn dim(&self) -> usize;
    /// Fill `out` (N×Q): row i = (1/dᵢ) Σ_{k ∈ subsets[i]} ∇f_k(x) — the
    /// *true* message of each device (before attack/compression).
    fn coded_grads(
        &mut self,
        x: &[f32],
        subsets_per_device: &[Vec<usize>],
        out: &mut Mat,
    ) -> Result<()>;
    /// Per-subset gradient matrix (row k = ∇f_k(x)); used by DRACO and
    /// diagnostics.
    fn grad_matrix(&mut self, x: &[f32], out: &mut Mat) -> Result<()>;
    /// Training loss F(x).
    fn loss(&mut self, x: &[f32]) -> Result<f64>;
    /// Oracle label for logs.
    fn name(&self) -> &'static str;
    /// Hint: the oracle may use up to this many worker threads for its
    /// device-parallel compute. Implementations must stay bit-identical to
    /// their serial path (default: ignore the hint).
    fn set_parallelism(&mut self, _par: Parallelism) {}
    /// Adopt a shared persistent worker pool for the device-parallel
    /// compute. The default degrades to [`Self::set_parallelism`] (scoped
    /// spawns with the pool's thread budget); implementations that can hold
    /// the handle should override to reuse the workers across iterations.
    fn set_pool(&mut self, pool: &Pool) {
        self.set_parallelism(pool.parallelism());
    }
}

pub use native::NativeLinReg;
pub use runtime_oracle::RuntimeLinReg;
