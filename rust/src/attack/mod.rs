//! Byzantine attack behaviours (§III-B, §VII).
//!
//! The paper's experiments use *sign-flipping* with coefficient −2 (each
//! Byzantine device multiplies its true message by −2; in Com-LAD the result
//! is then compressed like any other message). The zoo adds the standard
//! literature attacks for the ablation benches: ALIE (Baruch et al.),
//! inner-product manipulation (Xie et al.), mimic, zero, Gaussian noise and
//! random spikes.

use crate::config::AttackKind;
use crate::util::math::{mean_of, norm};
use crate::util::rng::Rng;

/// Context handed to an attack each iteration. Both message families are
/// borrowed slices-of-slices so callers can point straight into a
/// contiguous gradient slab (the zero-copy trainer/leader paths) without
/// materializing per-device `Vec`s.
pub struct AttackContext<'a> {
    /// Messages the honest devices are about to send (post-coding,
    /// pre-compression) — the omniscient-adversary worst case.
    pub honest: &'a [&'a [f32]],
    /// The message each Byzantine device WOULD have sent if honest
    /// (one per Byzantine device).
    pub own_true: &'a [&'a [f32]],
    pub rng: &'a mut Rng,
}

/// A Byzantine behaviour: craft one message per Byzantine device.
pub trait Attack: Send + Sync {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>>;
    fn name(&self) -> String;
}

/// Sign-flip (paper default): bᵢ = coeff · gᵢ with coeff = −2.
pub struct SignFlip {
    pub coeff: f32,
}

impl Attack for SignFlip {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>> {
        ctx.own_true
            .iter()
            .map(|g| g.iter().map(|x| self.coeff * x).collect())
            .collect()
    }
    fn name(&self) -> String {
        format!("sign-flip({})", self.coeff)
    }
}

/// Send the zero vector (stealthy under norm filters).
pub struct Zero;

impl Attack for Zero {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>> {
        let q = ctx.own_true.first().map(|v| v.len()).unwrap_or(0);
        vec![vec![0.0; q]; ctx.own_true.len()]
    }
    fn name(&self) -> String {
        "zero".into()
    }
}

/// Additive Gaussian noise on the true message.
pub struct GaussianNoise {
    pub std: f32,
}

impl Attack for GaussianNoise {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(ctx.own_true.len());
        for g in ctx.own_true {
            out.push(
                g.iter()
                    .map(|x| x + ctx.rng.normal(0.0, self.std as f64) as f32)
                    .collect(),
            );
        }
        out
    }
    fn name(&self) -> String {
        format!("gaussian({})", self.std)
    }
}

/// ALIE — "a little is enough": collude at mean − z·std per coordinate,
/// staying inside the honest envelope to evade distance filters.
pub struct Alie {
    pub z: f32,
}

impl Default for Alie {
    fn default() -> Self {
        Alie { z: 1.0 }
    }
}

impl Attack for Alie {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>> {
        if ctx.honest.is_empty() {
            return ctx.own_true.iter().map(|g| g.to_vec()).collect();
        }
        let q = ctx.honest[0].len();
        let n = ctx.honest.len() as f64;
        let mut mean = vec![0.0f64; q];
        for h in ctx.honest {
            for j in 0..q {
                mean[j] += h[j] as f64;
            }
        }
        mean.iter_mut().for_each(|v| *v /= n);
        let mut var = vec![0.0f64; q];
        for h in ctx.honest {
            for j in 0..q {
                let d = h[j] as f64 - mean[j];
                var[j] += d * d;
            }
        }
        let msg: Vec<f32> = (0..q)
            .map(|j| (mean[j] - self.z as f64 * (var[j] / n).sqrt()) as f32)
            .collect();
        vec![msg; ctx.own_true.len()]
    }
    fn name(&self) -> String {
        format!("alie(z={})", self.z)
    }
}

/// Inner-product manipulation: collude at −ε · mean(honest).
pub struct Ipm {
    pub eps: f32,
}

impl Attack for Ipm {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>> {
        if ctx.honest.is_empty() {
            return ctx.own_true.iter().map(|g| g.to_vec()).collect();
        }
        let mean = mean_of(ctx.honest);
        let msg: Vec<f32> = mean.iter().map(|x| -self.eps * x).collect();
        vec![msg; ctx.own_true.len()]
    }
    fn name(&self) -> String {
        format!("ipm(eps={})", self.eps)
    }
}

/// Mimic: replay one fixed honest device's message (amplifies heterogeneity).
pub struct Mimic;

impl Attack for Mimic {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>> {
        if ctx.honest.is_empty() {
            return ctx.own_true.iter().map(|g| g.to_vec()).collect();
        }
        // deterministically mimic the honest message with the largest norm
        let target = ctx
            .honest
            .iter()
            .max_by(|a, b| norm(a).partial_cmp(&norm(b)).unwrap())
            .unwrap();
        vec![target.to_vec(); ctx.own_true.len()]
    }
    fn name(&self) -> String {
        "mimic".into()
    }
}

/// Huge random spike (easily filtered; lower bound for robust rules).
pub struct RandomSpike {
    pub scale: f32,
}

impl Attack for RandomSpike {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>> {
        let q = ctx.own_true.first().map(|v| v.len()).unwrap_or(0);
        (0..ctx.own_true.len())
            .map(|_| (0..q).map(|_| (ctx.rng.f32() * 2.0 - 1.0) * self.scale).collect())
            .collect()
    }
    fn name(&self) -> String {
        format!("spike({})", self.scale)
    }
}

/// No attack — Byzantine devices behave honestly (control runs).
pub struct NoAttack;

impl Attack for NoAttack {
    fn craft(&self, ctx: &mut AttackContext) -> Vec<Vec<f32>> {
        ctx.own_true.iter().map(|g| g.to_vec()).collect()
    }
    fn name(&self) -> String {
        "none".into()
    }
}

/// Build an attack from a config kind.
pub fn from_kind(kind: AttackKind) -> Box<dyn Attack> {
    match kind {
        AttackKind::None => Box::new(NoAttack),
        AttackKind::SignFlip { coeff } => Box::new(SignFlip { coeff }),
        AttackKind::Gaussian { std } => Box::new(GaussianNoise { std }),
        AttackKind::Zero => Box::new(Zero),
        AttackKind::Alie => Box::new(Alie::default()),
        AttackKind::Ipm { eps } => Box::new(Ipm { eps }),
        AttackKind::Mimic => Box::new(Mimic),
        AttackKind::RandomSpike { scale } => Box::new(RandomSpike { scale }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn refs(v: &[Vec<f32>]) -> Vec<&[f32]> {
        v.iter().map(|m| m.as_slice()).collect()
    }

    fn ctx_fixture<'a>(
        honest: &'a [&'a [f32]],
        own: &'a [&'a [f32]],
        rng: &'a mut Rng,
    ) -> AttackContext<'a> {
        AttackContext { honest, own_true: own, rng }
    }

    #[test]
    fn sign_flip_scales_own_message() {
        let honest = vec![vec![1.0f32, 2.0]];
        let own = vec![vec![3.0f32, -4.0]];
        let (honest, own) = (refs(&honest), refs(&own));
        let mut rng = Rng::new(1);
        let out = SignFlip { coeff: -2.0 }.craft(&mut ctx_fixture(&honest, &own, &mut rng));
        assert_eq!(out, vec![vec![-6.0, 8.0]]);
    }

    #[test]
    fn alie_stays_within_one_std() {
        let honest = vec![vec![1.0f32], vec![2.0], vec![3.0]];
        let own = vec![vec![0.0f32]; 2];
        let (honest, own) = (refs(&honest), refs(&own));
        let mut rng = Rng::new(2);
        let out = Alie { z: 1.0 }.craft(&mut ctx_fixture(&honest, &own, &mut rng));
        assert_eq!(out.len(), 2);
        // mean 2, pop std ≈ 0.816 => msg ≈ 1.184
        assert!((out[0][0] - 1.1835).abs() < 1e-3, "{}", out[0][0]);
        assert_eq!(out[0], out[1]); // collusion
    }

    #[test]
    fn ipm_is_negative_scaled_mean() {
        let honest = vec![vec![2.0f32, 4.0], vec![4.0, 8.0]];
        let own = vec![vec![0.0f32, 0.0]];
        let (honest, own) = (refs(&honest), refs(&own));
        let mut rng = Rng::new(3);
        let out = Ipm { eps: 0.5 }.craft(&mut ctx_fixture(&honest, &own, &mut rng));
        assert_eq!(out[0], vec![-1.5, -3.0]);
    }

    #[test]
    fn mimic_copies_an_honest_message() {
        let honest = vec![vec![1.0f32], vec![5.0]];
        let own = vec![vec![0.0f32]];
        let (honest, own) = (refs(&honest), refs(&own));
        let mut rng = Rng::new(4);
        let out = Mimic.craft(&mut ctx_fixture(&honest, &own, &mut rng));
        assert_eq!(out[0], vec![5.0]);
    }

    #[test]
    fn all_kinds_build_and_produce_right_count() {
        let honest = vec![vec![1.0f32, 1.0]; 4];
        let own = vec![vec![1.0f32, 1.0]; 3];
        let (honest, own) = (refs(&honest), refs(&own));
        for kind in [
            AttackKind::None,
            AttackKind::SignFlip { coeff: -2.0 },
            AttackKind::Gaussian { std: 1.0 },
            AttackKind::Zero,
            AttackKind::Alie,
            AttackKind::Ipm { eps: 0.5 },
            AttackKind::Mimic,
            AttackKind::RandomSpike { scale: 10.0 },
        ] {
            let atk = from_kind(kind);
            let mut rng = Rng::new(5);
            let out = atk.craft(&mut ctx_fixture(&honest, &own, &mut rng));
            assert_eq!(out.len(), 3, "{}", atk.name());
            assert!(out.iter().all(|m| m.len() == 2));
        }
    }
}
