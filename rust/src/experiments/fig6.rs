//! Fig. 6 — training loss vs iterations under **compressed** communication.
//! Paper setting: N=100, H=70, rand-K sparsification with Q̂=30, d=3,
//! γ=3e-7, σ_H=0.3, CWTM 0.1, TGN 0.2; Byzantine devices sign-flip (−2)
//! then compress.
//!
//! Methods: Com-VA, Com-CWTM, Com-CWTM-NNM, Com-TGN, Com-LAD-CWTM,
//! Com-LAD-CWTM-NNM.

use super::common::{run_figure_par, ExperimentOutput, Series, Variant};
use crate::config::{AggregatorKind, AttackKind, CompressionKind, OracleKind, TrainConfig};
use crate::util::parallel::Parallelism;
use crate::Result;

#[derive(Debug, Clone)]
pub struct Fig6Params {
    pub n: usize,
    pub h: usize,
    pub q: usize,
    pub q_hat: usize,
    pub iters: usize,
    pub lr: f64,
    pub sigma_h: f64,
    pub d: usize,
    pub oracle: OracleKind,
    pub seed: u64,
    /// total thread budget for the figure (0 = all cores): the variant
    /// fan-out and each variant's inner stages share one budgeted pool
    pub threads: usize,
}

impl Default for Fig6Params {
    fn default() -> Self {
        Fig6Params {
            n: 100,
            h: 70,
            q: 100,
            q_hat: 30,
            // time-rescaled vs the paper's γ=3e-7 (see EXPERIMENTS.md);
            // the rand-K noise requires a smaller step than Fig 4
            iters: 3000,
            lr: 1e-5,
            sigma_h: 0.3,
            d: 3,
            oracle: OracleKind::NativeLinreg,
            seed: 6,
            threads: 0,
        }
    }
}

fn variants(p: &Fig6Params) -> Vec<Variant> {
    let mut base = TrainConfig::default();
    base.n_devices = p.n;
    base.n_honest = p.h;
    base.dim = p.q;
    base.iters = p.iters;
    base.lr = p.lr;
    base.sigma_h = p.sigma_h;
    base.attack = AttackKind::SignFlip { coeff: -2.0 };
    base.compression = CompressionKind::RandK { k: p.q_hat };
    base.oracle = p.oracle;
    base.log_every = (p.iters / 30).max(1);
    let mut vs = Vec::new();
    // non-redundant compressed baselines
    for (label, kind, nnm, trim) in [
        ("com-va", AggregatorKind::Mean, false, 0.1),
        ("com-cwtm", AggregatorKind::Cwtm, false, 0.1),
        ("com-cwtm-nnm", AggregatorKind::Cwtm, true, 0.1),
        ("com-tgn", AggregatorKind::Tgn, false, 0.2),
    ] {
        let mut cfg = base.clone();
        cfg.d = 1;
        cfg.aggregator = kind;
        cfg.nnm = nnm;
        cfg.trim_frac = trim;
        vs.push(Variant { label: label.into(), cfg, draco_r: None });
    }
    // Com-LAD
    for (label, nnm) in [("com-lad-cwtm", false), ("com-lad-cwtm-nnm", true)] {
        let mut cfg = base.clone();
        cfg.d = p.d;
        cfg.aggregator = AggregatorKind::Cwtm;
        cfg.nnm = nnm;
        cfg.trim_frac = 0.1;
        vs.push(Variant { label: format!("{label}(d={})", p.d), cfg, draco_r: None });
    }
    vs
}

pub fn run(p: &Fig6Params) -> Result<ExperimentOutput> {
    // the compressed-communication variant list as a sweep-engine job
    // batch, via run_figure_par's delegation (traces bit-identical to
    // the pre-engine driver)
    let traces = run_figure_par(
        p.n,
        p.q,
        p.sigma_h,
        &variants(p),
        p.seed,
        p.seed ^ 0x66,
        Parallelism::new(p.threads),
    )?;
    Ok(ExperimentOutput {
        name: "fig6_compressed_loss_vs_iters".into(),
        x_label: "iter".into(),
        y_label: "training loss".into(),
        series: traces.iter().map(Series::from_trace).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compressed_orderings_match_paper_shape() {
        let p = Fig6Params {
            n: 24,
            h: 17,
            q: 16,
            q_hat: 6,
            iters: 150,
            lr: 4e-6,
            d: 3,
            ..Default::default()
        };
        let out = run(&p).unwrap();
        let fin = |label: &str| -> f64 {
            *out.series
                .iter()
                .find(|s| s.label.starts_with(label))
                .unwrap()
                .y
                .last()
                .unwrap()
        };
        assert!(fin("com-va") > fin("com-lad-cwtm("), "va must be worst");
        assert!(fin("com-lad-cwtm(") < fin("com-cwtm"), "coding helps cwtm");
        assert!(
            fin("com-lad-cwtm-nnm") < fin("com-cwtm-nnm"),
            "coding helps cwtm-nnm"
        );
        assert!(
            fin("com-lad-cwtm-nnm") <= fin("com-lad-cwtm(") * 1.05,
            "nnm helps lad"
        );
    }
}
