//! Fig. 4 — training loss vs iterations under the sign-flip attack
//! (no compression). Paper setting: N=100, H=80, γ=1e-6, σ_H=0.3,
//! CWTM parameter 0.1, DRACO load 41.
//!
//! Methods: VA, CWTM, CWTM-NNM (all d=1, non-redundant), LAD-CWTM with
//! d ∈ {5, 10, 20}, LAD-CWTM-NNM (d=10), DRACO.

use super::common::{run_figure_par, ExperimentOutput, Series, Variant};
use crate::config::{AggregatorKind, AttackKind, CompressionKind, OracleKind, TrainConfig};
use crate::util::parallel::Parallelism;
use crate::Result;

#[derive(Debug, Clone)]
pub struct Fig4Params {
    pub n: usize,
    pub h: usize,
    pub q: usize,
    pub iters: usize,
    pub lr: f64,
    pub sigma_h: f64,
    pub lad_d: Vec<usize>,
    pub draco_r: usize,
    pub oracle: OracleKind,
    pub seed: u64,
    /// total thread budget for the figure (0 = all cores): the variant
    /// fan-out and each variant's inner stages share one budgeted pool
    pub threads: usize,
}

impl Default for Fig4Params {
    fn default() -> Self {
        Fig4Params {
            n: 100,
            h: 80,
            q: 100,
            // paper: γ=1e-6 over a long horizon; we rescale time
            // (γ=3e-5, T=3000) for the same dynamics in bounded wallclock
            // (see EXPERIMENTS.md §Fig4)
            iters: 3000,
            lr: 3e-5,
            sigma_h: 0.3,
            lad_d: vec![5, 10, 20],
            draco_r: 41,
            oracle: OracleKind::NativeLinreg,
            seed: 2026,
            threads: 0,
        }
    }
}

fn base_cfg(p: &Fig4Params) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_devices = p.n;
    cfg.n_honest = p.h;
    cfg.dim = p.q;
    cfg.iters = p.iters;
    cfg.lr = p.lr;
    cfg.sigma_h = p.sigma_h;
    cfg.trim_frac = 0.1;
    cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
    cfg.compression = CompressionKind::None;
    cfg.oracle = p.oracle;
    cfg.log_every = (p.iters / 30).max(1);
    cfg
}

pub fn variants(p: &Fig4Params) -> Vec<Variant> {
    let mut vs = Vec::new();
    // non-redundant baselines (d = 1)
    for (label, kind, nnm) in [
        ("va", AggregatorKind::Mean, false),
        ("cwtm", AggregatorKind::Cwtm, false),
        ("cwtm-nnm", AggregatorKind::Cwtm, true),
    ] {
        let mut cfg = base_cfg(p);
        cfg.d = 1;
        cfg.aggregator = kind;
        cfg.nnm = nnm;
        vs.push(Variant { label: label.into(), cfg, draco_r: None });
    }
    // LAD-CWTM at increasing d
    for &d in &p.lad_d {
        let mut cfg = base_cfg(p);
        cfg.d = d;
        cfg.aggregator = AggregatorKind::Cwtm;
        vs.push(Variant { label: format!("lad-cwtm(d={d})"), cfg, draco_r: None });
    }
    // LAD-CWTM-NNM (middle d)
    let d_mid = p.lad_d.get(p.lad_d.len() / 2).copied().unwrap_or(10);
    let mut cfg = base_cfg(p);
    cfg.d = d_mid;
    cfg.aggregator = AggregatorKind::Cwtm;
    cfg.nnm = true;
    vs.push(Variant { label: format!("lad-cwtm-nnm(d={d_mid})"), cfg, draco_r: None });
    // DRACO
    let mut cfg = base_cfg(p);
    cfg.d = 1; // unused by the DRACO path (load = scheme chunk size)
    vs.push(Variant { label: format!("draco(r={})", p.draco_r), cfg, draco_r: Some(p.draco_r) });
    vs
}

pub fn run(p: &Fig4Params) -> Result<ExperimentOutput> {
    // the variant list runs as a sweep-engine job batch: run_figure_par
    // wraps it via sweep::jobs_from_variants and delegates execution to
    // sweep::queue::execute (traces bit-identical to the pre-engine
    // driver), keeping the dataset-shape guard in one place
    let traces = run_figure_par(
        p.n,
        p.q,
        p.sigma_h,
        &variants(p),
        p.seed,
        p.seed ^ 0xABCD,
        Parallelism::new(p.threads),
    )?;
    Ok(ExperimentOutput {
        name: "fig4_loss_vs_iters".into(),
        x_label: "iter".into(),
        y_label: "training loss".into(),
        series: traces.iter().map(Series::from_trace).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Fig4Params {
        Fig4Params {
            n: 24,
            h: 19,
            q: 24,
            iters: 400,
            lr: 1e-3,
            lad_d: vec![4, 8],
            draco_r: 11,
            ..Default::default()
        }
    }

    #[test]
    fn orderings_match_paper_shape() {
        let out = run(&tiny()).unwrap();
        let fin = |label: &str| -> f64 {
            *out.series
                .iter()
                .find(|s| s.label.starts_with(label))
                .unwrap()
                .y
                .last()
                .unwrap()
        };
        // LAD beats the plain aggregation baselines (the paper's headline)
        assert!(
            fin("lad-cwtm(d=4)") < fin("cwtm"),
            "lad {} !< cwtm {}",
            fin("lad-cwtm(d=4)"),
            fin("cwtm")
        );
        assert!(fin("lad-cwtm(d=4)") < fin("va"));
        // larger d helps (weakly — stochastic runs)
        assert!(fin("lad-cwtm(d=8)") <= fin("lad-cwtm(d=4)") * 1.05);
        // NNM helps LAD (coding concentrates honest messages)
        assert!(fin("lad-cwtm-nnm") <= fin("lad-cwtm(d=8)") * 1.05);
        // DRACO is the best (exact recovery)
        let best_lad = fin("lad-cwtm(d=8)");
        assert!(fin("draco") <= best_lad * 1.1);
    }
}
