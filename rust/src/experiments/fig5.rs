//! Fig. 5 — training loss vs iterations under different heterogeneity
//! levels σ_H ∈ {0, 0.1}. Paper setting: 20 Byzantine devices, d=10,
//! γ=1e-6, CWTM 0.1. Methods: CWTM, CWTM-NNM, LAD-CWTM, LAD-CWTM-NNM.

use super::common::{run_figure_par, ExperimentOutput, Series, Variant};
use crate::config::{AggregatorKind, AttackKind, OracleKind, TrainConfig};
use crate::util::parallel::Parallelism;
use crate::Result;

#[derive(Debug, Clone)]
pub struct Fig5Params {
    pub n: usize,
    pub h: usize,
    pub q: usize,
    pub iters: usize,
    pub lr: f64,
    pub sigmas: Vec<f64>,
    pub d: usize,
    pub oracle: OracleKind,
    pub seed: u64,
    /// total thread budget for the figure (0 = all cores): the variant
    /// fan-out and each variant's inner stages share one budgeted pool
    pub threads: usize,
}

impl Default for Fig5Params {
    fn default() -> Self {
        Fig5Params {
            n: 100,
            h: 80,
            q: 100,
            // time-rescaled vs the paper's γ=1e-6 (see EXPERIMENTS.md)
            iters: 3000,
            lr: 3e-5,
            sigmas: vec![0.0, 0.1],
            d: 10,
            oracle: OracleKind::NativeLinreg,
            seed: 5,
            threads: 0,
        }
    }
}

fn variants(p: &Fig5Params) -> Vec<Variant> {
    let mut base = TrainConfig::default();
    base.n_devices = p.n;
    base.n_honest = p.h;
    base.dim = p.q;
    base.iters = p.iters;
    base.lr = p.lr;
    base.trim_frac = 0.1;
    base.attack = AttackKind::SignFlip { coeff: -2.0 };
    base.oracle = p.oracle;
    base.log_every = (p.iters / 30).max(1);
    let mut vs = Vec::new();
    for (label, d, nnm) in [
        ("cwtm", 1usize, false),
        ("cwtm-nnm", 1, true),
        ("lad-cwtm", p.d, false),
        ("lad-cwtm-nnm", p.d, true),
    ] {
        let mut cfg = base.clone();
        cfg.d = d;
        cfg.aggregator = AggregatorKind::Cwtm;
        cfg.nnm = nnm;
        vs.push(Variant { label: label.into(), cfg, draco_r: None });
    }
    vs
}

/// Returns one ExperimentOutput per σ_H (Fig. 5a, 5b, …).
pub fn run(p: &Fig5Params) -> Result<Vec<ExperimentOutput>> {
    let mut outs = Vec::new();
    for (idx, &sigma) in p.sigmas.iter().enumerate() {
        let mut vs = variants(p);
        for v in &mut vs {
            v.cfg.sigma_h = sigma;
        }
        eprintln!("fig5: σ_H = {sigma}");
        // one sweep-engine job batch per heterogeneity level (via
        // run_figure_par's delegation) — each level keeps its own dataset
        // seed, exactly as the pre-engine driver did
        let traces = run_figure_par(
            p.n,
            p.q,
            sigma,
            &vs,
            p.seed + idx as u64,
            p.seed ^ 0x55,
            Parallelism::new(p.threads),
        )?;
        outs.push(ExperimentOutput {
            name: format!("fig5{}_sigma_{}", (b'a' + idx as u8) as char, sigma),
            x_label: "iter".into(),
            y_label: "training loss".into(),
            series: traces.iter().map(Series::from_trace).collect(),
        });
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lad_gain_grows_with_heterogeneity() {
        let p = Fig5Params {
            n: 24,
            h: 19,
            q: 16,
            iters: 120,
            lr: 1e-5,
            sigmas: vec![0.0, 0.5],
            d: 8,
            ..Default::default()
        };
        let outs = run(&p).unwrap();
        let fin = |o: &ExperimentOutput, label: &str| -> f64 {
            *o.series.iter().find(|s| s.label == label).unwrap().y.last().unwrap()
        };
        for o in &outs {
            // LAD variant beats its non-redundant counterpart in both regimes
            assert!(
                fin(o, "lad-cwtm") <= fin(o, "cwtm") * 1.02,
                "{}: lad {} vs cwtm {}",
                o.name,
                fin(o, "lad-cwtm"),
                fin(o, "cwtm")
            );
        }
        // and the relative gain is at least as large under heterogeneity
        let gain0 = fin(&outs[0], "cwtm") / fin(&outs[0], "lad-cwtm").max(1e-12);
        let gain5 = fin(&outs[1], "cwtm") / fin(&outs[1], "lad-cwtm").max(1e-12);
        assert!(gain5 >= gain0 * 0.8, "gain σ=0.5 {gain5} vs σ=0 {gain0}");
    }
}
