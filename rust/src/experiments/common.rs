//! Shared experiment plumbing: labelled series, table printing, CSV export,
//! and the method-variant runner used by the Fig. 4/5/6 reproductions.

use crate::aggregation;
use crate::attack;
use crate::compress;
use crate::config::{OracleKind, TrainConfig};
use crate::data::linreg::LinRegDataset;
use crate::grad::{CodedGradOracle, NativeLinReg, RuntimeLinReg};
use crate::obs::Obs;
use crate::runtime::Runtime;
use crate::server::trainer::{DracoTrainer, Trainer};
use crate::server::TrainTrace;
use crate::util::csv::CsvWriter;
use crate::util::parallel::{Parallelism, Pool};
use crate::util::rng::Rng;
use crate::Result;
use std::path::Path;

/// One labelled curve (x → y).
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Self {
        Series { label: label.into(), x: Vec::new(), y: Vec::new() }
    }
    pub fn push(&mut self, x: f64, y: f64) {
        self.x.push(x);
        self.y.push(y);
    }
    pub fn from_trace(t: &TrainTrace) -> Self {
        Series {
            label: t.label.clone(),
            x: t.iters.iter().map(|&i| i as f64).collect(),
            y: t.loss.clone(),
        }
    }
}

/// A figure reproduction: several series + metadata.
#[derive(Debug, Clone)]
pub struct ExperimentOutput {
    pub name: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl ExperimentOutput {
    /// True when the series do not share one x grid — the resample-by-index
    /// fallback of [`ExperimentOutput::save_csv`] then misaligns rows.
    pub fn x_grids_disagree(&self) -> bool {
        match self.series.split_first() {
            None => false,
            Some((first, rest)) => rest.iter().any(|s| s.x != first.x),
        }
    }

    /// Save `x,<label1>,<label2>,...` rows (series must share x grids; any
    /// series with a different grid is resampled by index, with a warning —
    /// rows of such a CSV are not directly comparable across columns).
    pub fn save_csv<P: AsRef<Path>>(&self, dir: P) -> Result<std::path::PathBuf> {
        if self.x_grids_disagree() {
            eprintln!(
                "warning: {}: series x-grids disagree — resampling by index; \
                 rows mix different x values across columns",
                self.name
            );
        }
        let path = dir.as_ref().join(format!("{}.csv", self.name));
        let mut header: Vec<&str> = vec![self.x_label.as_str()];
        header.extend(self.series.iter().map(|s| s.label.as_str()));
        let mut w = CsvWriter::create(&path, &header)?;
        let rows = self.series.iter().map(|s| s.x.len()).max().unwrap_or(0);
        for r in 0..rows {
            let mut row = Vec::with_capacity(self.series.len() + 1);
            let x = self
                .series
                .iter()
                .find(|s| r < s.x.len())
                .map(|s| s.x[r.min(s.x.len() - 1)])
                .unwrap_or(r as f64);
            row.push(x);
            for s in &self.series {
                row.push(if r < s.y.len() { s.y[r] } else { f64::NAN });
            }
            w.row(&row)?;
        }
        w.flush()?;
        Ok(path)
    }

    /// Print the final value of each series (the "who wins" table).
    pub fn print_table(&self) {
        println!("\n── {} ── ({} vs {})", self.name, self.y_label, self.x_label);
        let mut rows: Vec<(&str, f64)> = self
            .series
            .iter()
            .map(|s| (s.label.as_str(), *s.y.last().unwrap_or(&f64::NAN)))
            .collect();
        rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        for (label, fin) in rows {
            println!("  {label:<28} final {fin:.6e}");
        }
    }
}

/// A method variant in a training-figure reproduction.
#[derive(Debug, Clone)]
pub struct Variant {
    pub label: String,
    /// d = 1 reproduces the non-redundant baselines
    pub cfg: TrainConfig,
    /// run DRACO decoding instead of robust aggregation (r = group size)
    pub draco_r: Option<usize>,
}

/// Run one variant against a shared dataset; every variant sees the same
/// data and the same seed so curves are comparable. Spins up a private
/// worker pool from `cfg.threads`; prefer [`run_variant_in`] when the
/// caller already owns a pool (the budgeted figure fan-outs), so variants
/// share workers instead of multiplying them.
pub fn run_variant(ds: &LinRegDataset, v: &Variant, seed: u64) -> Result<TrainTrace> {
    let pool =
        if v.draco_r.is_some() { Pool::serial() } else { Pool::new(v.cfg.threads) };
    run_variant_in(ds, v, seed, &pool)
}

/// [`run_variant`] with an explicit worker pool for the run's inner stages
/// (oracle, compression, aggregation). The pool only schedules — traces are
/// bit-identical for any pool width, so a borrowed budget slice
/// ([`Pool::borrow`]) gives the same curve a private pool would. The DRACO
/// path is decode-bound and ignores the pool.
pub fn run_variant_in(
    ds: &LinRegDataset,
    v: &Variant,
    seed: u64,
    pool: &Pool,
) -> Result<TrainTrace> {
    run_variant_obs(ds, v, seed, pool, &Obs::off())
}

/// [`run_variant_in`] with an observability sink attached to the
/// trainer, so the run's phase spans and per-rule kernel histograms
/// land in the caller's shared registry (the sweep engine's shape).
/// Telemetry only — the trace is bit-identical with obs on or off.
pub fn run_variant_obs(
    ds: &LinRegDataset,
    v: &Variant,
    seed: u64,
    pool: &Pool,
    obs: &Obs,
) -> Result<TrainTrace> {
    let mut oracle = make_oracle(ds, v.cfg.oracle)?;
    let mut x0 = vec![0.0f32; v.cfg.dim];
    let mut rng = Rng::new(seed);
    let attack = attack::from_kind(v.cfg.attack);
    if let Some(r) = v.draco_r {
        anyhow::ensure!(
            !v.cfg.compression.is_ef(),
            "variant {}: DRACO decoding has no error-feedback state — \
             ef-* compression applies to the LAD/Com-LAD trainers only",
            v.label
        );
        let trainer = DracoTrainer { cfg: &v.cfg, attack: attack.as_ref(), r };
        trainer.run(oracle.as_mut(), &mut x0, &v.label, &mut rng)
    } else {
        let agg = aggregation::from_config_pooled(&v.cfg, pool);
        let comp = compress::from_kind(v.cfg.compression);
        let trainer = Trainer::new(&v.cfg, agg.as_ref(), attack.as_ref(), comp.as_ref())
            .with_pool(pool)
            .with_obs(obs);
        trainer.run(oracle.as_mut(), &mut x0, &v.label, &mut rng)
    }
}

fn make_oracle(ds: &LinRegDataset, kind: OracleKind) -> Result<Box<dyn CodedGradOracle>> {
    Ok(match kind {
        OracleKind::NativeLinreg => Box::new(NativeLinReg::new(ds.clone())),
        OracleKind::RuntimeLinreg => {
            Box::new(RuntimeLinReg::new(Runtime::load_default()?, ds.clone())?)
        }
    })
}

/// Run a family of variants over one generated dataset; returns traces.
/// Variants run concurrently under one all-cores [`Pool::budgeted`] budget
/// (each variant owns its oracle, model and `Rng::new(run_seed)`, so
/// results are bit-identical to the serial sweep); use [`run_figure_par`]
/// to control the total thread budget.
pub fn run_figure(
    n: usize,
    q: usize,
    sigma_h: f64,
    variants: &[Variant],
    data_seed: u64,
    run_seed: u64,
) -> Result<Vec<TrainTrace>> {
    run_figure_par(n, q, sigma_h, variants, data_seed, run_seed, Parallelism::auto())
}

/// [`run_figure`] with an explicit **total** thread budget for the figure.
///
/// Since the sweep engine landed this is a thin wrapper: the variants are
/// wrapped as sweep jobs (`sweep::jobs_from_variants`) and executed by
/// `sweep::queue::execute` on one two-level [`Pool::budgeted`] budget —
/// the variant fan-out and every variant's inner stages (oracle,
/// compression, aggregation) share one worker pool, each variant
/// borrowing a capped slice. Traces are bit-identical to the pre-engine
/// driver: each job regenerates the figure dataset from the same
/// `Rng::new(data_seed)` and runs under the same `run_seed`, and thread
/// counts never alter a trace (pinned by `tests/fuzz_determinism.rs` and
/// `tests/parallel_determinism.rs`).
pub fn run_figure_par(
    n: usize,
    q: usize,
    sigma_h: f64,
    variants: &[Variant],
    data_seed: u64,
    run_seed: u64,
    par: Parallelism,
) -> Result<Vec<TrainTrace>> {
    for v in variants {
        anyhow::ensure!(
            v.cfg.n_devices == n && v.cfg.dim == q && v.cfg.sigma_h == sigma_h,
            "variant {} disagrees with the figure dataset shape (N={n}, Q={q}, σ_H={sigma_h})",
            v.label
        );
    }
    let jobs = crate::sweep::jobs_from_variants(variants, data_seed, run_seed);
    crate::sweep::queue::execute(&jobs, par)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_from_trace() {
        let mut t = TrainTrace::new("x");
        t.record(0, 3.0, 0.1, 10);
        t.record(5, 1.0, 0.05, 20);
        let s = Series::from_trace(&t);
        assert_eq!(s.x, vec![0.0, 5.0]);
        assert_eq!(s.y, vec![3.0, 1.0]);
    }

    #[test]
    fn mismatched_x_grids_are_detected_and_still_export() {
        let mut out = ExperimentOutput {
            name: "unit_mismatch".into(),
            x_label: "iter".into(),
            y_label: "loss".into(),
            series: vec![
                Series { label: "a".into(), x: vec![0.0, 1.0], y: vec![5.0, 4.0] },
                Series { label: "b".into(), x: vec![0.0, 2.0], y: vec![3.0, 2.0] },
            ],
        };
        assert!(out.x_grids_disagree(), "different grids must be flagged");
        // the export still succeeds (resample-by-index, with a warning)
        let dir = std::env::temp_dir().join("lad_exp_mismatch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = out.save_csv(&dir).unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap().lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
        // aligned grids are not flagged
        out.series[1].x = vec![0.0, 1.0];
        assert!(!out.x_grids_disagree());
        // degenerate shapes
        out.series.clear();
        assert!(!out.x_grids_disagree());
    }

    #[test]
    fn csv_export_shapes() {
        let out = ExperimentOutput {
            name: "unit_fig".into(),
            x_label: "iter".into(),
            y_label: "loss".into(),
            series: vec![
                Series { label: "a".into(), x: vec![0.0, 1.0], y: vec![5.0, 4.0] },
                Series { label: "b".into(), x: vec![0.0, 1.0], y: vec![3.0, 2.0] },
            ],
        };
        let dir = std::env::temp_dir().join("lad_exp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = out.save_csv(&dir).unwrap();
        let body = std::fs::read_to_string(p).unwrap();
        assert!(body.starts_with("iter,a,b\n"));
        assert_eq!(body.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
