//! Fig. 2 — the Com-LAD error term (eq. 33) as a function of the
//! compression constant δ. Paper setting: N=100, H=65, κ=1.5, β=1, d=5.

use super::common::{ExperimentOutput, Series};
use crate::theory::TheoryParams;

pub struct Fig2Params {
    pub n: usize,
    pub h: usize,
    pub d: usize,
    pub kappa: f64,
    pub beta: f64,
    pub delta_max: f64,
    pub points: usize,
}

impl Default for Fig2Params {
    fn default() -> Self {
        Fig2Params { n: 100, h: 65, d: 5, kappa: 1.5, beta: 1.0, delta_max: 2.0, points: 41 }
    }
}

pub fn run(p: &Fig2Params) -> ExperimentOutput {
    let mut s = Series::new(format!("eps_comlad(N={},H={},d={})", p.n, p.h, p.d));
    let mut s_exact = Series::new("eps_exact_eq32");
    for i in 0..p.points {
        let delta = p.delta_max * i as f64 / (p.points - 1) as f64;
        let tp = TheoryParams::new(p.n, p.h, p.d)
            .with_kappa(p.kappa)
            .with_beta(p.beta)
            .with_delta(delta);
        s.push(delta, tp.error_term_bigo());
        if tp.converges() && tp.gamma_max() > 0.0 {
            let tp2 = TheoryParams { gamma0: tp.gamma_max() * 0.5, ..tp };
            s_exact.push(delta, tp2.error_term_exact());
        }
    }
    ExperimentOutput {
        name: "fig2_error_vs_delta".into(),
        x_label: "delta".into(),
        y_label: "error term".into(),
        series: vec![s, s_exact],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_increasing_in_delta() {
        let out = run(&Fig2Params::default());
        let y = &out.series[0].y;
        for w in y.windows(2) {
            assert!(w[1] >= w[0], "error must grow with δ: {w:?}");
        }
    }

    #[test]
    fn delta_zero_matches_lad_constants() {
        let out = run(&Fig2Params::default());
        let tp = TheoryParams::new(100, 65, 5).with_kappa(1.5).with_beta(1.0);
        assert!((out.series[0].y[0] - tp.error_term_bigo()).abs() < 1e-9);
    }
}
