//! Extension experiment: final loss vs the number of Byzantine devices B,
//! empirical alongside the theory's ε_LAD ∝ √((N−d)N / (dH(N−H)))
//! (eq. 35 with H = N − B). Not a paper figure — an ablation of the
//! robustness margin that Theorem 2 predicts.

use super::common::{ExperimentOutput, Series, Variant};
use crate::config::{AggregatorKind, AttackKind, TrainConfig};
use crate::sweep;
use crate::theory::TheoryParams;
use crate::util::parallel::Parallelism;
use crate::Result;

#[derive(Debug, Clone)]
pub struct ByzSweepParams {
    pub n: usize,
    pub q: usize,
    pub d: usize,
    pub byz_counts: Vec<usize>,
    pub iters: usize,
    pub lr: f64,
    pub sigma_h: f64,
    pub seed: u64,
    /// total thread budget for the sweep (0 = all cores): the per-B
    /// fan-out and each run's inner stages share one budgeted pool
    pub threads: usize,
}

impl Default for ByzSweepParams {
    fn default() -> Self {
        ByzSweepParams {
            n: 60,
            q: 60,
            d: 8,
            byz_counts: vec![0, 4, 8, 12, 16, 20, 24],
            iters: 1200,
            lr: 4e-5,
            sigma_h: 0.3,
            seed: 33,
            threads: 0,
        }
    }
}

pub fn run(p: &ByzSweepParams) -> Result<ExperimentOutput> {
    // validate the whole grid before fanning out any training run
    for &b in &p.byz_counts {
        anyhow::ensure!(2 * (p.n - b) > p.n, "B={b} breaks honest majority");
    }
    // The per-B configs as a sweep-engine job batch (`f` axis): every job
    // regenerates the same dataset from `Rng::new(p.seed)` and runs with
    // `Rng::new(p.seed ^ 0xB)`, so the fan-out is bit-identical to the
    // pre-engine serial sweep, and the engine's two-level budget bounds
    // total threads at p.threads.
    let jobs: Vec<sweep::Job> = p
        .byz_counts
        .iter()
        .map(|&b| {
            let mut cfg = TrainConfig::default();
            cfg.n_devices = p.n;
            cfg.n_honest = p.n - b;
            cfg.d = p.d;
            cfg.dim = p.q;
            cfg.iters = p.iters;
            cfg.lr = p.lr;
            cfg.sigma_h = p.sigma_h;
            cfg.aggregator = AggregatorKind::Cwtm;
            cfg.trim_frac = ((b as f64 + 1.0) / p.n as f64).min(0.45);
            cfg.attack = AttackKind::SignFlip { coeff: -2.0 };
            cfg.log_every = 0;
            // scheduling-only: keep the pre-engine behaviour of giving each
            // run the full inner budget slice (threads never alter a trace)
            cfg.threads = 0;
            let mut job = sweep::Job::from_variant(
                &Variant { label: format!("b{b}"), cfg, draco_r: None },
                p.seed,
                p.seed ^ 0xB,
            );
            job.axes = vec![("f", b.to_string())];
            job
        })
        .collect();
    let traces = sweep::queue::execute(&jobs, Parallelism::new(p.threads))?;
    let mut empirical = Series::new(format!("final_loss(lad-cwtm,d={})", p.d));
    let mut theory = Series::new("eps_lad_eq35");
    for (&b, tr) in p.byz_counts.iter().zip(&traces) {
        empirical.push(b as f64, tr.final_loss);
        let tp = TheoryParams::new(p.n, p.n - b.max(1), p.d).with_kappa(1.5);
        theory.push(b as f64, tp.error_term_lad_bigo());
    }
    Ok(ExperimentOutput {
        name: "byz_sweep".into(),
        x_label: "byzantine devices".into(),
        y_label: "final loss / eps".into(),
        series: vec![empirical, theory],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_degrades_gracefully_with_byzantine_count() {
        let p = ByzSweepParams {
            n: 20,
            q: 20,
            d: 5,
            byz_counts: vec![0, 3, 6, 9],
            iters: 300,
            lr: 1e-4,
            ..Default::default()
        };
        let out = run(&p).unwrap();
        let emp = &out.series[0];
        // more Byzantine devices should never make things (much) better
        assert!(
            emp.y.last().unwrap() >= &(emp.y[0] * 0.8),
            "B=9 {} vs B=0 {}",
            emp.y.last().unwrap(),
            emp.y[0]
        );
        // the eq.-35 big-O curve hides (N−H)-dependent constants (κ grows
        // with B), so we only require it finite and positive here
        let th = &out.series[1];
        assert!(th.y.iter().all(|y| y.is_finite() && *y > 0.0));
        // honest-majority violation is rejected
        let bad = ByzSweepParams { byz_counts: vec![15], n: 20, ..p };
        assert!(run(&bad).is_err());
    }
}
