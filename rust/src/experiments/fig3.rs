//! Fig. 3 — the Com-LAD error term (eq. 33) as a function of the
//! computational load d. Paper setting: N=100, H=65, κ=1.5, β=1, δ=0.5.

use super::common::{ExperimentOutput, Series};
use crate::theory::TheoryParams;

pub struct Fig3Params {
    pub n: usize,
    pub h: usize,
    pub kappa: f64,
    pub beta: f64,
    pub delta: f64,
}

impl Default for Fig3Params {
    fn default() -> Self {
        Fig3Params { n: 100, h: 65, kappa: 1.5, beta: 1.0, delta: 0.5 }
    }
}

pub fn run(p: &Fig3Params) -> ExperimentOutput {
    let mut s = Series::new(format!("eps_comlad(N={},H={},delta={})", p.n, p.h, p.delta));
    let mut s_lad = Series::new("eps_lad_eq35");
    let mut s_base = Series::new("baseline_eq36");
    for d in 1..p.n {
        let tp = TheoryParams::new(p.n, p.h, d)
            .with_kappa(p.kappa)
            .with_beta(p.beta)
            .with_delta(p.delta);
        s.push(d as f64, tp.error_term_bigo());
        s_lad.push(d as f64, tp.error_term_lad_bigo());
        s_base.push(d as f64, tp.error_term_baseline());
    }
    ExperimentOutput {
        name: "fig3_error_vs_d".into(),
        x_label: "d".into(),
        y_label: "error term".into(),
        series: vec![s, s_lad, s_base],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_decreasing_in_d() {
        let out = run(&Fig3Params::default());
        let y = &out.series[0].y;
        for w in y.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "error must shrink with d: {w:?}");
        }
    }

    #[test]
    fn lad_crosses_baseline_at_d_3() {
        // paper example: LAD beats the O(β²κ) baseline from d ≥ 3
        let out = run(&Fig3Params::default());
        let lad = &out.series[1];
        let base = &out.series[2];
        assert!(lad.y[1] > base.y[1], "d=2 baseline should win"); // x starts at d=1
        assert!(lad.y[2] <= base.y[2], "d=3 LAD should win");
    }
}
