//! Experiment drivers — one per figure in the paper (see DESIGN.md's
//! experiment index). Each driver returns plottable [`common::Series`] and
//! can write CSVs under `results/`.

pub mod byz_sweep;
pub mod common;
pub mod e2e;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;

pub use common::{ExperimentOutput, Series};
