//! End-to-end driver: train a transformer LM with the full LAD stack —
//! cyclic gradient coding over device shards, Byzantine attack, optional
//! compression, κ-robust aggregation — with **all gradients computed by the
//! AOT transformer artifact via PJRT** (Python never runs here).
//!
//! This is the repo's proof that all three layers compose: L1/L2 artifacts
//! (`transformer_init/grad/loss`), the L3 coding + aggregation + training
//! loop, on a real (synthetic-corpus) LM workload.

use crate::aggregation::{self, Aggregator};
use crate::attack::{Attack, AttackContext};
use crate::coding::{Assignment, TaskMatrix};
use crate::compress::Compressor;
use crate::data::corpus::Corpus;
use crate::runtime::{Runtime, TensorIn};
use crate::server::metrics::TrainTrace;
use crate::util::math::norm;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use crate::Result;
use anyhow::Context as _;

/// End-to-end run parameters.
#[derive(Debug, Clone)]
pub struct E2eParams {
    /// devices N (= corpus shards)
    pub n_devices: usize,
    /// honest devices H
    pub n_honest: usize,
    /// coding load d (shards per device per step)
    pub d: usize,
    pub iters: usize,
    pub lr: f64,
    /// corpus shard length (tokens) and heterogeneity
    pub shard_len: usize,
    pub heterogeneity: f64,
    pub seed: u64,
    pub log_every: usize,
    /// sign-flip coefficient of the Byzantine devices
    pub flip_coeff: f32,
}

impl Default for E2eParams {
    fn default() -> Self {
        E2eParams {
            n_devices: 8,
            n_honest: 6,
            d: 2,
            iters: 60,
            lr: 0.5,
            shard_len: 4096,
            heterogeneity: 0.6,
            seed: 42,
            log_every: 5,
            flip_coeff: -2.0,
        }
    }
}

/// Transformer artifact metadata.
struct TfMeta {
    params: usize,
    vocab: usize,
    seq: usize,
    batch: usize,
}

fn tf_meta(rt: &Runtime) -> Result<TfMeta> {
    let meta = &rt
        .manifest()
        .entries
        .get("transformer_grad")
        .context("transformer_grad artifact missing — run `make artifacts`")?
        .meta;
    Ok(TfMeta {
        params: meta["params"] as usize,
        vocab: meta["vocab"] as usize,
        seq: meta["seq"] as usize,
        batch: meta["batch"] as usize,
    })
}

/// One honest device's coded gradient: mean of per-shard gradients over its
/// assigned shards (eq. 5 with the transformer oracle). Returns (grad, mean
/// device loss).
#[allow(clippy::too_many_arguments)]
fn device_coded_grad(
    rt: &mut Runtime,
    meta: &TfMeta,
    theta: &[f32],
    corpus: &Corpus,
    shards: &[usize],
    rng: &mut Rng,
) -> Result<(Vec<f32>, f64)> {
    let p = meta.params;
    let mut acc = vec![0.0f32; p];
    let mut loss_acc = 0.0f64;
    for &s in shards {
        let windows = corpus.sample_batch(s, meta.batch, meta.seq, rng);
        let outs = rt.exec_f32(
            "transformer_grad",
            &[
                TensorIn::F32(theta, &[p as i64]),
                TensorIn::I32(&windows, &[meta.batch as i64, meta.seq as i64 + 1]),
            ],
        )?;
        loss_acc += outs[0][0] as f64;
        crate::util::math::axpy(1.0, &outs[1], &mut acc);
    }
    crate::util::math::scale(&mut acc, 1.0 / shards.len() as f32);
    Ok((acc, loss_acc / shards.len() as f64))
}

/// Run the end-to-end LAD transformer training loop.
pub fn run(
    rt: &mut Runtime,
    p: &E2eParams,
    agg: &dyn Aggregator,
    attack: &dyn Attack,
    comp: &dyn Compressor,
) -> Result<TrainTrace> {
    anyhow::ensure!(p.n_honest * 2 > p.n_devices, "need honest majority");
    anyhow::ensure!(p.d >= 1 && p.d <= p.n_devices);
    let meta = tf_meta(rt)?;
    let timer = Timer::start();
    let mut rng = Rng::new(p.seed);
    let corpus = Corpus::generate(
        p.n_devices,
        p.shard_len,
        meta.vocab,
        p.heterogeneity,
        &mut rng,
    );

    // θ⁰ from the AOT init artifact (same init the Python tests exercise)
    let theta_out = rt.exec_f32("transformer_init", &[TensorIn::I32(&[p.seed as i32], &[])])?;
    let mut theta = theta_out.into_iter().next().unwrap();
    anyhow::ensure!(theta.len() == meta.params);

    let s_hat = TaskMatrix::cyclic(p.n_devices, p.d);
    let mut trace = TrainTrace::new(format!(
        "e2e-lad-{}(d={},byz={})",
        agg.name(),
        p.d,
        p.n_devices - p.n_honest
    ));
    let mut bits_total = 0u64;

    for t in 0..p.iters {
        let assign = Assignment::draw(p.n_devices, &mut rng);
        // every device's true coded gradient (honest compute path)
        let mut msgs_true: Vec<Vec<f32>> = Vec::with_capacity(p.n_devices);
        let mut honest_loss = 0.0f64;
        for i in 0..p.n_devices {
            let shards: Vec<usize> =
                assign.subsets_for(s_hat.row(assign.tasks[i])).collect();
            let (g, l) =
                device_coded_grad(rt, &meta, &theta, &corpus, &shards, &mut rng)?;
            if i < p.n_honest {
                honest_loss += l;
            }
            msgs_true.push(g);
        }
        honest_loss /= p.n_honest as f64;

        let honest: Vec<&[f32]> =
            msgs_true[..p.n_honest].iter().map(|m| m.as_slice()).collect();
        let byz_true: Vec<&[f32]> =
            msgs_true[p.n_honest..].iter().map(|m| m.as_slice()).collect();
        let lies = if byz_true.is_empty() {
            Vec::new()
        } else {
            let mut ctx =
                AttackContext { honest: &honest, own_true: &byz_true, rng: &mut rng };
            attack.craft(&mut ctx)
        };
        let mut msgs: Vec<Vec<f32>> = Vec::with_capacity(p.n_devices);
        for m in honest.iter().copied().chain(lies.iter().map(|l| l.as_slice())) {
            let c = comp.compress(m, &mut rng);
            bits_total += c.bits as u64;
            msgs.push(c.vec);
        }
        let update = agg.aggregate(&msgs);
        for (th, u) in theta.iter_mut().zip(&update) {
            *th -= p.lr as f32 * u;
        }
        if p.log_every > 0 && (t % p.log_every == 0 || t + 1 == p.iters) {
            trace.record(t, honest_loss, norm(&update), bits_total);
            eprintln!(
                "  e2e iter {t:>4}: loss {honest_loss:.4}  |update| {:.3e}",
                norm(&update)
            );
        }
    }
    trace.final_loss = *trace.loss.last().unwrap_or(&f64::NAN);
    trace.wall_s = timer.elapsed_s();
    // persist the trained model (resume/eval from Rust, no Python needed)
    let ck = crate::server::Checkpoint::new(p.iters as u64, p.seed, theta);
    ck.save("results/e2e_transformer.ckpt")?;
    Ok(trace)
}

/// Convenience: build the default LAD-CWTM-NNM stack and run.
pub fn run_default(rt: &mut Runtime, p: &E2eParams) -> Result<TrainTrace> {
    let mut cfg = crate::config::TrainConfig::default();
    cfg.n_devices = p.n_devices;
    cfg.n_honest = p.n_honest;
    cfg.aggregator = crate::config::AggregatorKind::Cwtm;
    cfg.trim_frac = 0.15;
    cfg.nnm = true;
    let agg = aggregation::from_config(&cfg);
    let attack = crate::attack::SignFlip { coeff: p.flip_coeff };
    let comp = crate::compress::Identity;
    run(rt, p, agg.as_ref(), &attack, &comp)
}
