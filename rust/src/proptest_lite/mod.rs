//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `forall(cases, seed, gen, check)` draws `cases` random inputs from `gen`
//! and asserts `check`; on failure it panics with the failing case index and
//! the *per-case seed* so the exact input can be replayed with
//! [`replay`]. Shrinking is intentionally out of scope — inputs are kept
//! small and structured instead.

use crate::util::rng::Rng;

/// Run `check` on `cases` generated inputs. Panics with a replayable seed on
/// the first failure.
pub fn forall<T, G, C>(cases: usize, seed: u64, mut gen: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {case}/{cases} (replay seed: {case_seed:#x})\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Re-run a single case from a seed reported by [`forall`].
pub fn replay<T, G, C>(case_seed: u64, mut gen: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut rng = Rng::new(case_seed);
    let input = gen(&mut rng);
    if let Err(msg) = check(&input) {
        panic!("replay {case_seed:#x} failed: {msg}\n  input: {input:?}");
    }
}

/// Assert helper: `ensure(cond, || format!(...))?` style for checks.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

/// Common generators.
pub mod gen {
    use crate::util::rng::Rng;

    /// Vector of iid U(-scale, scale) f32s.
    pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
    }

    /// Family of `n` vectors of dim `q`, iid normal with the given std.
    pub fn vec_family(rng: &mut Rng, n: usize, q: usize, std: f64) -> Vec<Vec<f32>> {
        (0..n).map(|_| (0..q).map(|_| rng.normal(0.0, std) as f32).collect()).collect()
    }

    /// usize in [lo, hi].
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            64,
            1,
            |rng| gen::vec_f32(rng, 10, 5.0),
            |v| ensure(v.len() == 10, || "len".into()),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(
            64,
            2,
            |rng| gen::usize_in(rng, 0, 100),
            |&x| ensure(x < 50, || format!("x={x} too big")),
        );
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..100 {
            let x = gen::usize_in(&mut rng, 5, 9);
            assert!((5..=9).contains(&x));
        }
        let v = gen::vec_f32(&mut rng, 50, 2.0);
        assert!(v.iter().all(|x| x.abs() <= 2.0));
    }
}
