//! Norm-thresholding aggregation — "Com-TGN" baseline (Ghosh et al.,
//! JSAIT'21 [19]): discard the ⌈βN⌉ messages with the largest Euclidean
//! norms, average the rest. Designed for the compressed domain, where
//! attacks typically inflate norms.

use super::{check_family, Aggregator};
use crate::util::math::{mean_of, norm_sq};

#[derive(Debug, Clone, Copy)]
pub struct Tgn {
    beta: f64,
}

impl Tgn {
    /// β — fraction of largest-norm messages to drop (paper: 0.2).
    pub fn new(beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta));
        Tgn { beta }
    }
}

impl Aggregator for Tgn {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        check_family(msgs);
        let n = msgs.len();
        let drop = ((self.beta * n as f64).ceil() as usize).min(n - 1);
        let mut idx: Vec<usize> = (0..n).collect();
        let norms: Vec<f64> = msgs.iter().map(|m| norm_sq(m)).collect();
        idx.sort_by(|&a, &b| norms[a].partial_cmp(&norms[b]).unwrap());
        let keep: Vec<&[f32]> =
            idx[..n - drop].iter().map(|&i| msgs[i].as_slice()).collect();
        mean_of(&keep)
    }

    fn name(&self) -> String {
        format!("tgn({})", self.beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drops_largest_norms() {
        let mut msgs = vec![vec![1.0f32, 0.0]; 8];
        msgs.push(vec![-200.0, 5.0]);
        msgs.push(vec![150.0, -9.0]);
        let out = Tgn::new(0.2).aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 1e-5);
        assert!(out[1].abs() < 1e-5);
    }

    #[test]
    fn beta_zero_is_mean() {
        let msgs = vec![vec![2.0f32], vec![4.0]];
        assert_eq!(Tgn::new(0.0).aggregate(&msgs), vec![3.0]);
    }

    #[test]
    fn defeated_by_small_norm_attack() {
        // documents the known weakness: zero-vector attacks pass the filter
        let mut msgs = vec![vec![10.0f32]; 6];
        msgs.push(vec![0.0]);
        msgs.push(vec![0.0]);
        let out = Tgn::new(0.25).aggregate(&msgs);
        assert!(out[0] < 10.0); // biased toward zero — expected
    }

    #[test]
    fn keeps_at_least_one() {
        let out = Tgn::new(0.99).aggregate(&[vec![1.0], vec![5.0]]);
        assert_eq!(out, vec![1.0]);
    }
}
