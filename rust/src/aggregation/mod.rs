//! κ-robust aggregation rules (Definition 1) and pre-aggregation.
//!
//! All rules implement [`Aggregator`]: a pure function from the N received
//! messages (honest + Byzantine, unlabeled) to one vector. Rules that need
//! an assumed Byzantine count take `f = N − H` at construction.
//!
//! # The κ-robustness constant
//!
//! Definition 1 calls `agg` **(f, κ)-robust** when, for every family of H
//! honest messages `z₁..z_H` (mean `z̄`) and any f Byzantine messages,
//!
//! ```text
//! ‖agg(z₁..z_H, z̃₁..z̃_f) − z̄‖² ≤ κ · (1/H) Σᵢ ‖zᵢ − z̄‖²
//! ```
//!
//! i.e. the aggregate's deviation from the honest mean is bounded by κ times
//! the honest empirical variance, **uniformly over adversarial inputs**.
//! Plain averaging has no finite κ (one spike moves the mean arbitrarily);
//! every robust rule below admits a finite κ for f < N/2, and κ enters the
//! convergence bounds (Theorems 1–2) multiplicatively — smaller κ means a
//! smaller error floor. [`kappa::estimate_kappa`] lower-bounds κ
//! empirically; cyclic gradient coding (LAD) shrinks the *variance* term κ
//! multiplies, which is how coding and robustness compose.
//!
//! # Rule zoo: cost and robustness at a glance
//!
//! For N messages of dimension Q, with f the assumed Byzantine count:
//!
//! | Rule                         | Per-call cost          | Notes |
//! |------------------------------|------------------------|-------|
//! | [`Mean`] (VA)                | O(NQ)                  | κ unbounded — baseline only |
//! | [`Cwtm`] (trimmed mean [7])  | O(NQ) expected         | per-coord double `select_nth` |
//! | [`CoordinateMedian`] [4]     | O(NQ) expected         | linear-time selection per coord |
//! | [`GeometricMedian`] [6,8]    | O(T·NQ), T Weiszfeld   | `CenterScratch`; breakdown 1/2 |
//! | [`Krum`] / [`MultiKrum`] [3] | O(N²Q/2) + O(N²)       | one shared tiled Gram pass |
//! | [`Mcc`] (correntropy [9])    | O(T·NQ), T reweights   | `CenterScratch`; adaptive kernel |
//! | [`Faba`] [5]                 | O(f·NQ)                | f farthest-from-mean removals |
//! | [`Tgn`] (norm filter [19])   | O(NQ + N log N)        | drops ⌈βN⌉ largest norms |
//! | [`MomentumFilter`] (CMF)     | O(NQ) expected         | momentum, median-dist filter |
//! | [`Nnm`] pre-aggregation [23] | O(N²Q/2) + inner rule  | Gram pass + parallel mixing; reuses its Gram for inner (Multi-)Krum via W·G·Wᵀ |
//!
//! # The gram/pool subsystem
//!
//! The distance-consuming rules are built on two shared kernels in
//! [`gram`]: [`gram::PairwiseDistances`] computes the triangular distance
//! matrix exactly once per aggregate call via `‖i‖²+‖j‖²−2⟨i,j⟩` (tiled
//! into disjoint per-task scratch for the parallel pass) into
//! **packed-triangular storage** — n(n−1)/2 f64, half the full-matrix
//! footprint — consumed per logical row through the `RowView` adapter; and
//! [`gram::CenterScratch`] reuses one pool-parallel distance buffer across
//! the reweight iterations of MCC / geometric median and the κ estimator
//! (stable subtract-first distances, not the Gram form). The dots and
//! distances themselves run on the widest kernel tier the
//! [`crate::util::math`] dispatcher detected (scalar / SSE2 / AVX2+FMA,
//! bit-identical by the lane contract). Underneath, every rule that
//! parallelizes holds a [`Pool`] handle — a persistent worker pool shared
//! with the trainer's gradient oracle and compression stages via
//! [`from_config_pooled`] (the [`TrainConfig::threads`] wiring), and with
//! the figure fan-outs via the two-level `Pool::budgeted` API;
//! `with_parallelism` keeps the scoped-spawn engine available behind the
//! same API. Serial, scoped and pooled passes are bit-identical — pinned by
//! `tests/fuzz_determinism.rs`.
//!
//! # Example
//!
//! ```
//! use lad::aggregation::{Aggregator, Cwtm, Krum};
//!
//! // 9 honest messages near (1, 2) and one adversarial spike
//! let mut msgs = vec![vec![1.0f32, 2.0]; 9];
//! msgs.push(vec![1e6, -1e6]);
//!
//! let trimmed = Cwtm::new(0.2).aggregate(&msgs);
//! assert!((trimmed[0] - 1.0).abs() < 1e-5 && (trimmed[1] - 2.0).abs() < 1e-5);
//!
//! // Krum returns one of the honest inputs
//! let picked = Krum::new(1).aggregate(&msgs);
//! assert_eq!(picked, vec![1.0, 2.0]);
//! ```

pub mod cwtm;
pub mod faba;
pub mod geometric_median;
pub mod gram;
pub mod kappa;
pub mod krum;
pub mod mcc;
pub mod mean;
pub mod median;
pub mod momentum_filter;
pub mod nnm;
pub mod tgn;

use crate::config::{AggregatorKind, TrainConfig};
use crate::obs::Obs;
use crate::util::parallel::Pool;

/// A robust aggregation rule agg(·) (Definition 1).
pub trait Aggregator: Send + Sync {
    /// Aggregate the received messages (each of equal dim Q) into one vector.
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32>;
    /// Human-readable name for logs and tables.
    fn name(&self) -> String;
    /// [`Aggregator::aggregate`] with a precomputed pairwise-distance matrix
    /// over `msgs` (e.g. [`Nnm`]'s mixed-Gram reuse, which derives the mixed
    /// family's distances from the matrix it already paid for). The default
    /// ignores the matrix; rules whose cost is dominated by the O(N²Q)
    /// distance pass override it and advertise via
    /// [`Aggregator::wants_distances`].
    fn aggregate_with_distances(
        &self,
        msgs: &[Vec<f32>],
        _pd: &gram::PairwiseDistances,
    ) -> Vec<f32> {
        self.aggregate(msgs)
    }
    /// True when [`Aggregator::aggregate_with_distances`] actually consumes
    /// the matrix — lets wrappers skip building one otherwise.
    fn wants_distances(&self) -> bool {
        false
    }
    /// The rule's cross-iteration state, if it carries any — one buffer
    /// per device, ready for a checkpoint's momentum section. Stateless
    /// rules (everything except [`MomentumFilter`]) return `None`.
    fn state_snapshot(&self) -> Option<Vec<Vec<f32>>> {
        None
    }
    /// Restore cross-iteration state captured by
    /// [`Aggregator::state_snapshot`]. A no-op for stateless rules; a
    /// stateful rule resumes bit-identically from the snapshot.
    fn state_restore(&self, _bufs: Vec<Vec<f32>>) {}
    /// Attach an observability context so the rule's internal kernels
    /// (Gram fill, Krum scoring, NNM mixing, Weiszfeld iterations) can
    /// span + histogram themselves. Wall-clock telemetry only — the
    /// aggregate bits are identical with it attached or not. Takes
    /// `&self` because rules are shared as `&dyn Aggregator`, so
    /// implementors store the handle behind interior mutability;
    /// wrappers ([`Nnm`]) forward to their inner rule. The default is a
    /// no-op for rules without internal kernels worth timing.
    fn set_obs(&self, _obs: &Obs) {}
}

pub use cwtm::Cwtm;
pub use faba::Faba;
pub use geometric_median::GeometricMedian;
pub use krum::{Krum, MultiKrum};
pub use mcc::Mcc;
pub use mean::Mean;
pub use median::CoordinateMedian;
pub use momentum_filter::MomentumFilter;
pub use nnm::Nnm;
pub use tgn::Tgn;

/// Build the aggregator described by a config (including NNM wrapping),
/// spinning up a private [`Pool`] from `cfg.threads`. Prefer
/// [`from_config_pooled`] when the run already owns a pool (the trainer
/// path), so aggregation shares workers with the oracle and compression.
pub fn from_config(cfg: &TrainConfig) -> Box<dyn Aggregator> {
    from_config_pooled(cfg, &Pool::new(cfg.threads))
}

/// [`from_config`] with an explicit shared worker pool. Every rule with a
/// parallel pass (Krum, Multi-Krum, NNM, MCC, geometric median) clones the
/// handle; the workers live until the last clone drops.
pub fn from_config_pooled(cfg: &TrainConfig, pool: &Pool) -> Box<dyn Aggregator> {
    let f = cfg.n_byz();
    let base: Box<dyn Aggregator> = match cfg.aggregator {
        AggregatorKind::Mean => Box::new(Mean),
        AggregatorKind::Cwtm => Box::new(Cwtm::new(cfg.trim_frac)),
        AggregatorKind::Median => Box::new(CoordinateMedian),
        AggregatorKind::GeometricMedian => {
            Box::new(GeometricMedian::default().with_pool(pool))
        }
        AggregatorKind::Krum => Box::new(Krum::new(f).with_pool(pool)),
        AggregatorKind::MultiKrum => Box::new(MultiKrum::new(f).with_pool(pool)),
        AggregatorKind::Mcc => Box::new(Mcc::default().with_pool(pool)),
        AggregatorKind::Faba => Box::new(Faba::new(f)),
        AggregatorKind::Tgn => Box::new(Tgn::new(cfg.trim_frac)),
        AggregatorKind::MomentumFilter => {
            Box::new(MomentumFilter::new(f, momentum_filter::DEFAULT_ALPHA))
        }
    };
    if cfg.nnm {
        Box::new(Nnm::new(f, base).with_pool(pool))
    } else {
        base
    }
}

/// Validate message family shape; panics on ragged or empty input.
pub(crate) fn check_family(msgs: &[Vec<f32>]) -> usize {
    assert!(!msgs.is_empty(), "aggregate() on empty message set");
    let q = msgs[0].len();
    assert!(msgs.iter().all(|m| m.len() == q), "ragged message family");
    q
}

/// Size gate for the parallel O(N²Q) passes (tiled Gram fill, NNM row
/// mixing): below roughly 2¹⁶ units of distance work the dispatch overhead
/// dominates. Purely a performance heuristic — the serial and parallel
/// passes are bit-identical either way.
pub(crate) fn par_gate(n: usize, q: usize) -> bool {
    n.saturating_mul(n).saturating_mul(q.max(1)) >= 1 << 16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_builds_every_kind() {
        for kind in [
            AggregatorKind::Mean,
            AggregatorKind::Cwtm,
            AggregatorKind::Median,
            AggregatorKind::GeometricMedian,
            AggregatorKind::Krum,
            AggregatorKind::MultiKrum,
            AggregatorKind::Mcc,
            AggregatorKind::Faba,
            AggregatorKind::Tgn,
            AggregatorKind::MomentumFilter,
        ] {
            let mut cfg = TrainConfig::default();
            cfg.aggregator = kind;
            let agg = from_config(&cfg);
            let out = agg.aggregate(&vec![vec![1.0, 2.0]; 10]);
            assert_eq!(out.len(), 2);
        }
    }

    #[test]
    fn from_config_pooled_shares_one_pool_and_matches_serial() {
        let pool = Pool::new(4);
        for kind in [AggregatorKind::Krum, AggregatorKind::MultiKrum, AggregatorKind::Mcc] {
            let mut cfg = TrainConfig::default();
            cfg.aggregator = kind;
            cfg.nnm = true;
            let msgs: Vec<Vec<f32>> =
                (0..40).map(|i| (0..64).map(|j| ((i * 64 + j) % 13) as f32).collect()).collect();
            let serial = from_config(&cfg).aggregate(&msgs);
            let pooled = from_config_pooled(&cfg, &pool).aggregate(&msgs);
            assert_eq!(serial, pooled, "{kind:?}");
        }
    }

    #[test]
    fn nnm_wrapping_in_name() {
        let mut cfg = TrainConfig::default();
        cfg.nnm = true;
        let agg = from_config(&cfg);
        assert!(agg.name().contains("nnm"), "{}", agg.name());
    }

    #[test]
    #[should_panic]
    fn ragged_family_panics() {
        let _ = Mean.aggregate(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
