//! κ-robust aggregation rules (Definition 1) and pre-aggregation.
//!
//! All rules implement [`Aggregator`]: a pure function from the N received
//! messages (honest + Byzantine, unlabeled) to one vector. Rules that need
//! an assumed Byzantine count take `f = N − H` at construction.
//!
//! The zoo covers every baseline the paper references: averaging (VA),
//! coordinate-wise trimmed mean (CWTM [7]), coordinate-wise median [4],
//! geometric median [6,8], (Multi-)Krum [3], FABA [5], maximum-correntropy
//! (MCC [9]), norm-thresholding (TGN [19]) and NNM pre-aggregation [23].

pub mod cwtm;
pub mod faba;
pub mod geometric_median;
pub mod kappa;
pub mod krum;
pub mod mcc;
pub mod mean;
pub mod median;
pub mod nnm;
pub mod tgn;

use crate::config::{AggregatorKind, TrainConfig};

/// A robust aggregation rule agg(·) (Definition 1).
pub trait Aggregator: Send + Sync {
    /// Aggregate the received messages (each of equal dim Q) into one vector.
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32>;
    /// Human-readable name for logs and tables.
    fn name(&self) -> String;
}

pub use cwtm::Cwtm;
pub use faba::Faba;
pub use geometric_median::GeometricMedian;
pub use krum::{Krum, MultiKrum};
pub use mcc::Mcc;
pub use mean::Mean;
pub use median::CoordinateMedian;
pub use nnm::Nnm;
pub use tgn::Tgn;

/// Build the aggregator described by a config (including NNM wrapping).
pub fn from_config(cfg: &TrainConfig) -> Box<dyn Aggregator> {
    let f = cfg.n_byz();
    let base: Box<dyn Aggregator> = match cfg.aggregator {
        AggregatorKind::Mean => Box::new(Mean),
        AggregatorKind::Cwtm => Box::new(Cwtm::new(cfg.trim_frac)),
        AggregatorKind::Median => Box::new(CoordinateMedian),
        AggregatorKind::GeometricMedian => Box::new(GeometricMedian::default()),
        AggregatorKind::Krum => Box::new(Krum::new(f)),
        AggregatorKind::MultiKrum => Box::new(MultiKrum::new(f)),
        AggregatorKind::Mcc => Box::new(Mcc::default()),
        AggregatorKind::Faba => Box::new(Faba::new(f)),
        AggregatorKind::Tgn => Box::new(Tgn::new(cfg.trim_frac)),
    };
    if cfg.nnm {
        Box::new(Nnm::new(f, base))
    } else {
        base
    }
}

/// Validate message family shape; panics on ragged or empty input.
pub(crate) fn check_family(msgs: &[Vec<f32>]) -> usize {
    assert!(!msgs.is_empty(), "aggregate() on empty message set");
    let q = msgs[0].len();
    assert!(msgs.iter().all(|m| m.len() == q), "ragged message family");
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_builds_every_kind() {
        for kind in [
            AggregatorKind::Mean,
            AggregatorKind::Cwtm,
            AggregatorKind::Median,
            AggregatorKind::GeometricMedian,
            AggregatorKind::Krum,
            AggregatorKind::MultiKrum,
            AggregatorKind::Mcc,
            AggregatorKind::Faba,
            AggregatorKind::Tgn,
        ] {
            let mut cfg = TrainConfig::default();
            cfg.aggregator = kind;
            let agg = from_config(&cfg);
            let out = agg.aggregate(&vec![vec![1.0, 2.0]; 10]);
            assert_eq!(out.len(), 2);
        }
    }

    #[test]
    fn nnm_wrapping_in_name() {
        let mut cfg = TrainConfig::default();
        cfg.nnm = true;
        let agg = from_config(&cfg);
        assert!(agg.name().contains("nnm"), "{}", agg.name());
    }

    #[test]
    #[should_panic]
    fn ragged_family_panics() {
        let _ = Mean.aggregate(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
