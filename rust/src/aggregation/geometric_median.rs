//! Geometric median via Weiszfeld iteration (Chen et al. [6], Pillutla et
//! al. [8]). Minimizes Σᵢ‖y − xᵢ‖; breakdown point 1/2.
//!
//! Each Weiszfeld iteration needs every ‖xᵢ − y‖; the shared
//! [`CenterScratch`] kernel reuses one distance buffer across iterations
//! (stable subtract-first distances on the runtime-dispatched `dist_sq`
//! tier — essential here, where y converges onto a message and a Gram
//! expansion would cancel to zero and blow up the 1/dist weight), and the
//! f32 image of y is materialized once per iteration (the old loop
//! re-allocated it once per *message*).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::gram::CenterScratch;
use super::{check_family, Aggregator};
use crate::obs::Obs;
use crate::util::parallel::Pool;

/// Smoothed Weiszfeld with fixed iteration budget and tolerance.
#[derive(Debug, Clone)]
pub struct GeometricMedian {
    pub max_iters: usize,
    pub tol: f64,
    pub eps: f64,
    pool: Pool,
    obs: Arc<Mutex<Obs>>,
}

impl Default for GeometricMedian {
    fn default() -> Self {
        GeometricMedian {
            max_iters: 100,
            tol: 1e-10,
            eps: 1e-12,
            pool: Pool::serial(),
            obs: Arc::default(),
        }
    }
}

impl GeometricMedian {
    /// Share a worker pool for the per-iteration distance pass.
    pub fn with_pool(mut self, pool: &Pool) -> Self {
        self.pool = pool.clone();
        self
    }

    fn obs_handle(&self) -> Obs {
        self.obs.lock().map(|o| o.clone()).unwrap_or_default()
    }
}

impl Aggregator for GeometricMedian {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let n = msgs.len();
        let obs = self.obs_handle();
        let sp = obs.span("kernel/weiszfeld");
        let mut scratch = CenterScratch::new();
        // init at coordinate mean
        let mut y = vec![0.0f64; q];
        for m in msgs {
            for j in 0..q {
                y[j] += m[j] as f64;
            }
        }
        y.iter_mut().for_each(|v| *v /= n as f64);

        let mut yd = vec![0.0f32; q];
        let mut next = vec![0.0f64; q];
        for _ in 0..self.max_iters {
            // per-iteration histogram sample, gated so the obs-off hot
            // path pays only the branch (the whole-loop span above is
            // the always-measure cover)
            let t_it = obs.enabled().then(Instant::now);
            for (f32v, &f64v) in yd.iter_mut().zip(&y) {
                *f32v = f64v as f32;
            }
            let d2 = scratch.dist_sq_to(msgs, &yd, &self.pool);
            let mut wsum = 0.0f64;
            next.iter_mut().for_each(|v| *v = 0.0);
            for (m, &d2i) in msgs.iter().zip(d2) {
                let dist = d2i.sqrt().max(self.eps);
                let w = 1.0 / dist;
                wsum += w;
                for j in 0..q {
                    next[j] += w * m[j] as f64;
                }
            }
            next.iter_mut().for_each(|v| *v /= wsum);
            let shift: f64 =
                y.iter().zip(&next).map(|(a, b)| (a - b) * (a - b)).sum();
            std::mem::swap(&mut y, &mut next);
            if let Some(t0) = t_it {
                obs.observe_ns("kernel/weiszfeld_iter", t0.elapsed().as_nanos() as u64);
            }
            if shift < self.tol * self.tol {
                break;
            }
        }
        sp.done();
        y.into_iter().map(|v| v as f32).collect()
    }

    fn name(&self) -> String {
        "geomed".into()
    }

    fn set_obs(&self, obs: &Obs) {
        if let Ok(mut g) = self.obs.lock() {
            *g = obs.clone();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn of_identical_points_is_the_point() {
        let out = GeometricMedian::default().aggregate(&vec![vec![3.0, -1.0]; 5]);
        assert!((out[0] - 3.0).abs() < 1e-4 && (out[1] + 1.0).abs() < 1e-4);
    }

    #[test]
    fn collinear_median() {
        // geometric median of {0, 1, 10} on a line is the middle point 1
        let msgs = vec![vec![0.0], vec![1.0], vec![10.0]];
        let out = GeometricMedian::default().aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 1e-2, "{}", out[0]);
    }

    #[test]
    fn robust_to_minority_outlier() {
        let mut msgs = vec![vec![1.0f32, 1.0]; 6];
        msgs.push(vec![1e5, -1e5]);
        let out = GeometricMedian::default().aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 0.1 && (out[1] - 1.0).abs() < 0.1);
    }

    #[test]
    fn two_points_lands_between() {
        let msgs = vec![vec![0.0], vec![2.0]];
        let out = GeometricMedian::default().aggregate(&msgs);
        assert!(out[0] >= 0.0 && out[0] <= 2.0);
    }

    #[test]
    fn pooled_aggregate_is_bit_identical_to_serial() {
        let mut rng = crate::util::rng::Rng::new(3);
        let msgs: Vec<Vec<f32>> = (0..40).map(|_| rng.gauss_vec(128)).collect();
        let serial = GeometricMedian::default().aggregate(&msgs);
        let pool = Pool::new(8);
        let pooled = GeometricMedian::default().with_pool(&pool).aggregate(&msgs);
        assert_eq!(serial, pooled);
    }
}
