//! Geometric median via Weiszfeld iteration (Chen et al. [6], Pillutla et
//! al. [8]). Minimizes Σᵢ‖y − xᵢ‖; breakdown point 1/2.

use super::{check_family, Aggregator};
use crate::util::math::dist_sq;

/// Smoothed Weiszfeld with fixed iteration budget and tolerance.
#[derive(Debug, Clone, Copy)]
pub struct GeometricMedian {
    pub max_iters: usize,
    pub tol: f64,
    pub eps: f64,
}

impl Default for GeometricMedian {
    fn default() -> Self {
        GeometricMedian { max_iters: 100, tol: 1e-10, eps: 1e-12 }
    }
}

impl Aggregator for GeometricMedian {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let n = msgs.len();
        // init at coordinate mean
        let mut y = vec![0.0f64; q];
        for m in msgs {
            for j in 0..q {
                y[j] += m[j] as f64;
            }
        }
        y.iter_mut().for_each(|v| *v /= n as f64);

        let mut next = vec![0.0f64; q];
        for _ in 0..self.max_iters {
            let mut wsum = 0.0f64;
            next.iter_mut().for_each(|v| *v = 0.0);
            for m in msgs {
                let yd: Vec<f32> = y.iter().map(|&v| v as f32).collect();
                let dist = dist_sq(m, &yd).sqrt().max(self.eps);
                let w = 1.0 / dist;
                wsum += w;
                for j in 0..q {
                    next[j] += w * m[j] as f64;
                }
            }
            next.iter_mut().for_each(|v| *v /= wsum);
            let shift: f64 =
                y.iter().zip(&next).map(|(a, b)| (a - b) * (a - b)).sum();
            std::mem::swap(&mut y, &mut next);
            if shift < self.tol * self.tol {
                break;
            }
        }
        y.into_iter().map(|v| v as f32).collect()
    }

    fn name(&self) -> String {
        "geomed".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn of_identical_points_is_the_point() {
        let out = GeometricMedian::default().aggregate(&vec![vec![3.0, -1.0]; 5]);
        assert!((out[0] - 3.0).abs() < 1e-4 && (out[1] + 1.0).abs() < 1e-4);
    }

    #[test]
    fn collinear_median() {
        // geometric median of {0, 1, 10} on a line is the middle point 1
        let msgs = vec![vec![0.0], vec![1.0], vec![10.0]];
        let out = GeometricMedian::default().aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 1e-2, "{}", out[0]);
    }

    #[test]
    fn robust_to_minority_outlier() {
        let mut msgs = vec![vec![1.0f32, 1.0]; 6];
        msgs.push(vec![1e5, -1e5]);
        let out = GeometricMedian::default().aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 0.1 && (out[1] - 1.0).abs() < 0.1);
    }

    #[test]
    fn two_points_lands_between() {
        let msgs = vec![vec![0.0], vec![2.0]];
        let out = GeometricMedian::default().aggregate(&msgs);
        assert!(out[0] >= 0.0 && out[0] <= 2.0);
    }
}
