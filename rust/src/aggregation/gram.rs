//! Shared pairwise-distance kernel for the O(N²Q) aggregation rules.
//!
//! Krum, Multi-Krum and NNM all consume the same triangular matrix of
//! squared distances d(i,j) = ‖xᵢ − xⱼ‖². [`PairwiseDistances`] computes it
//! exactly once per aggregate call via the Gram expansion
//! `‖i‖² + ‖j‖² − 2⟨i,j⟩` with cached norms — N(N−1)/2 dot products total,
//! half of what PR 1's row-parallel pass spent (each d(i,j) was evaluated
//! once per side there).
//!
//! The parallel pass tiles the upper triangle into `TILE`×`TILE` blocks of
//! (i, j) pairs; each block is one task producing its own scratch vector
//! (disjoint output, no synchronization), scattered into the full symmetric
//! matrix afterwards. Every entry is produced by exactly one task with the
//! same expression the serial loop uses, so serial, scoped and pooled
//! execution are bit-identical by construction (pinned by
//! `tests/fuzz_determinism.rs`).
//!
//! [`CenterScratch`] is the kernel's one-vs-many sibling for the iterative
//! reweighting rules (MCC, geometric median) and the κ estimator: the
//! distance buffer is allocated once and refilled across every reweight
//! iteration, with the per-message distances fanned out over the pool.
//! Unlike the pairwise pass it does **not** use the Gram expansion: near a
//! converged center the expansion cancels catastrophically in f32 (the
//! Weiszfeld weights would blow up on a clamped-to-zero distance), so each
//! entry is the numerically stable subtract-first [`dist_sq`], which the
//! SIMD backend accelerates directly.

use super::par_gate;
use crate::util::math::{dist_sq, dot, norm_sq};
use crate::util::parallel::Pool;

/// Maximum row-block edge of one parallel tile: 16×16 pairs of Q-dim dot
/// products is plenty of work per task while still load-balancing N=100
/// across many workers (⌈100/16⌉ = 7 row blocks ⇒ 28 tasks). Small
/// families shrink the tile instead of going serial — see [`tile_for`].
const TILE: usize = 16;

/// Tile edge for an N-message family on `threads` workers: small enough
/// that the triangle yields ≥ ~4 tasks per worker (so a fat-Q N=8 family
/// still spreads its dots), capped at [`TILE`]. Purely a scheduling choice
/// — every entry is computed by the same expression whatever the tiling,
/// so results are bit-identical for any tile edge.
fn tile_for(n: usize, threads: usize) -> usize {
    let target_blocks = ((4.0 * threads as f64).sqrt().ceil() as usize).max(2);
    n.div_ceil(target_blocks).clamp(1, TILE)
}

/// The symmetric N×N squared-distance matrix of a message family, computed
/// once via the Gram expansion.
#[derive(Debug, Clone)]
pub struct PairwiseDistances {
    n: usize,
    /// full symmetric matrix, diagonal 0 (row access beats triangular
    /// packing on the consumer side; N ≤ a few hundred keeps this small)
    dist: Vec<f64>,
    norms: Vec<f64>,
}

impl PairwiseDistances {
    /// Compute the matrix for `msgs` (equal-length vectors), tiling the
    /// triangular pass over `pool` when the family is large enough.
    pub fn compute(msgs: &[Vec<f32>], pool: &Pool) -> Self {
        let n = msgs.len();
        let q = msgs.first().map(|m| m.len()).unwrap_or(0);
        let norms: Vec<f64> = msgs.iter().map(|m| norm_sq(m)).collect();
        let mut dist = vec![0.0f64; n * n];
        let entry = |i: usize, j: usize| -> f64 {
            (norms[i] + norms[j] - 2.0 * dot(&msgs[i], &msgs[j]) as f64).max(0.0)
        };
        if pool.is_serial() || !par_gate(n, q) || n < 2 {
            for i in 0..n {
                for j in i + 1..n {
                    let d = entry(i, j);
                    dist[i * n + j] = d;
                    dist[j * n + i] = d;
                }
            }
        } else {
            let tile = tile_for(n, pool.threads());
            let blocks = n.div_ceil(tile);
            let mut tasks: Vec<(usize, usize)> = Vec::with_capacity(blocks * (blocks + 1) / 2);
            for bi in 0..blocks {
                for bj in bi..blocks {
                    tasks.push((bi, bj));
                }
            }
            // per-task scratch tiles: disjoint output, stitched serially
            let tiles: Vec<Vec<f64>> = pool.par_map(&tasks, |_, &(bi, bj)| {
                let mut out = Vec::with_capacity(tile * tile);
                for i in bi * tile..((bi + 1) * tile).min(n) {
                    for j in (bj * tile).max(i + 1)..((bj + 1) * tile).min(n) {
                        out.push(entry(i, j));
                    }
                }
                out
            });
            for (&(bi, bj), t) in tasks.iter().zip(&tiles) {
                let mut it = t.iter();
                for i in bi * tile..((bi + 1) * tile).min(n) {
                    for j in (bj * tile).max(i + 1)..((bj + 1) * tile).min(n) {
                        let d = *it.next().expect("tile layout mismatch");
                        dist[i * n + j] = d;
                        dist[j * n + i] = d;
                    }
                }
            }
        }
        PairwiseDistances { n, dist, norms }
    }

    /// Family size N.
    pub fn n(&self) -> usize {
        self.n
    }

    /// d(i,j); 0 on the diagonal.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.n && j < self.n);
        self.dist[i * self.n + j]
    }

    /// Full row i (diagonal entry included, = 0).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.n);
        &self.dist[i * self.n..(i + 1) * self.n]
    }

    /// Cached squared norms ‖xᵢ‖² (free byproduct of the Gram pass).
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }
}

/// Below this many total elements (messages × dim) the one-vs-many pass
/// stays on the calling thread — dispatch overhead would dominate.
const CENTER_PAR_MIN_ELEMS: usize = 1 << 12;

/// Reusable distance scratch for repeated distances-to-a-moving-center
/// queries — the shape of every iteratively-reweighted rule (MCC
/// reweighting, Weiszfeld iterations) and of the κ estimator's spread
/// computation. The output buffer is allocated once and reused across
/// iterations; each entry is the subtract-first [`dist_sq`] (stable near a
/// converged center, where the Gram expansion would cancel to a clamped
/// zero and explode the reweight), fanned out over the pool when the
/// family is large enough — bit-identical either way (entries are
/// independent).
#[derive(Debug, Clone, Default)]
pub struct CenterScratch {
    d2: Vec<f64>,
}

impl CenterScratch {
    pub fn new() -> Self {
        CenterScratch { d2: Vec::new() }
    }

    /// Fill the internal buffer with ‖msgs[i] − c‖² and return it.
    pub fn dist_sq_to(&mut self, msgs: &[Vec<f32>], c: &[f32], pool: &Pool) -> &[f64] {
        self.d2.clear();
        if !pool.is_serial() && msgs.len() * c.len() >= CENTER_PAR_MIN_ELEMS {
            self.d2.extend(pool.par_map(msgs, |_, m| dist_sq(m, c)));
        } else {
            self.d2.extend(msgs.iter().map(|m| dist_sq(m, c)));
        }
        &self.d2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::dist_sq;
    use crate::util::parallel::Parallelism;
    use crate::util::rng::Rng;

    fn family(n: usize, q: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.gauss_vec(q)).collect()
    }

    #[test]
    fn matches_direct_distances_within_float_error() {
        let msgs = family(12, 9, 1);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        for i in 0..12 {
            assert_eq!(pd.get(i, i), 0.0);
            for j in 0..12 {
                let direct = dist_sq(&msgs[i], &msgs[j]);
                let scale = direct.max(1.0);
                assert!(
                    (pd.get(i, j) - direct).abs() < 1e-4 * scale,
                    "d({i},{j}): gram {} vs direct {direct}",
                    pd.get(i, j)
                );
                assert_eq!(pd.get(i, j), pd.get(j, i), "symmetry");
            }
        }
    }

    #[test]
    fn tiled_parallel_pass_is_bit_identical_to_serial() {
        // n ≥ 2·TILE and n²·q above the gate so tiling genuinely engages
        let msgs = family(45, 64, 2);
        let serial = PairwiseDistances::compute(&msgs, &Pool::serial());
        for pool in [Pool::new(4), Pool::new(8), Pool::scoped(Parallelism::new(3))] {
            let par = PairwiseDistances::compute(&msgs, &pool);
            assert_eq!(serial.dist, par.dist, "{pool:?}");
            assert_eq!(serial.norms, par.norms, "{pool:?}");
        }
    }

    #[test]
    fn ragged_tile_edges_are_covered() {
        // n not a multiple of the tile edge: every off-diagonal entry must
        // be filled
        let msgs = family(2 * TILE + 3, 97, 3);
        let pd = PairwiseDistances::compute(&msgs, &Pool::new(4));
        for i in 0..pd.n() {
            for j in 0..pd.n() {
                if i != j {
                    assert!(pd.get(i, j) > 0.0, "unfilled entry d({i},{j})");
                }
            }
        }
    }

    #[test]
    fn small_n_fat_q_still_tiles_and_matches_serial() {
        // n far below TILE but n²·q above the gate: the adaptive tile must
        // engage (fat-Q regime) and stay bit-identical to serial
        for n in [2usize, 3, 8, 20] {
            let msgs = family(n, 70_000 / (n * n) + 16, 40 + n as u64);
            let serial = PairwiseDistances::compute(&msgs, &Pool::serial());
            let par = PairwiseDistances::compute(&msgs, &Pool::new(8));
            assert_eq!(serial.dist, par.dist, "n={n}");
        }
        // tile_for spreads small families over multiple blocks
        assert!(tile_for(8, 8) < 8);
        assert!(tile_for(1, 8) >= 1);
        assert!(tile_for(1000, 8) <= TILE);
    }

    #[test]
    fn norms_accessor_matches_norm_sq() {
        let msgs = family(6, 17, 4);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        for (m, &n2) in msgs.iter().zip(pd.norms()) {
            assert_eq!(n2, norm_sq(m));
        }
    }

    #[test]
    fn center_scratch_matches_direct_and_is_pool_invariant() {
        let msgs = family(40, 120, 5);
        let c = family(1, 120, 6).pop().unwrap();
        let mut scratch = CenterScratch::new();
        let serial: Vec<f64> = scratch.dist_sq_to(&msgs, &c, &Pool::serial()).to_vec();
        for (m, &d2) in msgs.iter().zip(&serial) {
            assert_eq!(d2, dist_sq(m, &c), "stable direct distance, exactly");
        }
        let pooled: Vec<f64> = scratch.dist_sq_to(&msgs, &c, &Pool::new(4)).to_vec();
        assert_eq!(serial, pooled);
        // reuse: second query with another center refills the same buffer
        let c2 = family(1, 120, 7).pop().unwrap();
        assert_eq!(scratch.dist_sq_to(&msgs, &c2, &Pool::serial()).len(), msgs.len());
    }

    #[test]
    fn center_scratch_is_stable_near_a_converged_center() {
        // the reason CenterScratch is NOT Gram-based: center == a message
        // with large norms must give exactly 0, not cancellation noise
        let big: Vec<f32> = (0..4096).map(|i| 100.0 + (i % 7) as f32).collect();
        let msgs = vec![big.clone(), big.iter().map(|x| x + 1.0).collect()];
        let mut scratch = CenterScratch::new();
        let d2 = scratch.dist_sq_to(&msgs, &big, &Pool::serial()).to_vec();
        assert_eq!(d2[0], 0.0);
        assert!((d2[1] - 4096.0).abs() < 1e-6, "{}", d2[1]);
    }
}
