//! Shared pairwise-distance kernel for the O(N²Q) aggregation rules.
//!
//! Krum, Multi-Krum and NNM all consume the same triangular matrix of
//! squared distances d(i,j) = ‖xᵢ − xⱼ‖². [`PairwiseDistances`] computes it
//! exactly once per aggregate call via the Gram expansion
//! `‖i‖² + ‖j‖² − 2⟨i,j⟩` with cached norms — N(N−1)/2 dot products total,
//! half of what PR 1's row-parallel pass spent (each d(i,j) was evaluated
//! once per side there). The dots themselves run on whatever kernel tier
//! the [`crate::util::math`] dispatcher selected (scalar / SSE2 /
//! AVX2+FMA), bit-identical across tiers by the lane contract.
//!
//! # Packed-triangular storage
//!
//! Only the strict upper triangle is stored — n(n−1)/2 f64 values in
//! row-major pair order — which halves the footprint of the full symmetric
//! matrix PR 2 kept (at the federated-scale N ≳ 10³ sweeps that is ~4 MB
//! saved per aggregate call, and the build pass writes each entry once
//! instead of mirroring it). Consumers keep their row-oriented access
//! pattern through the [`RowView`] adapter: `pd.row(i)` yields the same
//! n-length logical row (diagonal 0) the full layout exposed, walking the
//! column segment j < i with a decreasing stride and the row segment j > i
//! contiguously.
//!
//! The parallel pass tiles the upper triangle into `TILE`×`TILE` blocks of
//! (i, j) pairs; each block is one task producing its own scratch vector
//! (disjoint output, no synchronization), scattered into the packed
//! triangle afterwards — one write per entry, where the full-matrix layout
//! paid two. Every entry is produced by exactly one task with the same
//! expression the serial loop uses, so serial, scoped and pooled execution
//! are bit-identical by construction (pinned by
//! `tests/fuzz_determinism.rs`, which also pins packed-vs-full equality
//! against a naively built N×N reference).
//!
//! [`CenterScratch`] is the kernel's one-vs-many sibling for the iterative
//! reweighting rules (MCC, geometric median) and the κ estimator: the
//! distance buffer is allocated once and refilled across every reweight
//! iteration, with the per-message distances fanned out over the pool.
//! Unlike the pairwise pass it does **not** use the Gram expansion: near a
//! converged center the expansion cancels catastrophically in f32 (the
//! Weiszfeld weights would blow up on a clamped-to-zero distance), so each
//! entry is the numerically stable subtract-first [`dist_sq`], which every
//! intrinsics tier accelerates directly.

use super::par_gate;
use crate::obs::Obs;
use crate::util::math::{dist_sq, dot, norm_sq};
use crate::util::parallel::Pool;

/// Maximum row-block edge of one parallel tile: 16×16 pairs of Q-dim dot
/// products is plenty of work per task while still load-balancing N=100
/// across many workers (⌈100/16⌉ = 7 row blocks ⇒ 28 tasks). Small
/// families shrink the tile instead of going serial — see [`tile_for`].
const TILE: usize = 16;

/// Tile edge for an N-message family on `threads` workers: small enough
/// that the triangle yields ≥ ~4 tasks per worker (so a fat-Q N=8 family
/// still spreads its dots), capped at [`TILE`]. Purely a scheduling choice
/// — every entry is computed by the same expression whatever the tiling,
/// so results are bit-identical for any tile edge.
fn tile_for(n: usize, threads: usize) -> usize {
    let target_blocks = ((4.0 * threads as f64).sqrt().ceil() as usize).max(2);
    n.div_ceil(target_blocks).clamp(1, TILE)
}

/// Index of pair (i, j), i < j, in the packed strict upper triangle
/// (row-major: row 0's n−1 entries, then row 1's n−2, …).
#[inline]
fn tri_index(n: usize, i: usize, j: usize) -> usize {
    debug_assert!(i < j && j < n);
    i * n - i * (i + 1) / 2 + (j - i - 1)
}

/// Packed offset where row i's contiguous segment (j > i) begins.
#[inline]
fn row_start(n: usize, i: usize) -> usize {
    i * n - i * (i + 1) / 2
}

/// The symmetric N×N squared-distance matrix of a message family, computed
/// once via the Gram expansion and stored as a packed strict upper triangle
/// (n(n−1)/2 f64 — half the full-matrix footprint).
#[derive(Debug, Clone)]
pub struct PairwiseDistances {
    n: usize,
    /// strict upper triangle in row-major pair order; entry (i,j), i<j, at
    /// [`tri_index`]`(n, i, j)`
    tri: Vec<f64>,
    norms: Vec<f64>,
}

impl PairwiseDistances {
    /// [`PairwiseDistances::compute`] wrapped in the `kernel/gram_fill`
    /// span: the tiled triangular fill dominates every distance-hungry
    /// rule's cost, so rules with an attached obs context
    /// ([`crate::aggregation::Aggregator::set_obs`]) time it here.
    /// Telemetry only — the computed matrix is bit-identical.
    pub fn compute_spanned(msgs: &[Vec<f32>], pool: &Pool, obs: &Obs) -> Self {
        let sp = obs.span("kernel/gram_fill");
        let pd = Self::compute(msgs, pool);
        sp.done();
        pd
    }

    /// Compute the matrix for `msgs` (equal-length vectors), tiling the
    /// triangular pass over `pool` when the family is large enough.
    pub fn compute(msgs: &[Vec<f32>], pool: &Pool) -> Self {
        let n = msgs.len();
        let q = msgs.first().map(|m| m.len()).unwrap_or(0);
        let norms: Vec<f64> = msgs.iter().map(|m| norm_sq(m)).collect();
        let pairs = n * n.saturating_sub(1) / 2;
        let entry = |i: usize, j: usize| -> f64 {
            (norms[i] + norms[j] - 2.0 * dot(&msgs[i], &msgs[j]) as f64).max(0.0)
        };
        let tri = if pool.is_serial() || !par_gate(n, q) || n < 2 {
            // serial pass appends in exactly packed order — no index math
            let mut tri = Vec::with_capacity(pairs);
            for i in 0..n {
                for j in i + 1..n {
                    tri.push(entry(i, j));
                }
            }
            tri
        } else {
            let tile = tile_for(n, pool.threads());
            let blocks = n.div_ceil(tile);
            let mut tasks: Vec<(usize, usize)> = Vec::with_capacity(blocks * (blocks + 1) / 2);
            for bi in 0..blocks {
                for bj in bi..blocks {
                    tasks.push((bi, bj));
                }
            }
            // per-task scratch tiles: disjoint pair sets, stitched into the
            // packed triangle serially (one write per entry)
            let tiles: Vec<Vec<f64>> = pool.par_map(&tasks, |_, &(bi, bj)| {
                let mut out = Vec::with_capacity(tile * tile);
                for i in bi * tile..((bi + 1) * tile).min(n) {
                    for j in (bj * tile).max(i + 1)..((bj + 1) * tile).min(n) {
                        out.push(entry(i, j));
                    }
                }
                out
            });
            let mut tri = vec![0.0f64; pairs];
            for (&(bi, bj), t) in tasks.iter().zip(&tiles) {
                let mut it = t.iter();
                for i in bi * tile..((bi + 1) * tile).min(n) {
                    let base = row_start(n, i);
                    for j in (bj * tile).max(i + 1)..((bj + 1) * tile).min(n) {
                        tri[base + (j - i - 1)] = *it.next().expect("tile layout mismatch");
                    }
                }
            }
            tri
        };
        PairwiseDistances { n, tri, norms }
    }

    /// Family size N.
    pub fn n(&self) -> usize {
        self.n
    }

    /// d(i,j); 0 on the diagonal, symmetric by construction.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.n && j < self.n);
        match i.cmp(&j) {
            std::cmp::Ordering::Less => self.tri[tri_index(self.n, i, j)],
            std::cmp::Ordering::Equal => 0.0,
            std::cmp::Ordering::Greater => self.tri[tri_index(self.n, j, i)],
        }
    }

    /// Logical row i as a [`RowView`] — the same n entries (diagonal 0) the
    /// full-matrix layout used to expose, adapted onto the packed triangle.
    #[inline]
    pub fn row(&self, i: usize) -> RowView<'_> {
        debug_assert!(i < self.n);
        RowView { pd: self, i }
    }

    /// Cached squared norms ‖xᵢ‖² (free byproduct of the Gram pass).
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    /// Distances of a **mixed** family reusing this matrix — the NNM →
    /// inner-Krum Gram-reuse path. Given per-row neighbor index sets Sᵢ
    /// (each a non-empty, in-range subset of `0..n`; NNM passes them
    /// sorted), the mixed messages are yᵢ = (1/|Sᵢ|) Σ_{a∈Sᵢ} x_a and their
    /// pairwise distances follow from this matrix alone:
    ///
    /// ```text
    /// G(a,b)  = (‖x_a‖² + ‖x_b‖² − d(a,b)) / 2          (recovered Gram)
    /// H(i,j)  = (W·G·Wᵀ)ᵢⱼ / (kᵢ·kⱼ) = ⟨yᵢ, yⱼ⟩
    /// d'(i,j) = max(0, H(i,i) + H(j,j) − 2·H(i,j))
    /// ```
    ///
    /// evaluated as two passes (U = W·G, then H = U·Wᵀ) — O(m·n·k) total
    /// instead of the second O(m²·Q) pass over the Q-dim mixed vectors.
    /// All sums run in ascending set order in f64, so every pool width is
    /// bit-identical (the packed result is pinned against a naive full
    /// N×N reference by `tests/fuzz_determinism.rs`). The float path
    /// differs from re-running [`PairwiseDistances::compute`] on the
    /// mixed vectors (clamped Gram recovery vs fresh dot products), so
    /// consumers see slightly different — but deterministic — entries.
    pub fn mixed(&self, sets: &[Vec<usize>], pool: &Pool) -> PairwiseDistances {
        let n = self.n;
        let m = sets.len();
        debug_assert!(
            sets.iter().all(|s| !s.is_empty() && s.iter().all(|&a| a < n)),
            "neighbor sets must be non-empty and in range"
        );
        // recovered Gram entry ⟨x_a, x_b⟩ from the distance expansion
        let g = |a: usize, b: usize| -> f64 {
            (self.norms[a] + self.norms[b] - self.get(a, b)) / 2.0
        };
        // U = W·G: row i holds Σ_{a∈Sᵢ} G(a, ·)
        let u_row = |i: usize| -> Vec<f64> {
            let mut row = vec![0.0f64; n];
            for &a in &sets[i] {
                for (b, slot) in row.iter_mut().enumerate() {
                    *slot += g(a, b);
                }
            }
            row
        };
        let idx: Vec<usize> = (0..m).collect();
        let u: Vec<Vec<f64>> = if pool.is_serial() || !par_gate(m, n) {
            idx.iter().map(|&i| u_row(i)).collect()
        } else {
            pool.par_map(&idx, |_, &i| u_row(i))
        };
        // H(i,j) = (U·Wᵀ)ᵢⱼ / (kᵢ·kⱼ) — the mixed inner products
        let h = |i: usize, j: usize| -> f64 {
            let mut s = 0.0f64;
            for &b in &sets[j] {
                s += u[i][b];
            }
            s / (sets[i].len() as f64 * sets[j].len() as f64)
        };
        // mixed squared norms ‖yᵢ‖² = H(i,i), clamped like every distance
        let norms: Vec<f64> = (0..m).map(|i| h(i, i).max(0.0)).collect();
        let entry =
            |i: usize, j: usize| -> f64 { (norms[i] + norms[j] - 2.0 * h(i, j)).max(0.0) };
        let tri = if pool.is_serial() || !par_gate(m, n) || m < 2 {
            let mut tri = Vec::with_capacity(m * m.saturating_sub(1) / 2);
            for i in 0..m {
                for j in i + 1..m {
                    tri.push(entry(i, j));
                }
            }
            tri
        } else {
            // per-row tasks produce disjoint contiguous packed segments;
            // concatenation in row order IS the packed layout
            let rows: Vec<Vec<f64>> =
                pool.par_map(&idx, |_, &i| (i + 1..m).map(|j| entry(i, j)).collect());
            rows.concat()
        };
        PairwiseDistances { n: m, tri, norms }
    }

    /// Stored distance entries (the packed strict upper triangle).
    pub fn packed_len(&self) -> usize {
        self.tri.len()
    }

    /// Bytes held by the packed distance storage.
    pub fn packed_bytes(&self) -> usize {
        self.tri.len() * std::mem::size_of::<f64>()
    }

    /// Bytes the PR 2 full symmetric N×N layout would have held — the
    /// denominator of the bench's storage-footprint line.
    pub fn full_bytes_equivalent(&self) -> usize {
        self.n * self.n * std::mem::size_of::<f64>()
    }
}

/// Borrowed view of one logical row of a [`PairwiseDistances`]: n entries
/// in column order j = 0..n, diagonal 0. Row-pattern consumers (Krum
/// scoring, NNM neighbor selection) iterate this exactly as they iterated
/// the old full-matrix row slice.
#[derive(Clone, Copy)]
pub struct RowView<'a> {
    pd: &'a PairwiseDistances,
    i: usize,
}

impl<'a> RowView<'a> {
    /// Row length (= n).
    pub fn len(&self) -> usize {
        self.pd.n
    }

    pub fn is_empty(&self) -> bool {
        self.pd.n == 0
    }

    /// d(i, j).
    #[inline]
    pub fn get(&self, j: usize) -> f64 {
        self.pd.get(self.i, j)
    }

    /// Iterate the row's n entries in column order. The column segment
    /// (j < i) walks the packed triangle with a decreasing stride; the row
    /// segment (j > i) is one contiguous packed slice.
    pub fn iter(&self) -> RowIter<'a> {
        let n = self.pd.n;
        let i = self.i;
        RowIter {
            tri: &self.pd.tri,
            n,
            i,
            j: 0,
            // (0, i) for the column walk; (i, i+1) for the contiguous tail.
            // Placeholder 0 when the respective segment is empty.
            col_idx: if i > 0 { tri_index(n, 0, i) } else { 0 },
            row_idx: if i + 1 < n { tri_index(n, i, i + 1) } else { 0 },
        }
    }

    /// Materialize the logical row (tests / debugging).
    pub fn to_vec(&self) -> Vec<f64> {
        self.iter().collect()
    }
}

impl<'a> IntoIterator for RowView<'a> {
    type Item = f64;
    type IntoIter = RowIter<'a>;
    fn into_iter(self) -> RowIter<'a> {
        self.iter()
    }
}

/// Iterator over one logical row of the packed triangle (see
/// [`RowView::iter`]).
pub struct RowIter<'a> {
    tri: &'a [f64],
    n: usize,
    i: usize,
    j: usize,
    col_idx: usize,
    row_idx: usize,
}

impl Iterator for RowIter<'_> {
    type Item = f64;

    #[inline]
    fn next(&mut self) -> Option<f64> {
        if self.j >= self.n {
            return None;
        }
        let j = self.j;
        self.j += 1;
        Some(match j.cmp(&self.i) {
            std::cmp::Ordering::Less => {
                let v = self.tri[self.col_idx];
                // next column entry (j+1, i) sits n−j−2 further on
                self.col_idx += self.n - j - 2;
                v
            }
            std::cmp::Ordering::Equal => 0.0,
            std::cmp::Ordering::Greater => {
                let v = self.tri[self.row_idx];
                self.row_idx += 1;
                v
            }
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.n - self.j;
        (left, Some(left))
    }
}

impl ExactSizeIterator for RowIter<'_> {}

/// Below this many total elements (messages × dim) the one-vs-many pass
/// stays on the calling thread — dispatch overhead would dominate.
const CENTER_PAR_MIN_ELEMS: usize = 1 << 12;

/// Reusable distance scratch for repeated distances-to-a-moving-center
/// queries — the shape of every iteratively-reweighted rule (MCC
/// reweighting, Weiszfeld iterations) and of the κ estimator's spread
/// computation. The output buffer is allocated once and reused across
/// iterations; each entry is the subtract-first [`dist_sq`] (stable near a
/// converged center, where the Gram expansion would cancel to a clamped
/// zero and explode the reweight), fanned out over the pool when the
/// family is large enough — bit-identical either way (entries are
/// independent).
#[derive(Debug, Clone, Default)]
pub struct CenterScratch {
    d2: Vec<f64>,
}

impl CenterScratch {
    pub fn new() -> Self {
        CenterScratch { d2: Vec::new() }
    }

    /// Fill the internal buffer with ‖msgs[i] − c‖² and return it.
    pub fn dist_sq_to(&mut self, msgs: &[Vec<f32>], c: &[f32], pool: &Pool) -> &[f64] {
        self.d2.clear();
        if !pool.is_serial() && msgs.len() * c.len() >= CENTER_PAR_MIN_ELEMS {
            self.d2.extend(pool.par_map(msgs, |_, m| dist_sq(m, c)));
        } else {
            self.d2.extend(msgs.iter().map(|m| dist_sq(m, c)));
        }
        &self.d2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::dist_sq;
    use crate::util::parallel::Parallelism;
    use crate::util::rng::Rng;

    fn family(n: usize, q: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.gauss_vec(q)).collect()
    }

    #[test]
    fn tri_index_is_the_packed_row_major_order() {
        // n = 5: (0,1)(0,2)(0,3)(0,4)(1,2)(1,3)(1,4)(2,3)(2,4)(3,4)
        let mut k = 0;
        for i in 0..5 {
            assert_eq!(row_start(5, i), k);
            for j in i + 1..5 {
                assert_eq!(tri_index(5, i, j), k, "({i},{j})");
                k += 1;
            }
        }
        assert_eq!(k, 10);
    }

    #[test]
    fn matches_direct_distances_within_float_error() {
        let msgs = family(12, 9, 1);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        assert_eq!(pd.packed_len(), 12 * 11 / 2);
        for i in 0..12 {
            assert_eq!(pd.get(i, i), 0.0);
            for j in 0..12 {
                let direct = dist_sq(&msgs[i], &msgs[j]);
                let scale = direct.max(1.0);
                assert!(
                    (pd.get(i, j) - direct).abs() < 1e-4 * scale,
                    "d({i},{j}): gram {} vs direct {direct}",
                    pd.get(i, j)
                );
                assert_eq!(pd.get(i, j), pd.get(j, i), "symmetry");
            }
        }
    }

    #[test]
    fn row_view_matches_entrywise_access() {
        for n in [1usize, 2, 3, 7, 12] {
            let msgs = family(n, 5, 100 + n as u64);
            let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
            for i in 0..n {
                let row = pd.row(i);
                assert_eq!(row.len(), n);
                assert_eq!(row.iter().len(), n, "ExactSize i={i}");
                let v = row.to_vec();
                assert_eq!(v.len(), n);
                for j in 0..n {
                    assert_eq!(v[j], pd.get(i, j), "n={n} ({i},{j})");
                    assert_eq!(row.get(j), pd.get(i, j), "n={n} get({i},{j})");
                }
                assert_eq!(v[i], 0.0, "diagonal");
            }
        }
    }

    #[test]
    fn packed_storage_halves_the_full_matrix_footprint() {
        let msgs = family(20, 4, 7);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        assert_eq!(pd.packed_bytes(), 20 * 19 / 2 * 8);
        assert_eq!(pd.full_bytes_equivalent(), 20 * 20 * 8);
        assert!(pd.packed_bytes() * 2 < pd.full_bytes_equivalent());
    }

    #[test]
    fn tiled_parallel_pass_is_bit_identical_to_serial() {
        // n ≥ 2·TILE and n²·q above the gate so tiling genuinely engages
        let msgs = family(45, 64, 2);
        let serial = PairwiseDistances::compute(&msgs, &Pool::serial());
        for pool in [Pool::new(4), Pool::new(8), Pool::scoped(Parallelism::new(3))] {
            let par = PairwiseDistances::compute(&msgs, &pool);
            assert_eq!(serial.tri, par.tri, "{pool:?}");
            assert_eq!(serial.norms, par.norms, "{pool:?}");
        }
    }

    #[test]
    fn ragged_tile_edges_are_covered() {
        // n not a multiple of the tile edge: every off-diagonal entry must
        // be filled
        let msgs = family(2 * TILE + 3, 97, 3);
        let pd = PairwiseDistances::compute(&msgs, &Pool::new(4));
        for i in 0..pd.n() {
            for j in 0..pd.n() {
                if i != j {
                    assert!(pd.get(i, j) > 0.0, "unfilled entry d({i},{j})");
                }
            }
        }
    }

    #[test]
    fn small_n_fat_q_still_tiles_and_matches_serial() {
        // n far below TILE but n²·q above the gate: the adaptive tile must
        // engage (fat-Q regime) and stay bit-identical to serial
        for n in [2usize, 3, 8, 20] {
            let msgs = family(n, 70_000 / (n * n) + 16, 40 + n as u64);
            let serial = PairwiseDistances::compute(&msgs, &Pool::serial());
            let par = PairwiseDistances::compute(&msgs, &Pool::new(8));
            assert_eq!(serial.tri, par.tri, "n={n}");
        }
        // tile_for spreads small families over multiple blocks
        assert!(tile_for(8, 8) < 8);
        assert!(tile_for(1, 8) >= 1);
        assert!(tile_for(1000, 8) <= TILE);
    }

    #[test]
    fn norms_accessor_matches_norm_sq() {
        let msgs = family(6, 17, 4);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        for (m, &n2) in msgs.iter().zip(pd.norms()) {
            assert_eq!(n2, norm_sq(m));
        }
    }

    #[test]
    fn center_scratch_matches_direct_and_is_pool_invariant() {
        let msgs = family(40, 120, 5);
        let c = family(1, 120, 6).pop().unwrap();
        let mut scratch = CenterScratch::new();
        let serial: Vec<f64> = scratch.dist_sq_to(&msgs, &c, &Pool::serial()).to_vec();
        for (m, &d2) in msgs.iter().zip(&serial) {
            assert_eq!(d2, dist_sq(m, &c), "stable direct distance, exactly");
        }
        let pooled: Vec<f64> = scratch.dist_sq_to(&msgs, &c, &Pool::new(4)).to_vec();
        assert_eq!(serial, pooled);
        // reuse: second query with another center refills the same buffer
        let c2 = family(1, 120, 7).pop().unwrap();
        assert_eq!(scratch.dist_sq_to(&msgs, &c2, &Pool::serial()).len(), msgs.len());
    }

    #[test]
    fn mixed_matches_distances_of_explicitly_mixed_vectors() {
        let n = 14;
        let q = 24;
        let msgs = family(n, q, 8);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        // per-row neighbor sets of varying size, sorted ascending
        let sets: Vec<Vec<usize>> = (0..n)
            .map(|i| {
                let mut s: Vec<usize> = (0..3 + i % 4).map(|k| (i + 2 * k) % n).collect();
                s.sort_unstable();
                s
            })
            .collect();
        let mixed = pd.mixed(&sets, &Pool::serial());
        assert_eq!(mixed.n(), n);
        assert_eq!(mixed.packed_len(), n * (n - 1) / 2);
        // reference: mix the vectors explicitly, then measure them directly
        let ymix: Vec<Vec<f32>> = sets
            .iter()
            .map(|s| {
                let mut y = vec![0.0f32; q];
                for &a in s {
                    for (slot, v) in y.iter_mut().zip(&msgs[a]) {
                        *slot += v;
                    }
                }
                for slot in &mut y {
                    *slot /= s.len() as f32;
                }
                y
            })
            .collect();
        for i in 0..n {
            assert_eq!(mixed.get(i, i), 0.0);
            for j in 0..n {
                let direct = dist_sq(&ymix[i], &ymix[j]);
                let scale = direct.max(1.0);
                assert!(
                    (mixed.get(i, j) - direct).abs() < 1e-3 * scale,
                    "d'({i},{j}): gram-derived {} vs direct {direct}",
                    mixed.get(i, j)
                );
                assert_eq!(mixed.get(i, j), mixed.get(j, i), "symmetry");
            }
        }
        for (i, (&nm, y)) in mixed.norms().iter().zip(&ymix).enumerate() {
            let direct = norm_sq(y);
            assert!((nm - direct).abs() < 1e-3 * direct.max(1.0), "norm {i}: {nm} vs {direct}");
        }
    }

    #[test]
    fn mixed_parallel_fill_is_bit_identical_to_serial() {
        // m²·n above the gate so the pooled U rows AND the pooled packed
        // fill both engage
        let msgs = family(45, 64, 9);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        let sets: Vec<Vec<usize>> = (0..45)
            .map(|i| {
                let mut s: Vec<usize> =
                    (0..3 + i % 17).map(|k| (i * 7 + k * 5) % 45).collect();
                s.sort_unstable();
                s.dedup();
                s
            })
            .collect();
        let serial = pd.mixed(&sets, &Pool::serial());
        for pool in [Pool::new(4), Pool::new(8), Pool::scoped(Parallelism::new(3))] {
            let par = pd.mixed(&sets, &pool);
            assert_eq!(serial.tri, par.tri, "{pool:?}");
            assert_eq!(serial.norms, par.norms, "{pool:?}");
        }
    }

    #[test]
    fn center_scratch_is_stable_near_a_converged_center() {
        // the reason CenterScratch is NOT Gram-based: center == a message
        // with large norms must give exactly 0, not cancellation noise
        let big: Vec<f32> = (0..4096).map(|i| 100.0 + (i % 7) as f32).collect();
        let msgs = vec![big.clone(), big.iter().map(|x| x + 1.0).collect()];
        let mut scratch = CenterScratch::new();
        let d2 = scratch.dist_sq_to(&msgs, &big, &Pool::serial()).to_vec();
        assert_eq!(d2[0], 0.0);
        assert!((d2[1] - 4096.0).abs() < 1e-6, "{}", d2[1]);
    }
}
