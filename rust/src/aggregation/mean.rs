//! Plain averaging — the "VA" baseline (no Byzantine robustness).

use super::{check_family, Aggregator};
use crate::util::math::{axpy, scale};

/// Coordinate-wise arithmetic mean.
#[derive(Debug, Clone, Copy, Default)]
pub struct Mean;

impl Aggregator for Mean {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let mut out = vec![0.0f32; q];
        for m in msgs {
            axpy(1.0, m, &mut out);
        }
        scale(&mut out, 1.0 / msgs.len() as f32);
        out
    }

    fn name(&self) -> String {
        "mean".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages() {
        let out = Mean.aggregate(&[vec![1.0, 2.0], vec![3.0, 6.0]]);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn single_message_identity() {
        let out = Mean.aggregate(&[vec![5.0, -1.0]]);
        assert_eq!(out, vec![5.0, -1.0]);
    }

    #[test]
    fn hijacked_by_one_outlier() {
        // documents WHY VA fails under attack (Fig. 4)
        let mut msgs = vec![vec![1.0f32]; 9];
        msgs.push(vec![1e6]);
        let out = Mean.aggregate(&msgs);
        assert!(out[0] > 1e4);
    }
}
