//! Coordinate-wise trimmed mean (Yin et al., ICML'18 [7]).
//!
//! Per coordinate: drop the ⌊βN⌋ smallest and ⌊βN⌋ largest values, average
//! the rest. The paper's experiments use β = 0.1.
//!
//! Hot-path note: uses `select_nth_unstable` twice per coordinate (O(N))
//! instead of a full sort (O(N log N)); the column scratch buffer is reused
//! across coordinates.

use super::{check_family, Aggregator};

/// CWTM with trim fraction β ∈ [0, 0.5).
#[derive(Debug, Clone, Copy)]
pub struct Cwtm {
    beta: f64,
}

impl Cwtm {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..0.5).contains(&beta), "trim fraction must be in [0, 0.5)");
        Cwtm { beta }
    }

    fn trim_count(&self, n: usize) -> usize {
        let b = (self.beta * n as f64).floor() as usize;
        // never trim everything
        b.min((n - 1) / 2)
    }
}

impl Aggregator for Cwtm {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let n = msgs.len();
        let b = self.trim_count(n);
        let keep = n - 2 * b;
        let mut out = vec![0.0f32; q];
        let mut col: Vec<f32> = vec![0.0; n];
        for j in 0..q {
            for (i, m) in msgs.iter().enumerate() {
                col[i] = m[j];
            }
            if b > 0 {
                // partition: everything below index b is among the b smallest,
                // everything above n-b-1 among the b largest (total_cmp is
                // branch-lean vs partial_cmp().unwrap(); §Perf)
                col.select_nth_unstable_by(b, f32::total_cmp);
                col[b..].select_nth_unstable_by(keep - 1, f32::total_cmp);
            }
            let sum: f32 = col[b..n - b].iter().sum();
            out[j] = sum / keep as f32;
        }
        out
    }

    fn name(&self) -> String {
        format!("cwtm({})", self.beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn trims_outliers_per_coordinate() {
        let msgs = vec![
            vec![1.0, -100.0],
            vec![2.0, 1.0],
            vec![3.0, 2.0],
            vec![4.0, 3.0],
            vec![100.0, 4.0],
        ];
        // β=0.2, N=5 => trim 1 each side per coordinate
        let out = Cwtm::new(0.2).aggregate(&msgs);
        assert_eq!(out, vec![3.0, 2.0]);
    }

    #[test]
    fn zero_trim_equals_mean() {
        let mut rng = Rng::new(1);
        let msgs: Vec<Vec<f32>> = (0..7).map(|_| rng.gauss_vec(5)).collect();
        let a = Cwtm::new(0.0).aggregate(&msgs);
        let b = super::super::Mean.aggregate(&msgs);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matches_naive_sorted_implementation() {
        let mut rng = Rng::new(2);
        let msgs: Vec<Vec<f32>> = (0..20).map(|_| rng.gauss_vec(16)).collect();
        let beta = 0.1;
        let fast = Cwtm::new(beta).aggregate(&msgs);
        // naive reference
        let n = msgs.len();
        let b = (beta * n as f64).floor() as usize;
        for j in 0..16 {
            let mut col: Vec<f32> = msgs.iter().map(|m| m[j]).collect();
            col.sort_by(|a, c| a.partial_cmp(c).unwrap());
            let want: f32 =
                col[b..n - b].iter().sum::<f32>() / (n - 2 * b) as f32;
            assert!((fast[j] - want).abs() < 1e-4, "coord {j}");
        }
    }

    #[test]
    fn resists_minority_sign_flip() {
        // 8 honest near 1.0, 2 Byzantine at -2000: trimmed mean stays near 1
        let mut msgs = vec![vec![1.0f32; 3]; 8];
        msgs.push(vec![-2000.0; 3]);
        msgs.push(vec![-2000.0; 3]);
        let out = Cwtm::new(0.2).aggregate(&msgs);
        for x in out {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn never_trims_everything() {
        let out = Cwtm::new(0.49).aggregate(&[vec![1.0], vec![3.0]]);
        assert_eq!(out, vec![2.0]); // n=2 => trim 0
    }
}
