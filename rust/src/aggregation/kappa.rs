//! Empirical robustness-coefficient estimation (Definition 1).
//!
//! κ is the smallest constant with
//! ‖agg({z},{z̃}) − z̄‖² ≤ κ · (1/H) Σ‖zᵢ − z̄‖² for all inputs. We lower-
//! bound it by maximizing the ratio over randomized honest families and a
//! small portfolio of adversarial placements — enough to (a) sanity-check
//! that robust rules have small κ while the mean does not, and (b) feed a
//! measured κ into the theory formulas for the Fig. 2/3 reproductions.
//!
//! The honest spread Σ‖zᵢ − z̄‖² is computed through the shared
//! [`CenterScratch`] kernel (one distance buffer reused across every trial,
//! on the runtime-dispatched `dist_sq` tier) and shared by the whole
//! adversarial portfolio of each trial.

use super::gram::CenterScratch;
use super::Aggregator;
use crate::util::math::{dist_sq, mean_of};
use crate::util::parallel::Pool;
use crate::util::rng::Rng;

/// One adversarial scenario's ratio against a precomputed honest baseline;
/// κ̂ is the max over scenarios.
fn ratio(
    agg: &dyn Aggregator,
    honest: &[Vec<f32>],
    byz: &[Vec<f32>],
    zbar: &[f32],
    spread: f64,
) -> f64 {
    let mut msgs: Vec<Vec<f32>> = honest.to_vec();
    msgs.extend_from_slice(byz);
    let out = agg.aggregate(&msgs);
    let dev = dist_sq(&out, zbar);
    if spread < 1e-18 {
        if dev < 1e-18 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        dev / spread
    }
}

/// Estimate κ̂ for an aggregation rule with `h` honest / `f` Byzantine.
pub fn estimate_kappa(
    agg: &dyn Aggregator,
    h: usize,
    f: usize,
    dim: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let pool = Pool::serial();
    let mut scratch = CenterScratch::new();
    let mut kappa: f64 = 0.0;
    for _ in 0..trials {
        let spread_scale = 10f64.powf(rng.f64() * 2.0 - 1.0); // 0.1 .. 10
        let honest: Vec<Vec<f32>> = (0..h)
            .map(|_| (0..dim).map(|_| rng.normal(0.0, spread_scale) as f32).collect())
            .collect();
        let zbar =
            mean_of(&honest.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
        let d2 = scratch.dist_sq_to(&honest, &zbar, &pool);
        let spread = d2.iter().sum::<f64>() / h as f64;
        // adversarial portfolio: far point, sign-flip of mean, mimic extreme
        // honest, small-norm bias
        let far: Vec<f32> =
            zbar.iter().map(|x| x + 100.0 * spread_scale as f32).collect();
        let flip: Vec<f32> = zbar.iter().map(|x| -2.0 * x).collect();
        let zero = vec![0.0f32; dim];
        let shifted: Vec<f32> =
            zbar.iter().map(|x| x + 3.0 * spread_scale as f32).collect();
        for adv in [&far, &flip, &zero, &shifted] {
            let byz: Vec<Vec<f32>> = (0..f).map(|_| adv.clone()).collect();
            let r = ratio(agg, &honest, &byz, &zbar, spread);
            if r.is_finite() {
                kappa = kappa.max(r);
            }
        }
    }
    kappa
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::{CoordinateMedian, Cwtm, Mean};

    #[test]
    fn mean_has_unbounded_kappa() {
        let mut rng = Rng::new(1);
        let k = estimate_kappa(&Mean, 8, 2, 5, 10, &mut rng);
        assert!(k > 100.0, "mean κ̂ = {k}");
    }

    #[test]
    fn cwtm_kappa_is_bounded() {
        let mut rng = Rng::new(2);
        let k = estimate_kappa(&Cwtm::new(0.2), 8, 2, 5, 20, &mut rng);
        assert!(k.is_finite() && k < 50.0, "cwtm κ̂ = {k}");
    }

    #[test]
    fn median_kappa_is_bounded() {
        let mut rng = Rng::new(3);
        let k = estimate_kappa(&CoordinateMedian, 9, 3, 5, 20, &mut rng);
        assert!(k.is_finite() && k < 60.0, "median κ̂ = {k}");
    }

    #[test]
    fn robust_rules_beat_mean() {
        let mut rng = Rng::new(4);
        let km = estimate_kappa(&Mean, 8, 2, 4, 10, &mut rng);
        let kc = estimate_kappa(&Cwtm::new(0.2), 8, 2, 4, 10, &mut rng);
        assert!(kc < km);
    }
}
