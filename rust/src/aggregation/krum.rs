//! (Multi-)Krum (Blanchard et al., NeurIPS'17 [3]).
//!
//! score(i) = Σ of the n−f−2 smallest squared distances from xᵢ to the other
//! messages; Krum returns the argmin message, Multi-Krum averages the
//! m = n − f best-scored messages.
//!
//! Both rules read the shared [`PairwiseDistances`] kernel: one triangular
//! Gram pass (tiled over the pool when large enough) feeds every score, so
//! each d(i,j) is computed exactly once — half the dot products of the old
//! row-parallel pass, and Krum + Multi-Krum on the same family share the
//! same kernel shape. Scoring walks the packed triangle through the
//! [`crate::aggregation::gram::RowView`] adapter (same logical rows as the
//! old full matrix, half the memory). The per-row partial sort is O(N²)
//! with no Q factor and stays serial.

use std::sync::{Arc, Mutex};

use super::gram::PairwiseDistances;
use super::{check_family, Aggregator};
use crate::obs::Obs;
use crate::util::math::mean_of;
use crate::util::parallel::{Parallelism, Pool};

// The un-spanned composition, kept for the pool-equivalence tests (the
// aggregate paths go through `compute_spanned` + `scores_from` so an
// attached obs context can time the two kernels separately).
#[cfg_attr(not(test), allow(dead_code))]
fn scores(msgs: &[Vec<f32>], f: usize, pool: &Pool) -> Vec<f64> {
    scores_from(&PairwiseDistances::compute(msgs, pool), f)
}

/// Krum scores from an already-built distance matrix — the entry point the
/// NNM mixed-Gram reuse path feeds through
/// [`Aggregator::aggregate_with_distances`].
fn scores_from(pd: &PairwiseDistances, f: usize) -> Vec<f64> {
    let n = pd.n();
    // number of neighbors summed per Krum: n - f - 2, floored at 1
    let m = n.saturating_sub(f + 2).max(1);
    let mut out = Vec::with_capacity(n);
    let mut dists: Vec<f64> = Vec::with_capacity(n.saturating_sub(1));
    for i in 0..n {
        dists.clear();
        dists.extend(pd.row(i).iter().enumerate().filter(|&(j, _)| j != i).map(|(_, d)| d));
        let k = m.min(dists.len());
        if k < dists.len() {
            dists.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
        }
        out.push(dists[..k].iter().sum());
    }
    out
}

/// Classic Krum: select the single most central message.
#[derive(Debug, Clone)]
pub struct Krum {
    f: usize,
    pool: Pool,
    obs: Arc<Mutex<Obs>>,
}

impl Krum {
    pub fn new(f: usize) -> Self {
        Krum { f, pool: Pool::serial(), obs: Arc::default() }
    }

    /// Share a worker pool for the tiled O(N²Q) distance pass.
    pub fn with_pool(mut self, pool: &Pool) -> Self {
        self.pool = pool.clone();
        self
    }

    /// Scoped-spawn parallelism (no persistent workers) — the pre-pool API.
    pub fn with_parallelism(self, par: Parallelism) -> Self {
        let pool = Pool::scoped(par);
        self.with_pool(&pool)
    }

    fn select(&self, msgs: &[Vec<f32>], s: &[f64]) -> Vec<f32> {
        let best = s
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        msgs[best].clone()
    }

    fn obs_handle(&self) -> Obs {
        self.obs.lock().map(|o| o.clone()).unwrap_or_default()
    }
}

impl Aggregator for Krum {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        check_family(msgs);
        let obs = self.obs_handle();
        let pd = PairwiseDistances::compute_spanned(msgs, &self.pool, &obs);
        let sp = obs.span("kernel/krum_score");
        let s = scores_from(&pd, self.f);
        sp.done();
        self.select(msgs, &s)
    }

    fn aggregate_with_distances(
        &self,
        msgs: &[Vec<f32>],
        pd: &PairwiseDistances,
    ) -> Vec<f32> {
        check_family(msgs);
        assert_eq!(pd.n(), msgs.len(), "distance matrix / family size mismatch");
        let obs = self.obs_handle();
        let sp = obs.span("kernel/krum_score");
        let s = scores_from(pd, self.f);
        sp.done();
        self.select(msgs, &s)
    }

    fn wants_distances(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("krum(f={})", self.f)
    }

    fn set_obs(&self, obs: &Obs) {
        if let Ok(mut g) = self.obs.lock() {
            *g = obs.clone();
        }
    }
}

/// Multi-Krum: average the n−f best-scored messages.
#[derive(Debug, Clone)]
pub struct MultiKrum {
    f: usize,
    pool: Pool,
    obs: Arc<Mutex<Obs>>,
}

impl MultiKrum {
    pub fn new(f: usize) -> Self {
        MultiKrum { f, pool: Pool::serial(), obs: Arc::default() }
    }

    /// Share a worker pool for the tiled O(N²Q) distance pass.
    pub fn with_pool(mut self, pool: &Pool) -> Self {
        self.pool = pool.clone();
        self
    }

    /// Scoped-spawn parallelism (no persistent workers) — the pre-pool API.
    pub fn with_parallelism(self, par: Parallelism) -> Self {
        let pool = Pool::scoped(par);
        self.with_pool(&pool)
    }

    fn select(&self, msgs: &[Vec<f32>], s: &[f64]) -> Vec<f32> {
        let n = msgs.len();
        let keep = n.saturating_sub(self.f).max(1);
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| s[a].partial_cmp(&s[b]).unwrap());
        let selected: Vec<&[f32]> =
            idx[..keep].iter().map(|&i| msgs[i].as_slice()).collect();
        mean_of(&selected)
    }

    fn obs_handle(&self) -> Obs {
        self.obs.lock().map(|o| o.clone()).unwrap_or_default()
    }
}

impl Aggregator for MultiKrum {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        check_family(msgs);
        let obs = self.obs_handle();
        let pd = PairwiseDistances::compute_spanned(msgs, &self.pool, &obs);
        let sp = obs.span("kernel/krum_score");
        let s = scores_from(&pd, self.f);
        sp.done();
        self.select(msgs, &s)
    }

    fn aggregate_with_distances(
        &self,
        msgs: &[Vec<f32>],
        pd: &PairwiseDistances,
    ) -> Vec<f32> {
        check_family(msgs);
        assert_eq!(pd.n(), msgs.len(), "distance matrix / family size mismatch");
        let obs = self.obs_handle();
        let sp = obs.span("kernel/krum_score");
        let s = scores_from(pd, self.f);
        sp.done();
        self.select(msgs, &s)
    }

    fn wants_distances(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("multi-krum(f={})", self.f)
    }

    fn set_obs(&self, obs: &Obs) {
        if let Ok(mut g) = self.obs.lock() {
            *g = obs.clone();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn family_with_outliers(seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut msgs: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..4).map(|_| rng.normal(1.0, 0.1) as f32).collect())
            .collect();
        msgs.push(vec![500.0; 4]);
        msgs.push(vec![-500.0; 4]);
        msgs
    }

    #[test]
    fn krum_picks_a_central_honest_message() {
        let msgs = family_with_outliers(1);
        let out = Krum::new(2).aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 0.5);
    }

    #[test]
    fn multikrum_averages_honest_cluster() {
        let msgs = family_with_outliers(2);
        let out = MultiKrum::new(2).aggregate(&msgs);
        for x in &out {
            assert!((x - 1.0).abs() < 0.3, "{x}");
        }
    }

    #[test]
    fn krum_returns_member_of_input() {
        let msgs = family_with_outliers(3);
        let out = Krum::new(2).aggregate(&msgs);
        assert!(msgs.iter().any(|m| m == &out));
    }

    #[test]
    fn degenerate_small_family() {
        let msgs = vec![vec![1.0], vec![2.0]];
        // f too large relative to n must still produce a sane answer
        let out = Krum::new(5).aggregate(&msgs);
        assert!(out[0] == 1.0 || out[0] == 2.0);
    }

    #[test]
    fn aggregate_with_distances_matches_recompute() {
        let msgs = family_with_outliers(6);
        let pd = PairwiseDistances::compute(&msgs, &Pool::serial());
        let k = Krum::new(2);
        assert!(k.wants_distances());
        assert_eq!(k.aggregate(&msgs), k.aggregate_with_distances(&msgs, &pd));
        let mk = MultiKrum::new(2);
        assert!(mk.wants_distances());
        assert_eq!(mk.aggregate(&msgs), mk.aggregate_with_distances(&msgs, &pd));
    }

    #[test]
    fn pooled_scores_are_bit_identical_to_serial() {
        // sized to clear the tile gate (n ≥ 32, n²·q ≥ 2¹⁶)
        let mut rng = Rng::new(4);
        let msgs: Vec<Vec<f32>> = (0..40).map(|_| rng.gauss_vec(64)).collect();
        let serial = scores(&msgs, 8, &Pool::serial());
        for pool in [Pool::new(2), Pool::new(8), Pool::scoped(Parallelism::new(3))] {
            let par = scores(&msgs, 8, &pool);
            assert_eq!(serial, par, "{pool:?}");
        }
        let pool = Pool::new(8);
        let a = Krum::new(8).aggregate(&msgs);
        let b = Krum::new(8).with_pool(&pool).aggregate(&msgs);
        assert_eq!(a, b);
        let a = MultiKrum::new(8).aggregate(&msgs);
        let b = MultiKrum::new(8).with_parallelism(Parallelism::new(8)).aggregate(&msgs);
        assert_eq!(a, b);
    }
}
