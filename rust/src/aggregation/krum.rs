//! (Multi-)Krum (Blanchard et al., NeurIPS'17 [3]).
//!
//! score(i) = Σ of the n−f−2 smallest squared distances from xᵢ to the other
//! messages; Krum returns the argmin message, Multi-Krum averages the
//! m = n − f best-scored messages.

use super::{check_family, par_gate, Aggregator};
use crate::util::math::mean_of;
use crate::util::parallel::{par_map, Parallelism};

fn scores(msgs: &[Vec<f32>], f: usize, par: Parallelism) -> Vec<f64> {
    let n = msgs.len();
    // number of neighbors summed per Krum: n - f - 2, floored at 1
    let m = n.saturating_sub(f + 2).max(1);
    let norms: Vec<f64> = msgs.iter().map(|v| crate::util::math::norm_sq(v)).collect();
    let q = msgs.first().map(|v| v.len()).unwrap_or(0);
    if !par.is_serial() && par_gate(n, q) {
        // Row-parallel: each score only needs row i's distances, so no
        // shared matrix at all. Each d(i,j) is computed twice (once per
        // row), but the rows split across T threads — wall-clock beats the
        // halved serial pass for T ≥ 2. Bit-identical to the serial path:
        // f64 +/× are commutative and both paths evaluate
        // norms[i]+norms[j]−2·dot(i,j) with the same accumulation order.
        return par_map(par, msgs, |i, mi| {
            let mut dists: Vec<f64> = Vec::with_capacity(n - 1);
            for (j, mj) in msgs.iter().enumerate() {
                if j == i {
                    continue;
                }
                dists.push(
                    (norms[i] + norms[j] - 2.0 * crate::util::math::dot(mi, mj) as f64)
                        .max(0.0),
                );
            }
            let k = m.min(dists.len());
            if k < dists.len() {
                dists.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
            }
            dists[..k].iter().sum()
        });
    }
    // Serial perf: symmetric pairwise distances via the Gram expansion with
    // cached norms — halves the dominant dot-product count
    // (EXPERIMENTS.md §Perf).
    let mut dist = vec![0.0f64; n * n];
    for i in 0..n {
        for j in i + 1..n {
            let dij = (norms[i] + norms[j]
                - 2.0 * crate::util::math::dot(&msgs[i], &msgs[j]) as f64)
                .max(0.0);
            dist[i * n + j] = dij;
            dist[j * n + i] = dij;
        }
    }
    let mut out = Vec::with_capacity(n);
    let mut dists: Vec<f64> = Vec::with_capacity(n - 1);
    for i in 0..n {
        dists.clear();
        dists.extend((0..n).filter(|&j| j != i).map(|j| dist[i * n + j]));
        let k = m.min(dists.len());
        if k < dists.len() {
            dists.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
        }
        out.push(dists[..k].iter().sum());
    }
    out
}

/// Classic Krum: select the single most central message.
#[derive(Debug, Clone, Copy)]
pub struct Krum {
    f: usize,
    par: Parallelism,
}

impl Krum {
    pub fn new(f: usize) -> Self {
        Krum { f, par: Parallelism::serial() }
    }

    /// Enable the row-parallel O(N²Q) distance pass.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }
}

impl Aggregator for Krum {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        check_family(msgs);
        let s = scores(msgs, self.f, self.par);
        let best = s
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        msgs[best].clone()
    }

    fn name(&self) -> String {
        format!("krum(f={})", self.f)
    }
}

/// Multi-Krum: average the n−f best-scored messages.
#[derive(Debug, Clone, Copy)]
pub struct MultiKrum {
    f: usize,
    par: Parallelism,
}

impl MultiKrum {
    pub fn new(f: usize) -> Self {
        MultiKrum { f, par: Parallelism::serial() }
    }

    /// Enable the row-parallel O(N²Q) distance pass.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }
}

impl Aggregator for MultiKrum {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        check_family(msgs);
        let n = msgs.len();
        let keep = n.saturating_sub(self.f).max(1);
        let s = scores(msgs, self.f, self.par);
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| s[a].partial_cmp(&s[b]).unwrap());
        let selected: Vec<&[f32]> =
            idx[..keep].iter().map(|&i| msgs[i].as_slice()).collect();
        mean_of(&selected)
    }

    fn name(&self) -> String {
        format!("multi-krum(f={})", self.f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn family_with_outliers(seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut msgs: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..4).map(|_| rng.normal(1.0, 0.1) as f32).collect())
            .collect();
        msgs.push(vec![500.0; 4]);
        msgs.push(vec![-500.0; 4]);
        msgs
    }

    #[test]
    fn krum_picks_a_central_honest_message() {
        let msgs = family_with_outliers(1);
        let out = Krum::new(2).aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 0.5);
    }

    #[test]
    fn multikrum_averages_honest_cluster() {
        let msgs = family_with_outliers(2);
        let out = MultiKrum::new(2).aggregate(&msgs);
        for x in &out {
            assert!((x - 1.0).abs() < 0.3, "{x}");
        }
    }

    #[test]
    fn krum_returns_member_of_input() {
        let msgs = family_with_outliers(3);
        let out = Krum::new(2).aggregate(&msgs);
        assert!(msgs.iter().any(|m| m == &out));
    }

    #[test]
    fn degenerate_small_family() {
        let msgs = vec![vec![1.0], vec![2.0]];
        // f too large relative to n must still produce a sane answer
        let out = Krum::new(5).aggregate(&msgs);
        assert!(out[0] == 1.0 || out[0] == 2.0);
    }

    #[test]
    fn parallel_scores_are_bit_identical_to_serial() {
        // sized to clear the par gate (n²·q ≥ 2¹⁶)
        let mut rng = Rng::new(4);
        let msgs: Vec<Vec<f32>> = (0..40).map(|_| rng.gauss_vec(64)).collect();
        let serial = scores(&msgs, 8, Parallelism::serial());
        for threads in [2usize, 3, 8] {
            let par = scores(&msgs, 8, Parallelism::new(threads));
            assert_eq!(serial, par, "threads={threads}");
        }
        let a = Krum::new(8).aggregate(&msgs);
        let b = Krum::new(8).with_parallelism(Parallelism::new(8)).aggregate(&msgs);
        assert_eq!(a, b);
        let a = MultiKrum::new(8).aggregate(&msgs);
        let b = MultiKrum::new(8).with_parallelism(Parallelism::new(8)).aggregate(&msgs);
        assert_eq!(a, b);
    }
}
