//! Coordinate-wise median (Yin et al. [7], Xie et al. [4]).

use super::{check_family, Aggregator};

/// Per-coordinate median via linear-time selection.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinateMedian;

impl Aggregator for CoordinateMedian {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let n = msgs.len();
        let mut out = vec![0.0f32; q];
        let mut col: Vec<f32> = vec![0.0; n];
        for j in 0..q {
            for (i, m) in msgs.iter().enumerate() {
                col[i] = m[j];
            }
            let mid = n / 2;
            let (_, pivot, _) =
                col.select_nth_unstable_by(mid, |a, b| a.partial_cmp(b).unwrap());
            let hi = *pivot;
            out[j] = if n % 2 == 1 {
                hi
            } else {
                // even: average the two central order statistics
                let lo = col[..mid]
                    .iter()
                    .cloned()
                    .fold(f32::NEG_INFINITY, f32::max);
                (lo + hi) / 2.0
            };
        }
        out
    }

    fn name(&self) -> String {
        "cwmed".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn odd_median() {
        let out =
            CoordinateMedian.aggregate(&[vec![1.0], vec![9.0], vec![2.0]]);
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn even_median_averages() {
        let out = CoordinateMedian
            .aggregate(&[vec![1.0], vec![2.0], vec![4.0], vec![100.0]]);
        assert_eq!(out, vec![3.0]);
    }

    #[test]
    fn immune_to_minority_outliers() {
        let mut msgs = vec![vec![5.0f32; 4]; 7];
        msgs.push(vec![1e9; 4]);
        msgs.push(vec![-1e9; 4]);
        let out = CoordinateMedian.aggregate(&msgs);
        assert_eq!(out, vec![5.0; 4]);
    }

    #[test]
    fn per_coordinate_independence() {
        let out = CoordinateMedian.aggregate(&[
            vec![1.0, 30.0],
            vec![2.0, 10.0],
            vec![3.0, 20.0],
        ]);
        assert_eq!(out, vec![2.0, 20.0]);
    }
}
