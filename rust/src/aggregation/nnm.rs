//! Nearest-Neighbor Mixing pre-aggregation (Allouah et al., AISTATS'23
//! [23]): replace each message xᵢ by the mean of its n−f nearest neighbors
//! (including itself), then apply any base rule. NNM provably upgrades any
//! (f, κ)-robust rule to optimal robustness under heterogeneity.
//!
//! Hot-path note: the O(n²Q) distance pass reads the shared
//! [`PairwiseDistances`] kernel — one tiled triangular Gram pass, each
//! d(i,j) computed exactly once into packed-triangular storage, consumed
//! per row through the `RowView` adapter. The per-row selection + averaging
//! (O(nQ) per row) is parallelized over the pool on top of the shared
//! matrix; both stages are bit-identical to serial by construction.
//!
//! Degenerate-mixing fast path: when `keep == n` (f = 0) every row keeps
//! all n messages, so the mixed family is n copies of the global mean — an
//! affine image of the input that needs no distances at all. [`Nnm::mix`]
//! detects this and skips the O(n²Q) `PairwiseDistances` pass entirely,
//! producing the same bits the generic path would (same axpy order), which
//! makes `f = 0` reference runs as cheap as their non-NNM counterparts.
//!
//! Gram reuse for distance-hungry inner rules: when `f > 0` and the inner
//! rule reports [`Aggregator::wants_distances`] (Krum, Multi-Krum), the
//! mixed family's distance matrix is derived from the one the mixing pass
//! already computed via [`PairwiseDistances::mixed`] (W·G·Wᵀ on the
//! recovered Gram matrix, O(n²·keep) flops with no Q factor) and handed to
//! [`Aggregator::aggregate_with_distances`] — the inner rule's second
//! O(n²Q) pass over the Q-dim mixed vectors disappears. The derived
//! entries are float-different from a fresh pass (clamped Gram recovery),
//! so Krum-under-NNM selections can shift by design; the path itself is
//! deterministic and bit-identical across pool widths.

use std::sync::{Arc, Mutex};

use super::gram::PairwiseDistances;
use super::{check_family, par_gate, Aggregator};
use crate::obs::Obs;
use crate::util::math::{axpy, scale};
use crate::util::parallel::{Parallelism, Pool};

pub struct Nnm {
    f: usize,
    inner: Box<dyn Aggregator>,
    pool: Pool,
    obs: Arc<Mutex<Obs>>,
}

impl Nnm {
    pub fn new(f: usize, inner: Box<dyn Aggregator>) -> Self {
        Nnm { f, inner, pool: Pool::serial(), obs: Arc::default() }
    }

    fn obs_handle(&self) -> Obs {
        self.obs.lock().map(|o| o.clone()).unwrap_or_default()
    }

    /// Share a worker pool for the tiled distance pass and the row mixing.
    pub fn with_pool(mut self, pool: &Pool) -> Self {
        self.pool = pool.clone();
        self
    }

    /// Scoped-spawn parallelism (no persistent workers) — the pre-pool API.
    pub fn with_parallelism(self, par: Parallelism) -> Self {
        let pool = Pool::scoped(par);
        self.with_pool(&pool)
    }

    /// The mixing step alone (exposed for tests and ablation).
    pub fn mix(&self, msgs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let q = check_family(msgs);
        let n = msgs.len();
        let keep = n.saturating_sub(self.f).max(1);
        if keep == n {
            return self.mix_degenerate(msgs, q, n, keep);
        }
        self.mix_general(msgs, q, n, keep).0
    }

    /// Degenerate mixing (f = 0): every row keeps all n neighbors, so each
    /// mixed row is the same global mean. Computing it once with the exact
    /// axpy order the generic row loop uses keeps the result bit-identical
    /// while skipping the O(n²Q) distance pass.
    fn mix_degenerate(&self, msgs: &[Vec<f32>], q: usize, n: usize, keep: usize) -> Vec<Vec<f32>> {
        let mut y = vec![0.0f32; q];
        for m in msgs {
            axpy(1.0, m, &mut y);
        }
        scale(&mut y, 1.0 / keep as f32);
        vec![y; n]
    }

    /// Generic mixing: one distance pass, per-row neighbor selection +
    /// averaging. Returns the base-family distance matrix and each row's
    /// kept-neighbor index set (ascending) alongside the mixed messages, so
    /// [`Nnm::aggregate`] can hand distance-hungry inner rules a
    /// [`PairwiseDistances::mixed`] matrix instead of paying a second
    /// O(n²Q) pass over the mixed vectors.
    fn mix_general(
        &self,
        msgs: &[Vec<f32>],
        q: usize,
        n: usize,
        keep: usize,
    ) -> (Vec<Vec<f32>>, PairwiseDistances, Vec<Vec<usize>>) {
        let obs = self.obs_handle();
        let pd = PairwiseDistances::compute_spanned(msgs, &self.pool, &obs);
        let sp_mix = obs.span("kernel/nnm_mix");
        let mix_row = |i: usize| -> (Vec<f32>, Vec<usize>) {
            // the diagonal entry d(i,i) = 0 keeps xᵢ among its own neighbors
            let mut d: Vec<(f64, usize)> = pd.row(i).iter().zip(0..n).collect();
            if keep < n {
                d.select_nth_unstable_by(keep - 1, |a, b| a.0.total_cmp(&b.0));
            }
            let mut y = vec![0.0f32; q];
            for &(_, j) in &d[..keep] {
                axpy(1.0, &msgs[j], &mut y);
            }
            scale(&mut y, 1.0 / keep as f32);
            let mut set: Vec<usize> = d[..keep].iter().map(|&(_, j)| j).collect();
            set.sort_unstable();
            (y, set)
        };
        let rows: Vec<(Vec<f32>, Vec<usize>)> = if !self.pool.is_serial() && par_gate(n, q) {
            let idx: Vec<usize> = (0..n).collect();
            self.pool.par_map(&idx, |_, &i| mix_row(i))
        } else {
            (0..n).map(mix_row).collect()
        };
        let (mixed, sets) = rows.into_iter().unzip();
        sp_mix.done();
        (mixed, pd, sets)
    }
}

impl Aggregator for Nnm {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let n = msgs.len();
        let keep = n.saturating_sub(self.f).max(1);
        if keep == n {
            // f = 0: the mixed family is n identical means — distances are
            // all zero, so there is nothing for an inner rule to reuse
            return self.inner.aggregate(&self.mix_degenerate(msgs, q, n, keep));
        }
        let (mixed, pd, sets) = self.mix_general(msgs, q, n, keep);
        if self.inner.wants_distances() {
            // Gram reuse: derive the mixed family's distances from the
            // matrix the mixing pass already computed (W·G·Wᵀ) instead of
            // letting the inner rule run a second O(n²Q) pass
            let mixed_pd = pd.mixed(&sets, &self.pool);
            self.inner.aggregate_with_distances(&mixed, &mixed_pd)
        } else {
            self.inner.aggregate(&mixed)
        }
    }

    fn name(&self) -> String {
        format!("{}-nnm", self.inner.name())
    }

    // NNM itself is stateless — only the wrapped rule may carry momentum,
    // so checkpoint state flows straight through to it.
    fn state_snapshot(&self) -> Option<Vec<Vec<f32>>> {
        self.inner.state_snapshot()
    }

    fn state_restore(&self, bufs: Vec<Vec<f32>>) {
        self.inner.state_restore(bufs);
    }

    // Store the handle for the mixing kernels AND forward it, so a
    // wrapped (Multi-)Krum / geometric median times its own kernels too.
    fn set_obs(&self, obs: &Obs) {
        if let Ok(mut g) = self.obs.lock() {
            *g = obs.clone();
        }
        self.inner.set_obs(obs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::{Cwtm, Mean};
    use crate::util::rng::Rng;

    #[test]
    fn mixing_preserves_identical_points() {
        let nnm = Nnm::new(2, Box::new(Mean));
        let msgs = vec![vec![1.0f32, 2.0]; 6];
        let mixed = nnm.mix(&msgs);
        for m in mixed {
            assert_eq!(m, vec![1.0, 2.0]);
        }
    }

    #[test]
    fn mixing_shrinks_honest_spread() {
        let mut rng = Rng::new(1);
        let msgs: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..10).map(|_| rng.normal(0.0, 1.0) as f32).collect())
            .collect();
        let nnm = Nnm::new(0, Box::new(Mean));
        let mixed = nnm.mix(&msgs);
        // variance around the mean must not grow (mixing is an averaging op)
        let var = |fam: &[Vec<f32>]| -> f64 {
            let mu = crate::util::math::mean_of(
                &fam.iter().map(|v| v.as_slice()).collect::<Vec<_>>(),
            );
            fam.iter().map(|m| crate::util::math::dist_sq(m, &mu)).sum::<f64>()
                / fam.len() as f64
        };
        assert!(var(&mixed) <= var(&msgs) + 1e-9);
    }

    #[test]
    fn nnm_cwtm_resists_sign_flip_better_than_cwtm_under_heterogeneity() {
        // heterogeneous honest messages + coordinated sign-flip attackers
        let mut rng = Rng::new(2);
        let h = 16;
        let f = 4;
        let honest: Vec<Vec<f32>> = (0..h)
            .map(|i| {
                (0..8)
                    .map(|_| rng.normal(1.0 + 0.3 * i as f64, 0.5) as f32)
                    .collect()
            })
            .collect();
        let true_mean = crate::util::math::mean_of(
            &honest.iter().map(|v| v.as_slice()).collect::<Vec<_>>(),
        );
        let mut msgs = honest.clone();
        for _ in 0..f {
            msgs.push(true_mean.iter().map(|x| -2.0 * x).collect());
        }
        let plain = Cwtm::new(0.2).aggregate(&msgs);
        let mixed = Nnm::new(f, Box::new(Cwtm::new(0.2))).aggregate(&msgs);
        let err_plain = crate::util::math::dist_sq(&plain, &true_mean);
        let err_mixed = crate::util::math::dist_sq(&mixed, &true_mean);
        assert!(
            err_mixed <= err_plain * 1.5,
            "nnm {err_mixed} should not be much worse than plain {err_plain}"
        );
    }

    #[test]
    fn degenerate_keep_all_fast_path_matches_generic_mean() {
        let mut rng = Rng::new(9);
        let msgs: Vec<Vec<f32>> = (0..10).map(|_| rng.gauss_vec(33)).collect();
        let mixed = Nnm::new(0, Box::new(Mean)).mix(&msgs);
        // the generic row loop would sum all n messages in index order and
        // scale by 1/n — the fast path must reproduce those exact bits
        let mut want = vec![0.0f32; 33];
        for m in &msgs {
            axpy(1.0, m, &mut want);
        }
        scale(&mut want, 1.0 / 10.0);
        for row in &mixed {
            assert_eq!(row, &want);
        }
        // pooled calls take the same fast path (no distance dispatch at all)
        let pool = Pool::new(4);
        assert_eq!(Nnm::new(0, Box::new(Mean)).with_pool(&pool).mix(&msgs), mixed);
    }

    #[test]
    fn pooled_mix_is_bit_identical_to_serial() {
        let mut rng = Rng::new(5);
        let msgs: Vec<Vec<f32>> = (0..40).map(|_| rng.gauss_vec(64)).collect();
        let serial = Nnm::new(6, Box::new(Mean)).mix(&msgs);
        for pool in [Pool::new(2), Pool::new(8), Pool::scoped(Parallelism::new(8))] {
            let par = Nnm::new(6, Box::new(Mean)).with_pool(&pool).mix(&msgs);
            assert_eq!(serial, par, "{pool:?}");
        }
    }

    #[test]
    fn name_reflects_wrapping() {
        let nnm = Nnm::new(1, Box::new(Cwtm::new(0.1)));
        assert_eq!(nnm.name(), "cwtm(0.1)-nnm");
    }

    #[test]
    fn gram_reuse_krum_inner_still_lands_in_honest_cluster() {
        // honest messages near 1.0 plus far outliers: the reused (Gram-
        // derived) distances must still steer inner Krum to an honest mix
        let mut rng = Rng::new(11);
        let mut msgs: Vec<Vec<f32>> = (0..12)
            .map(|_| (0..6).map(|_| rng.normal(1.0, 0.1) as f32).collect())
            .collect();
        msgs.push(vec![300.0; 6]);
        msgs.push(vec![-300.0; 6]);
        let out = Nnm::new(2, Box::new(crate::aggregation::Krum::new(2))).aggregate(&msgs);
        for x in &out {
            assert!((x - 1.0).abs() < 0.5, "{x}");
        }
    }

    #[test]
    fn gram_reuse_path_is_bit_identical_across_pools() {
        // sized past par_gate so the pooled runs exercise the parallel
        // mixing, the tiled base Gram pass AND the parallel mixed() fill
        let mut rng = Rng::new(12);
        let msgs: Vec<Vec<f32>> = (0..40).map(|_| rng.gauss_vec(64)).collect();
        let f = 6;
        let serial = Nnm::new(f, Box::new(crate::aggregation::Krum::new(f))).aggregate(&msgs);
        for pool in [Pool::new(2), Pool::new(8), Pool::scoped(Parallelism::new(3))] {
            let inner = crate::aggregation::Krum::new(f).with_pool(&pool);
            let par = Nnm::new(f, Box::new(inner)).with_pool(&pool).aggregate(&msgs);
            assert_eq!(serial, par, "{pool:?}");
        }
    }
}
