//! Nearest-Neighbor Mixing pre-aggregation (Allouah et al., AISTATS'23
//! [23]): replace each message xᵢ by the mean of its n−f nearest neighbors
//! (including itself), then apply any base rule. NNM provably upgrades any
//! (f, κ)-robust rule to optimal robustness under heterogeneity.
//!
//! Hot-path note: the O(n²) distance pass dominates at N=100, Q=100; we
//! compute squared distances via the Gram expansion ‖a−b‖² = ‖a‖²+‖b‖²−2a·b
//! with cached norms, then select the n−f nearest with a partial sort.

use super::{check_family, par_gate, Aggregator};
use crate::util::math::{axpy, dot, norm_sq, scale};
use crate::util::parallel::{par_map, Parallelism};

pub struct Nnm {
    f: usize,
    inner: Box<dyn Aggregator>,
    par: Parallelism,
}

impl Nnm {
    pub fn new(f: usize, inner: Box<dyn Aggregator>) -> Self {
        Nnm { f, inner, par: Parallelism::serial() }
    }

    /// Enable the row-parallel O(N²Q) mixing pass.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// The mixing step alone (exposed for tests and ablation).
    ///
    /// Perf: serially, the O(n²) distance matrix is computed once,
    /// symmetrically (d(i,j) = d(j,i)), via the Gram expansion with cached
    /// norms — halving the dominant dot-product count (EXPERIMENTS.md
    /// §Perf). With `threads > 1` each mixed row is produced independently
    /// (its own distances, selection and average), which re-computes each
    /// d(i,j) once per side but splits rows across threads — a wall-clock
    /// win from 2 threads up, with bit-identical output (commutative f64
    /// +/× and identical per-row evaluation order).
    pub fn mix(&self, msgs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let q = check_family(msgs);
        let n = msgs.len();
        let keep = n.saturating_sub(self.f).max(1);
        let norms: Vec<f64> = msgs.iter().map(|m| norm_sq(m)).collect();
        if !self.par.is_serial() && par_gate(n, q) {
            return par_map(self.par, msgs, |i, mi| {
                let mut d: Vec<(f64, usize)> = Vec::with_capacity(n);
                for (j, mj) in msgs.iter().enumerate() {
                    let dij = if j == i {
                        0.0
                    } else {
                        (norms[i] + norms[j] - 2.0 * dot(mi, mj) as f64).max(0.0)
                    };
                    d.push((dij, j));
                }
                if keep < n {
                    d.select_nth_unstable_by(keep - 1, |a, b| a.0.total_cmp(&b.0));
                }
                let mut y = vec![0.0f32; q];
                for &(_, j) in &d[..keep] {
                    axpy(1.0, &msgs[j], &mut y);
                }
                scale(&mut y, 1.0 / keep as f32);
                y
            });
        }
        // symmetric distance matrix, upper triangle computed once
        let mut dist = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i + 1..n {
                let dij = (norms[i] + norms[j]
                    - 2.0 * dot(&msgs[i], &msgs[j]) as f64)
                    .max(0.0);
                dist[i * n + j] = dij;
                dist[j * n + i] = dij;
            }
        }
        let mut mixed = Vec::with_capacity(n);
        let mut d: Vec<(f64, usize)> = Vec::with_capacity(n);
        for i in 0..n {
            d.clear();
            d.extend(dist[i * n..(i + 1) * n].iter().copied().zip(0..n));
            if keep < n {
                d.select_nth_unstable_by(keep - 1, |a, b| a.0.total_cmp(&b.0));
            }
            let mut y = vec![0.0f32; q];
            for &(_, j) in &d[..keep] {
                axpy(1.0, &msgs[j], &mut y);
            }
            scale(&mut y, 1.0 / keep as f32);
            mixed.push(y);
        }
        mixed
    }
}

impl Aggregator for Nnm {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let mixed = self.mix(msgs);
        self.inner.aggregate(&mixed)
    }

    fn name(&self) -> String {
        format!("{}-nnm", self.inner.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::{Cwtm, Mean};
    use crate::util::rng::Rng;

    #[test]
    fn mixing_preserves_identical_points() {
        let nnm = Nnm::new(2, Box::new(Mean));
        let msgs = vec![vec![1.0f32, 2.0]; 6];
        let mixed = nnm.mix(&msgs);
        for m in mixed {
            assert_eq!(m, vec![1.0, 2.0]);
        }
    }

    #[test]
    fn mixing_shrinks_honest_spread() {
        let mut rng = Rng::new(1);
        let msgs: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..10).map(|_| rng.normal(0.0, 1.0) as f32).collect())
            .collect();
        let nnm = Nnm::new(0, Box::new(Mean));
        let mixed = nnm.mix(&msgs);
        // variance around the mean must not grow (mixing is an averaging op)
        let var = |fam: &[Vec<f32>]| -> f64 {
            let mu = crate::util::math::mean_of(
                &fam.iter().map(|v| v.as_slice()).collect::<Vec<_>>(),
            );
            fam.iter().map(|m| crate::util::math::dist_sq(m, &mu)).sum::<f64>()
                / fam.len() as f64
        };
        assert!(var(&mixed) <= var(&msgs) + 1e-9);
    }

    #[test]
    fn nnm_cwtm_resists_sign_flip_better_than_cwtm_under_heterogeneity() {
        // heterogeneous honest messages + coordinated sign-flip attackers
        let mut rng = Rng::new(2);
        let h = 16;
        let f = 4;
        let honest: Vec<Vec<f32>> = (0..h)
            .map(|i| {
                (0..8)
                    .map(|_| rng.normal(1.0 + 0.3 * i as f64, 0.5) as f32)
                    .collect()
            })
            .collect();
        let true_mean = crate::util::math::mean_of(
            &honest.iter().map(|v| v.as_slice()).collect::<Vec<_>>(),
        );
        let mut msgs = honest.clone();
        for _ in 0..f {
            msgs.push(true_mean.iter().map(|x| -2.0 * x).collect());
        }
        let plain = Cwtm::new(0.2).aggregate(&msgs);
        let mixed = Nnm::new(f, Box::new(Cwtm::new(0.2))).aggregate(&msgs);
        let err_plain = crate::util::math::dist_sq(&plain, &true_mean);
        let err_mixed = crate::util::math::dist_sq(&mixed, &true_mean);
        assert!(
            err_mixed <= err_plain * 1.5,
            "nnm {err_mixed} should not be much worse than plain {err_plain}"
        );
    }

    #[test]
    fn parallel_mix_is_bit_identical_to_serial() {
        let mut rng = Rng::new(5);
        let msgs: Vec<Vec<f32>> = (0..40).map(|_| rng.gauss_vec(64)).collect();
        let serial = Nnm::new(6, Box::new(Mean)).mix(&msgs);
        for threads in [2usize, 8] {
            let par = Nnm::new(6, Box::new(Mean))
                .with_parallelism(Parallelism::new(threads))
                .mix(&msgs);
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn name_reflects_wrapping() {
        let nnm = Nnm::new(1, Box::new(Cwtm::new(0.1)));
        assert_eq!(nnm.name(), "cwtm(0.1)-nnm");
    }
}
