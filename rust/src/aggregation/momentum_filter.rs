//! Server-side momentum filtering (Compressed Momentum Filtering,
//! arXiv 2409.08640).
//!
//! The server keeps one momentum buffer per device and folds each round's
//! received message into it before aggregating:
//!
//! ```text
//! mᵢᵗ = (1 − α)·mᵢᵗ⁻¹ + α·xᵢᵗ      (first observation: mᵢ = xᵢ)
//! out = mean{ mᵢ : i in the N − f momenta closest to cw-median(m) }
//! ```
//!
//! Momentum smoothing shrinks the honest variance the filter has to
//! tolerate (the same quantity κ multiplies in Definition 1), which is the
//! core of the CMF argument; the filter itself is a distance test against
//! the coordinate-wise median of the momenta, keeping the N − f closest
//! and averaging them in device-index order.
//!
//! Determinism and semantics contract:
//!
//! * The first call on fresh buffers initializes mᵢ = xᵢ, so a single
//!   call is exactly the *filtered mean* — translation-equivariant, and
//!   with f = 0 bitwise equal to [`super::Mean`] (same axpy-then-scale
//!   summation in index order).
//! * Momentum is tied to device slots. If the family size or dimension
//!   changes between calls (a retired device under the net leader's
//!   partial-participation path), all buffers reset — mirroring the EF
//!   residual-reset rule in [`crate::compress::ef`]: membership changes
//!   never replay stale per-device memory.
//! * All state lives behind a `Mutex` (the [`Aggregator`] trait is
//!   `&self`); calls are serialized, and the training loop is the only
//!   caller, so traces stay bit-identical across thread counts and
//!   kernel tiers.

use super::{check_family, Aggregator, CoordinateMedian};
use crate::util::math::{axpy, dist_sq, scale};
use std::sync::Mutex;

/// Default momentum weight on the incoming message (m ← (1−α)m + αx).
/// Hard-coded rather than configurable so the sweep engine's canonical
/// job strings stay stable — `momentum-filter` is a parameter-free rule
/// axis value.
pub const DEFAULT_ALPHA: f32 = 0.9;

/// Per-device momentum buffers + median-distance filter (see module docs).
pub struct MomentumFilter {
    f: usize,
    alpha: f32,
    buffers: Mutex<Vec<Vec<f32>>>,
}

impl MomentumFilter {
    /// `f` = assumed Byzantine count (the filter discards the `f` momenta
    /// farthest from the coordinate-wise median); `alpha` ∈ (0, 1] is the
    /// weight on the incoming message.
    pub fn new(f: usize, alpha: f32) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "momentum weight must be in (0, 1]");
        MomentumFilter { f, alpha, buffers: Mutex::new(Vec::new()) }
    }

    /// Drop all momentum buffers; the next call re-initializes mᵢ = xᵢ.
    pub fn reset(&self) {
        self.buffers.lock().unwrap().clear();
    }
}

impl Aggregator for MomentumFilter {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let n = msgs.len();
        let mut buf = self.buffers.lock().unwrap();
        if buf.len() != n || buf.iter().any(|m| m.len() != q) {
            buf.clear();
        }
        if buf.is_empty() {
            *buf = msgs.to_vec();
        } else {
            for (m, x) in buf.iter_mut().zip(msgs) {
                for j in 0..q {
                    m[j] = (1.0 - self.alpha) * m[j] + self.alpha * x[j];
                }
            }
        }
        // score momenta by distance to their coordinate-wise median, keep
        // the N − f closest (ties broken by device index), average the
        // kept momenta in index order
        let center = CoordinateMedian.aggregate(&buf);
        let mut scored: Vec<(f64, usize)> =
            buf.iter().enumerate().map(|(i, m)| (dist_sq(m, &center), i)).collect();
        scored.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let keep = n - self.f.min(n - 1);
        let mut kept: Vec<usize> = scored[..keep].iter().map(|&(_, i)| i).collect();
        kept.sort_unstable();
        let mut out = vec![0.0f32; q];
        for &i in &kept {
            axpy(1.0, &buf[i], &mut out);
        }
        scale(&mut out, 1.0 / keep as f32);
        out
    }

    fn name(&self) -> String {
        "momentum-filter".into()
    }

    /// The per-device momentum buffers, cloned — empty (`None`) before the
    /// first aggregate call, so a checkpoint cut at iteration 0 carries no
    /// spurious momentum section.
    fn state_snapshot(&self) -> Option<Vec<Vec<f32>>> {
        let buf = self.buffers.lock().unwrap();
        (!buf.is_empty()).then(|| buf.clone())
    }

    fn state_restore(&self, bufs: Vec<Vec<f32>>) {
        *self.buffers.lock().unwrap() = bufs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::Mean;

    #[test]
    fn fresh_f0_call_is_bitwise_mean() {
        let msgs: Vec<Vec<f32>> =
            (0..7).map(|i| (0..5).map(|j| (i * 5 + j) as f32 * 0.3 - 2.0).collect()).collect();
        let mf = MomentumFilter::new(0, DEFAULT_ALPHA);
        assert_eq!(mf.aggregate(&msgs), Mean.aggregate(&msgs));
    }

    #[test]
    fn filter_discards_the_far_momentum() {
        let mut msgs = vec![vec![1.0f32, 2.0]; 9];
        msgs.push(vec![1e6, -1e6]);
        let out = MomentumFilter::new(1, DEFAULT_ALPHA).aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 1e-5 && (out[1] - 2.0).abs() < 1e-5, "{out:?}");
    }

    #[test]
    fn momentum_carries_across_calls() {
        let mf = MomentumFilter::new(0, 0.5);
        let a = vec![vec![4.0f32]; 3];
        let b = vec![vec![0.0f32]; 3];
        assert_eq!(mf.aggregate(&a), vec![4.0]);
        // m = 0.5·4 + 0.5·0 = 2
        assert_eq!(mf.aggregate(&b), vec![2.0]);
        // m = 0.5·2 + 0.5·0 = 1
        assert_eq!(mf.aggregate(&b), vec![1.0]);
    }

    #[test]
    fn membership_change_resets_the_buffers() {
        let mf = MomentumFilter::new(0, 0.5);
        let _ = mf.aggregate(&vec![vec![8.0f32]; 4]);
        // family shrank: buffers reset, so this is a fresh filtered mean
        let out = mf.aggregate(&vec![vec![2.0f32]; 3]);
        assert_eq!(out, vec![2.0], "stale momentum leaked across a membership change");
    }

    #[test]
    fn explicit_reset_clears_state() {
        let mf = MomentumFilter::new(0, 0.5);
        let _ = mf.aggregate(&vec![vec![8.0f32]; 2]);
        mf.reset();
        assert_eq!(mf.aggregate(&vec![vec![2.0f32]; 2]), vec![2.0]);
    }

    #[test]
    fn name_matches_the_config_axis_value() {
        assert_eq!(MomentumFilter::new(1, DEFAULT_ALPHA).name(), "momentum-filter");
    }

    #[test]
    fn state_snapshot_restore_resumes_bit_identically() {
        let a = MomentumFilter::new(1, 0.5);
        let step1 = vec![vec![4.0f32, -1.0]; 5];
        let step2 = vec![vec![0.0f32, 3.0]; 5];
        let _ = a.aggregate(&step1);
        let snap = a.state_snapshot().expect("buffers initialized after one call");
        // a fresh instance restored from the snapshot must continue
        // exactly where `a` would
        let b = MomentumFilter::new(1, 0.5);
        assert!(b.state_snapshot().is_none(), "fresh filter has no state");
        b.state_restore(snap);
        let out_a = a.aggregate(&step2);
        let out_b = b.aggregate(&step2);
        assert_eq!(
            out_a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            out_b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
