//! Maximum-correntropy aggregation (Luan et al. [9]).
//!
//! Iteratively-reweighted mean with Gaussian-kernel weights
//! wᵢ = exp(−‖xᵢ − c‖² / (2σ²)); σ² is set adaptively to the mean squared
//! deviation so the kernel bandwidth tracks the honest spread.
//!
//! The per-iteration distance pass runs through the shared
//! [`CenterScratch`] kernel: one reused distance buffer across reweight
//! iterations, numerically stable subtract-first distances (on the
//! runtime-dispatched `dist_sq` kernel tier), pool-parallel over messages
//! when the family is large.

use super::gram::CenterScratch;
use super::{check_family, Aggregator};
use crate::util::parallel::Pool;

#[derive(Debug, Clone)]
pub struct Mcc {
    pub iters: usize,
    /// bandwidth multiplier on the adaptive σ²
    pub sigma_scale: f64,
    pool: Pool,
}

impl Default for Mcc {
    fn default() -> Self {
        Mcc { iters: 10, sigma_scale: 1.0, pool: Pool::serial() }
    }
}

impl Mcc {
    /// Share a worker pool for the per-iteration distance pass.
    pub fn with_pool(mut self, pool: &Pool) -> Self {
        self.pool = pool.clone();
        self
    }
}

impl Aggregator for Mcc {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let n = msgs.len();
        let mut scratch = CenterScratch::new();
        let mut c: Vec<f32> = {
            let mut s = vec![0.0f64; q];
            for m in msgs {
                for j in 0..q {
                    s[j] += m[j] as f64;
                }
            }
            s.iter().map(|&v| (v / n as f64) as f32).collect()
        };
        for _ in 0..self.iters {
            let d2 = scratch.dist_sq_to(msgs, &c, &self.pool);
            let sigma2 =
                (d2.iter().sum::<f64>() / n as f64).max(1e-12) * self.sigma_scale;
            let w: Vec<f64> =
                d2.iter().map(|&d| (-d / (2.0 * sigma2)).exp()).collect();
            let wsum: f64 = w.iter().sum();
            if wsum <= 1e-300 {
                break;
            }
            let mut next = vec![0.0f64; q];
            for (m, &wi) in msgs.iter().zip(&w) {
                for j in 0..q {
                    next[j] += wi * m[j] as f64;
                }
            }
            c = next.iter().map(|&v| (v / wsum) as f32).collect();
        }
        c
    }

    fn name(&self) -> String {
        "mcc".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_points_fixed() {
        let out = Mcc::default().aggregate(&vec![vec![2.0, -3.0]; 6]);
        assert!((out[0] - 2.0).abs() < 1e-5 && (out[1] + 3.0).abs() < 1e-5);
    }

    #[test]
    fn downweights_outliers() {
        let mut rng = Rng::new(1);
        let mut msgs: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..3).map(|_| rng.normal(1.0, 0.05) as f32).collect())
            .collect();
        msgs.push(vec![1000.0; 3]);
        let out = Mcc::default().aggregate(&msgs);
        // plain mean would be ≈ 91.8; correntropy stays near the cluster
        for x in &out {
            assert!((x - 1.0).abs() < 1.0, "{x}");
        }
    }

    #[test]
    fn converges_toward_dominant_cluster() {
        let mut msgs = vec![vec![0.0f32]; 9];
        msgs.push(vec![10.0]);
        let out = Mcc::default().aggregate(&msgs);
        assert!(out[0] < 1.5, "{}", out[0]);
    }

    #[test]
    fn pooled_aggregate_is_bit_identical_to_serial() {
        // sized above the center-distance gate (n·q ≥ 4096)
        let mut rng = Rng::new(2);
        let msgs: Vec<Vec<f32>> = (0..40).map(|_| rng.gauss_vec(128)).collect();
        let serial = Mcc::default().aggregate(&msgs);
        let pool = Pool::new(8);
        let pooled = Mcc::default().with_pool(&pool).aggregate(&msgs);
        assert_eq!(serial, pooled);
    }
}
