//! FABA (Xia et al., IJCAI'19 [5]): iteratively discard the message farthest
//! from the running mean, f times, then average the survivors.

use super::{check_family, Aggregator};
use crate::util::math::dist_sq;

#[derive(Debug, Clone, Copy)]
pub struct Faba {
    f: usize,
}

impl Faba {
    pub fn new(f: usize) -> Self {
        Faba { f }
    }
}

impl Aggregator for Faba {
    fn aggregate(&self, msgs: &[Vec<f32>]) -> Vec<f32> {
        let q = check_family(msgs);
        let n = msgs.len();
        let drop = self.f.min(n - 1);
        let mut alive: Vec<bool> = vec![true; n];
        let mut n_alive = n;
        // running sum for O(1) mean updates after removals
        let mut sum = vec![0.0f64; q];
        for m in msgs {
            for j in 0..q {
                sum[j] += m[j] as f64;
            }
        }
        for _ in 0..drop {
            let mean: Vec<f32> =
                sum.iter().map(|&s| (s / n_alive as f64) as f32).collect();
            let far = (0..n)
                .filter(|&i| alive[i])
                .max_by(|&a, &b| {
                    dist_sq(&msgs[a], &mean)
                        .partial_cmp(&dist_sq(&msgs[b], &mean))
                        .unwrap()
                })
                .unwrap();
            alive[far] = false;
            n_alive -= 1;
            for j in 0..q {
                sum[j] -= msgs[far][j] as f64;
            }
        }
        sum.iter().map(|&s| (s / n_alive as f64) as f32).collect()
    }

    fn name(&self) -> String {
        format!("faba(f={})", self.f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn removes_exactly_f_outliers() {
        let mut msgs = vec![vec![1.0f32]; 8];
        msgs.push(vec![100.0]);
        msgs.push(vec![-100.0]);
        let out = Faba::new(2).aggregate(&msgs);
        assert!((out[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn f_zero_is_mean() {
        let msgs = vec![vec![1.0f32], vec![3.0]];
        assert_eq!(Faba::new(0).aggregate(&msgs), vec![2.0]);
    }

    #[test]
    fn never_removes_all() {
        let msgs = vec![vec![7.0f32], vec![9.0]];
        let out = Faba::new(10).aggregate(&msgs);
        assert!(out[0] == 7.0 || out[0] == 9.0);
    }

    #[test]
    fn asymmetric_outliers_partially_trimmed() {
        let mut msgs = vec![vec![0.0f32]; 6];
        msgs.push(vec![50.0]);
        msgs.push(vec![60.0]);
        // only f=1 removals but two outliers: result biased but bounded
        let out = Faba::new(1).aggregate(&msgs);
        assert!(out[0] < 30.0);
    }
}
