//! Zero-dependency multi-node transport: the leader–worker protocol of
//! Fig. 1 over real connections.
//!
//! The paper's system model is a star topology — a server broadcasts the
//! iterate `x^t` plus cyclic task assignments and gathers coded
//! (optionally compressed) uplinks from `n` devices. This module turns the
//! in-process cluster simulation into an actual multi-node runner while
//! keeping the training semantics (and, with every device live, the exact
//! trace) of the central fast path:
//!
//! * [`wire`] — the versioned little-endian codec: `Join` / `Hello`
//!   (config-digest handshake, optional dataset shipping) /
//!   `Broadcast {x, subsets}` / `Upload {payload}` / `Shutdown`, with a
//!   **variant-specific payload encoding** per compression operator
//!   (dense f32s for Identity, index+value pairs for rand-K/top-K, packed
//!   sign+level bits for QSGD) so the bytes on the wire track the
//!   operators' analytic bit accounting — communication cost is measured,
//!   not just computed.
//! * [`frame`] — length-prefixed framing with a hard payload cap and a
//!   hand-rolled table-based CRC32, so corrupt or truncated frames are
//!   rejected before they become garbage messages.
//! * [`transport`] — the [`Transport`] trait with three implementations:
//!   in-process byte channels (the refactored `server::cluster` path), TCP
//!   and Unix-domain sockets, all carrying identical frames.
//! * [`leader`] / [`worker`] — the two event loops, generic over the
//!   transport, with a configurable gather deadline so a stalled
//!   (crash-Byzantine) worker cannot hang an iteration.
//!
//! # Wire format (version 2)
//!
//! Frame: `u32 LE payload length | u32 LE CRC32(payload) | payload`.
//! Message payloads (first byte = tag; see [`wire`] for field tables):
//!
//! | tag | message     | sent by | purpose                                |
//! |-----|-------------|---------|----------------------------------------|
//! | 1   | `Join`      | worker  | identify device, cross-check config    |
//! | 2   | `Hello`     | leader  | role, compression seed, dataset, and   |
//! |     |             |         | (v2) resume point + current iterate    |
//! | 3   | `Broadcast` | leader  | iterate + resolved subset list + (v2)  |
//! |     |             |         | per-iteration role bit + RNG cursor    |
//! | 4   | `Upload`    | worker  | coded (compressed) message + bit count |
//! |     |             |         | + (v2) post-compression cursor echo    |
//! | 5   | `Shutdown`  | leader  | end of run                             |
//!
//! # Elastic membership (v2)
//!
//! Version 2 makes cluster membership *elastic*. A `Join` arriving mid-run
//! is answered with an extended `Hello` carrying the worker's dataset
//! shard, the current iterate, the resume iteration, and a fresh split
//! compression-stream seed (`reset_stream = true`), so a late device can
//! adopt a retired slot and contribute from the next broadcast — without
//! perturbing the incumbents' RNG streams (no-churn traces stay
//! bit-identical). The same handshake with `reset_stream = false` serves
//! leader failover: a standby leader restarted from a
//! [`crate::server::Checkpoint`] re-admits workers that kept their live
//! compression streams and error-feedback residuals, and the resumed run
//! is bit-identical (trace *and* wire bytes) to one that never crashed.
//! Rotating Byzantine identities ride the `Broadcast` role bit, with the
//! leader handing honest-role devices their compression-stream cursor and
//! adopting the post-compression echo from each `Upload`.
//!
//! # Pipelined broadcast: the shared x-frame splice
//!
//! A `Broadcast` payload factors into two byte ranges:
//!
//! | part   | bytes                                  | varies per device? |
//! |--------|----------------------------------------|--------------------|
//! | prefix | tag, iteration index, `x^t` (Q floats) | no — identical     |
//! | tail   | resolved subset list for this device   | yes                |
//!
//! [`wire::broadcast_prefix`] `‖` [`wire::broadcast_tail`] is byte-for-byte
//! `Msg::Broadcast.encode()`, and [`frame::encode_frame_parts`] produces the
//! same frame as `encode_frame` over the concatenation (the CRC runs across
//! part boundaries). The pipelined leader ([`LeaderOpts::pipeline`], the
//! default) exploits this: the O(Q) prefix is encoded **once per
//! iteration** and each device's frame is assembled by splicing its small
//! assignment tail onto the shared prefix, with tail encoding and socket
//! writes fanned out on the leader's pool.
//!
//! **Staging RNG contract.** The pipelined leader also pre-draws iteration
//! `t+1`'s random assignment and pre-encodes its tails while gathering
//! iteration `t`. The leader RNG therefore observes the fixed order
//! `draw(0), craft(0), draw(1), craft(1), …` regardless of pipelining —
//! staging buffers reorder *work*, never *stream consumption* — so
//! pipelined and phase-serial runs produce bit-identical traces and
//! identical wire bytes. Pinned by `tests/fuzz_determinism.rs`
//! (pipelined-vs-phase-serial lattice) and the shared-frame case in
//! `tests/net_cluster.rs`; measured by `cargo bench --bench bench_e2e`.
//!
//! # Quick start
//!
//! In-process (what `server::cluster::run_cluster` does), or across real
//! processes:
//!
//! ```text
//! # terminal 1 — leader (TCP; use uds:/tmp/lad.sock for a local socket)
//! lad node-leader --listen tcp://127.0.0.1:7700 --devices 8 --honest 6 \
//!     --d 3 --dim 16 --iters 100
//! # terminals 2..9 — one worker per device index
//! lad node-worker --connect tcp://127.0.0.1:7700 --device 0
//! ```

pub mod frame;
pub mod leader;
pub mod transport;
pub mod wire;
pub mod worker;

pub use leader::{Leader, LeaderOpts, RejoinRequest, MISS_RETIRE_STREAK};
pub use transport::{connect, ChannelTransport, NetListener, TcpTransport, Transport};
pub use wire::{config_digest, DatasetBlock, Msg, Payload, WIRE_VERSION};
pub use worker::{run_worker, run_worker_opts, WorkerOpts, WorkerReport};
