//! Length-prefixed framing with CRC32 integrity checking.
//!
//! Every protocol message travels inside one frame:
//!
//! | offset | size | field                                  |
//! |--------|------|----------------------------------------|
//! | 0      | 4    | payload length, u32 little-endian      |
//! | 4      | 4    | CRC32 (IEEE) of the payload, u32 LE    |
//! | 8      | len  | payload (one `wire::Msg` encoding)     |
//!
//! The reader validates the length against a hard cap *before* allocating
//! (a corrupt or hostile length cannot trigger an OOM) and the CRC after
//! reading, so a flipped bit anywhere in the payload is rejected instead of
//! being decoded into a garbage message. The CRC is the standard reflected
//! IEEE 802.3 polynomial (`0xEDB88320`), computed byte-at-a-time from a
//! compile-time table — no external crates, same digest as zlib's `crc32`.

use std::io::Read;

/// Bytes of framing before the payload (length + CRC).
pub const HEADER_LEN: usize = 8;

/// Hard cap on a single frame's payload. Large enough for a broadcast or a
/// dataset block at production sizes, small enough that a corrupted length
/// field cannot ask the receiver to allocate unbounded memory.
pub const MAX_PAYLOAD: usize = 1 << 28;

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 == 1 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = make_table();

/// CRC32 (IEEE, reflected) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    crc32_finish(crc32_update(CRC_INIT, bytes))
}

/// Initial running state for an incremental CRC32 (feed through
/// [`crc32_update`], close with [`crc32_finish`]).
const CRC_INIT: u32 = 0xFFFF_FFFF;

/// Fold `bytes` into a running CRC32 state. Feeding slices `a` then `b`
/// yields the same state as one pass over their concatenation — the property
/// [`encode_frame_parts`] relies on to checksum a spliced payload without
/// materializing it.
#[inline]
fn crc32_update(mut c: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c
}

/// Close a running CRC32 state into the final digest.
#[inline]
fn crc32_finish(c: u32) -> u32 {
    c ^ 0xFFFF_FFFF
}

/// Framing / integrity failure.
#[derive(Debug)]
pub enum FrameError {
    /// Underlying stream error (disconnect, reset, ...).
    Io(std::io::Error),
    /// The stream ended inside a header or payload.
    Truncated,
    /// The length field exceeds the receiver's payload cap.
    Oversized { len: usize, max: usize },
    /// Payload bytes do not match the header checksum.
    Crc { expected: u32, got: u32 },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
            FrameError::Truncated => write!(f, "truncated frame"),
            FrameError::Oversized { len, max } => {
                write!(f, "frame payload length {len} exceeds cap {max}")
            }
            FrameError::Crc { expected, got } => {
                write!(f, "frame CRC mismatch: header {expected:#010x}, payload {got:#010x}")
            }
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

/// Frame a payload: header (length + CRC) followed by the payload bytes.
///
/// Panics if the payload exceeds [`MAX_PAYLOAD`] — encoders construct
/// payloads bounded far below the cap, so an oversized send is a bug, not
/// an input condition.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_PAYLOAD, "frame payload too large: {}", payload.len());
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Frame a payload supplied as consecutive slices, without concatenating
/// them first: the header's length is the summed part length and the CRC is
/// computed incrementally across the parts, so the output is byte-identical
/// to `encode_frame(&concat(parts))`. This is the shared-x-frame splice
/// path: the leader encodes the broadcast prefix (iterate payload) once per
/// iteration and frames it with each device's tiny assignment tail.
///
/// Panics if the combined payload exceeds [`MAX_PAYLOAD`] (same contract as
/// [`encode_frame`]).
pub fn encode_frame_parts(parts: &[&[u8]]) -> Vec<u8> {
    let len: usize = parts.iter().map(|p| p.len()).sum();
    assert!(len <= MAX_PAYLOAD, "frame payload too large: {len}");
    let mut c = CRC_INIT;
    for p in parts {
        c = crc32_update(c, p);
    }
    let mut out = Vec::with_capacity(HEADER_LEN + len);
    out.extend_from_slice(&(len as u32).to_le_bytes());
    out.extend_from_slice(&crc32_finish(c).to_le_bytes());
    for p in parts {
        out.extend_from_slice(p);
    }
    out
}

/// Validate one complete frame held in `buf` and return its payload slice.
///
/// The buffer must contain exactly one frame (header + payload, no excess)
/// — the shape a datagram-like transport (in-process channels) delivers.
pub fn decode_frame(buf: &[u8]) -> Result<&[u8], FrameError> {
    if buf.len() < HEADER_LEN {
        return Err(FrameError::Truncated);
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(FrameError::Oversized { len, max: MAX_PAYLOAD });
    }
    if buf.len() != HEADER_LEN + len {
        return Err(FrameError::Truncated);
    }
    let expected = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    let payload = &buf[HEADER_LEN..];
    let got = crc32(payload);
    if got != expected {
        return Err(FrameError::Crc { expected, got });
    }
    Ok(payload)
}

fn read_exact_mapped(r: &mut impl Read, buf: &mut [u8]) -> Result<(), FrameError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Truncated
        } else {
            FrameError::Io(e)
        }
    })
}

/// Read one frame from a byte stream (TCP / UDS): header first, length
/// validated against `max_payload` before the payload allocation, CRC
/// checked after the read. Returns `(payload, total bytes consumed)`.
pub fn read_frame(r: &mut impl Read, max_payload: usize) -> Result<(Vec<u8>, u64), FrameError> {
    let mut header = [0u8; HEADER_LEN];
    read_exact_mapped(r, &mut header)?;
    let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]) as usize;
    if len > max_payload {
        return Err(FrameError::Oversized { len, max: max_payload });
    }
    let expected = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
    let mut payload = vec![0u8; len];
    read_exact_mapped(r, &mut payload)?;
    let got = crc32(&payload);
    if got != expected {
        return Err(FrameError::Crc { expected, got });
    }
    Ok((payload, (HEADER_LEN + len) as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn frame_round_trip() {
        for payload in [&b""[..], b"x", b"hello frame", &[0u8; 1000]] {
            let f = encode_frame(payload);
            assert_eq!(f.len(), HEADER_LEN + payload.len());
            assert_eq!(decode_frame(&f).unwrap(), payload);
            let mut cursor = &f[..];
            let (p, n) = read_frame(&mut cursor, MAX_PAYLOAD).unwrap();
            assert_eq!(p, payload);
            assert_eq!(n, f.len() as u64);
        }
    }

    #[test]
    fn spliced_parts_frame_is_byte_identical_to_the_concat_frame() {
        let prefix = b"shared broadcast prefix \x00\x01\x02";
        let tails: [&[u8]; 4] = [b"", b"t", b"tail-two", &[0xFFu8; 33]];
        for tail in tails {
            let mut concat = prefix.to_vec();
            concat.extend_from_slice(tail);
            assert_eq!(encode_frame_parts(&[prefix, tail]), encode_frame(&concat));
        }
        // degenerate splits: zero parts / many parts of one payload
        assert_eq!(encode_frame_parts(&[]), encode_frame(b""));
        let p = b"abcdefgh";
        assert_eq!(encode_frame_parts(&[&p[..3], &p[3..5], &p[5..]]), encode_frame(p));
    }

    #[test]
    fn corrupted_byte_is_rejected() {
        let f = encode_frame(b"some payload bytes");
        for i in HEADER_LEN..f.len() {
            let mut bad = f.clone();
            bad[i] ^= 0x40;
            assert!(
                matches!(decode_frame(&bad), Err(FrameError::Crc { .. })),
                "flip at {i} undetected"
            );
        }
    }

    #[test]
    fn truncated_and_oversized_are_rejected() {
        let f = encode_frame(b"0123456789");
        assert!(matches!(decode_frame(&f[..f.len() - 1]), Err(FrameError::Truncated)));
        assert!(matches!(decode_frame(&f[..4]), Err(FrameError::Truncated)));
        // a stream that dies mid-payload
        let mut cursor = &f[..f.len() - 3];
        assert!(matches!(read_frame(&mut cursor, MAX_PAYLOAD), Err(FrameError::Truncated)));
        // hostile length field: rejected from the header alone, no allocation
        let mut huge = Vec::new();
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        huge.extend_from_slice(&[0u8; 4]);
        let mut cursor = &huge[..];
        assert!(matches!(
            read_frame(&mut cursor, MAX_PAYLOAD),
            Err(FrameError::Oversized { .. })
        ));
        assert!(matches!(decode_frame(&huge), Err(FrameError::Oversized { .. })));
    }

    #[test]
    fn trailing_garbage_is_rejected_by_slice_decoder() {
        let mut f = encode_frame(b"abc");
        f.push(0);
        assert!(matches!(decode_frame(&f), Err(FrameError::Truncated)));
    }
}
