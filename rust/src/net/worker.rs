//! The device-side event loop (Fig. 1, right-hand side).
//!
//! A worker joins the leader, handshakes (`Join` → `Hello`, with a config
//! digest cross-check), then serves broadcasts until `Shutdown`: for every
//! `Broadcast {x, subsets}` it computes its coded vector (eq. 5 — the mean
//! of its assigned subset gradients) and uploads it. Honest devices under
//! device-side compression (Com-LAD) compress with their private pre-split
//! RNG stream (`Hello.comp_seed`) before uploading, so the bytes on the
//! wire ARE the compressed message; devices playing the Byzantine role
//! upload their true vector densely and the leader crafts their lie
//! centrally (the omniscient-adversary emulation cannot live on a real
//! device).
//!
//! Under an error-feedback kind (`ef-*`, see [`crate::compress::ef`]) the
//! worker holds its own residual memory: each served broadcast compresses
//! `residual + coded` and stores the error back. The residual starts at
//! zero with the process, a stalled iteration leaves it untouched (no
//! compute happens), and a retired device's memory simply dies with the
//! leader's interest in it — the leader's mirror of that slot is reset, so
//! a rejoining slot can never replay stale state.
//!
//! The same function serves every transport: the in-process cluster
//! simulation passes a borrowed dataset (no copy per worker), while the
//! `lad node-worker` CLI decodes the dataset from `Hello`.
//!
//! [`run_worker_opts`] adds fault injection for the partial-participation
//! experiments: with [`WorkerOpts::stall_prob`] set, the worker swallows
//! broadcasts from a private seeded stream instead of uploading —
//! deterministic crash-fault emulation against the leader's gather
//! deadline and retirement machinery.

use super::transport::Transport;
use super::wire::{Msg, Payload, WIRE_VERSION};
use crate::compress;
use crate::data::linreg::LinRegDataset;
use crate::util::math::{axpy, scale};
use crate::util::rng::Rng;
use crate::Result;
use anyhow::{bail, ensure, Context};

/// What a worker did over its lifetime (printed by `lad node-worker`).
#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub device: usize,
    /// Iterations served (broadcasts answered with an upload).
    pub iters: usize,
    /// Broadcasts deliberately left unanswered ([`WorkerOpts::stall_prob`]).
    pub stalled: usize,
    /// Uplink bytes written (frames included).
    pub up_bytes: u64,
    /// Downlink bytes read (frames included).
    pub down_bytes: u64,
}

/// Fault-injection knobs for a worker — the device side of the
/// partial-participation experiments (`sweep::scenarios`).
#[derive(Debug, Clone, Default)]
pub struct WorkerOpts {
    /// Per-broadcast probability of simulating a stall: the worker
    /// swallows the broadcast and never uploads for that iteration, so
    /// the leader's gather deadline expires and (on a long enough streak)
    /// retires the device. `0.0` (the default) never stalls.
    pub stall_prob: f64,
    /// Seed of the private stall stream. Stall decisions draw from their
    /// own `Rng`, never from training randomness, so a stalling worker's
    /// served iterations stay bit-identical to a live worker's.
    pub stall_seed: u64,
}

/// Run one device until the leader shuts the run down.
///
/// * `local_ds`: the dataset, when this process already holds it (the
///   in-process cluster borrows the leader's copy — no per-worker clone).
///   When `None`, the dataset must arrive in `Hello`.
/// * `local_digest`: digest of a locally loaded config (`--config`),
///   verified against the leader's; `None` trusts the leader.
pub fn run_worker(
    link: Box<dyn Transport>,
    device: usize,
    local_ds: Option<&LinRegDataset>,
    local_digest: Option<u64>,
) -> Result<WorkerReport> {
    run_worker_opts(link, device, local_ds, local_digest, &WorkerOpts::default())
}

/// [`run_worker`] with fault-injection options (see [`WorkerOpts`]).
pub fn run_worker_opts(
    mut link: Box<dyn Transport>,
    device: usize,
    local_ds: Option<&LinRegDataset>,
    local_digest: Option<u64>,
    opts: &WorkerOpts,
) -> Result<WorkerReport> {
    let mut up = 0u64;
    let mut down = 0u64;
    up += link.send(&Msg::Join {
        version: WIRE_VERSION,
        device: device as u32,
        digest: local_digest.unwrap_or(0),
    })?;

    let (hello, n) = link.recv().context("waiting for leader hello")?;
    down += n;
    let Msg::Hello {
        version,
        device: dev,
        n_devices: _,
        dim,
        byzantine,
        device_compression,
        comp_seed,
        digest,
        compression,
        dataset,
    } = hello
    else {
        bail!("expected hello from leader (protocol error)");
    };
    ensure!(
        version == WIRE_VERSION,
        "protocol version mismatch: leader {version}, us {WIRE_VERSION}"
    );
    ensure!(dev as usize == device, "leader assigned device {dev}, we are {device}");
    if let Some(local) = local_digest {
        ensure!(
            local == digest,
            "config digest mismatch: leader {digest:#018x}, local {local:#018x}"
        );
    }
    let owned: Option<LinRegDataset> = match (local_ds, dataset) {
        (Some(_), _) => None,
        (None, Some(block)) => Some(block.into_dataset().context("decoding dataset block")?),
        (None, None) => bail!("leader sent no dataset and none was provided locally"),
    };
    let ds: &LinRegDataset = match local_ds {
        Some(d) => d,
        None => owned.as_ref().unwrap(),
    };
    ensure!(ds.dim() == dim as usize, "dataset dim {} != leader dim {dim}", ds.dim());

    // reject degenerate operator params with an error, not a constructor
    // panic, since they arrive over the wire
    match compression {
        crate::config::CompressionKind::RandK { k }
        | crate::config::CompressionKind::TopK { k }
        | crate::config::CompressionKind::EfRandK { k }
        | crate::config::CompressionKind::EfTopK { k } => {
            ensure!(k >= 1, "hello carries a degenerate sparsifier (k = 0)");
        }
        crate::config::CompressionKind::Qsgd { levels }
        | crate::config::CompressionKind::EfQsgd { levels } => {
            ensure!(levels >= 1, "hello carries a degenerate quantizer (0 levels)");
        }
        crate::config::CompressionKind::None => {}
    }
    let comp = compress::from_kind(compression);
    let mut comp_rng = Rng::new(comp_seed);
    // worker-held EF residual memory (one row, this device): zero at
    // process start; a stalled iteration never touches it
    let mut ef = compress::EfState::for_kind(compression, 1, ds.dim());
    let mut stall_rng = Rng::new(opts.stall_seed);
    let compress_uplink = device_compression && !byzantine;
    let mut iters = 0usize;
    let mut stalled = 0usize;

    loop {
        let (msg, n) = link.recv().context("connection to leader lost")?;
        down += n;
        match msg {
            Msg::Broadcast { iter, x, subsets } => {
                // crash-fault emulation: swallow the broadcast before any
                // compute so a stalled iteration consumes no training
                // randomness (the stall stream is private)
                if opts.stall_prob > 0.0 && stall_rng.bernoulli(opts.stall_prob) {
                    stalled += 1;
                    continue;
                }
                ensure!(!subsets.is_empty(), "broadcast with no subsets");
                ensure!(x.len() == ds.dim(), "broadcast x has dim {}", x.len());
                // coded vector: mean of the assigned subset gradients —
                // identical arithmetic (axpy then scale) to the central
                // oracle, so traces stay bit-identical
                let mut coded = vec![0.0f32; ds.dim()];
                for &k in &subsets {
                    ensure!((k as usize) < ds.n(), "subset index {k} out of range {}", ds.n());
                    let g = ds.subset_grad(k as usize, &x);
                    axpy(1.0, &g, &mut coded);
                }
                scale(&mut coded, 1.0 / subsets.len() as f32);
                let (payload, analytic_bits) = if compress_uplink {
                    let c = match ef.as_mut() {
                        Some(st) => st.step(0, &coded, comp.as_ref(), &mut comp_rng),
                        None => comp.compress(&coded, &mut comp_rng),
                    };
                    (Payload::from_compressed(&c), c.bits as u64)
                } else {
                    (Payload::Dense { values: coded }, 0)
                };
                up += link.send(&Msg::Upload {
                    iter,
                    device: device as u32,
                    analytic_bits,
                    payload,
                })?;
                iters += 1;
            }
            Msg::Shutdown => break,
            other => bail!("unexpected message from leader: {other:?}"),
        }
    }
    Ok(WorkerReport { device, iters, stalled, up_bytes: up, down_bytes: down })
}
