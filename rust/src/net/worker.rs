//! The device-side event loop (Fig. 1, right-hand side).
//!
//! A worker joins the leader, handshakes (`Join` → `Hello`, with a config
//! digest cross-check), then serves broadcasts until `Shutdown`: for every
//! `Broadcast {x, subsets}` it computes its coded vector (eq. 5 — the mean
//! of its assigned subset gradients) and uploads it. Honest devices under
//! device-side compression (Com-LAD) compress with their private pre-split
//! RNG stream (`Hello.comp_seed`) before uploading, so the bytes on the
//! wire ARE the compressed message; devices playing the Byzantine role
//! upload their true vector densely and the leader crafts their lie
//! centrally (the omniscient-adversary emulation cannot live on a real
//! device). When the leader announced role rotation (`Hello.rotate`), the
//! per-iteration `Broadcast` role bit is authoritative instead of the
//! session-level `Hello.byzantine` — and a `Broadcast` stream-cursor
//! hand-off, when present, replaces the local compression stream before
//! compressing, with the post-compression cursor echoed in the `Upload`
//! (this keeps the leader's stream mirror exact while roles move around).
//!
//! Under an error-feedback kind (`ef-*`, see [`crate::compress::ef`]) the
//! worker holds its own residual memory: each served broadcast compresses
//! `residual + coded` and stores the error back. The residual starts at
//! zero with the process, a stalled iteration leaves it untouched (no
//! compute happens), and a retired device's memory simply dies with the
//! leader's interest in it — the leader's mirror of that slot is reset, so
//! a rejoining slot can never replay stale state.
//!
//! **Leader failover.** With [`WorkerOpts::reconnect_addr`] set, a lost
//! connection mid-run is not fatal: the worker redials (bounded attempts ×
//! backoff), re-joins with its device id, and applies the fresh `Hello` —
//! keeping its live compression stream and EF residual when the leader
//! says `reset_stream: false` (a warm restart resuming the same run), or
//! reinitializing from the new `comp_seed` when `reset_stream: true` (a
//! rejoin into a reclaimed slot).
//!
//! The same function serves every transport: the in-process cluster
//! simulation passes a borrowed dataset (no copy per worker), while the
//! `lad node-worker` CLI decodes the dataset from `Hello`.
//!
//! [`run_worker_opts`] adds fault injection for the partial-participation
//! and churn experiments: [`WorkerOpts::stall_prob`] swallows broadcasts
//! from a private seeded stream, and [`WorkerOpts::stall_after_iter`]
//! deterministically swallows every broadcast from a given iteration on —
//! the churn harness's "departing worker" primitive (the leader's gather
//! deadline then retires the slot for a replacement to reclaim).

use super::transport::Transport;
use super::wire::{DatasetBlock, Msg, Payload, WIRE_VERSION};
use crate::compress;
use crate::data::linreg::LinRegDataset;
use crate::obs::{Event, Obs};
use crate::util::math::{axpy, scale};
use crate::util::rng::Rng;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::time::Duration;

/// What a worker did over its lifetime (printed by `lad node-worker`).
#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub device: usize,
    /// Iterations served (broadcasts answered with an upload).
    pub iters: usize,
    /// Broadcasts deliberately left unanswered ([`WorkerOpts::stall_prob`]
    /// or [`WorkerOpts::stall_after_iter`]).
    pub stalled: usize,
    /// Successful leader reconnects ([`WorkerOpts::reconnect_addr`]).
    pub reconnects: usize,
    /// Uplink bytes written (frames included).
    pub up_bytes: u64,
    /// Downlink bytes read (frames included).
    pub down_bytes: u64,
}

/// Fault-injection and resilience knobs for a worker — the device side of
/// the partial-participation, churn and failover experiments.
#[derive(Debug, Clone)]
pub struct WorkerOpts {
    /// Per-broadcast probability of simulating a stall: the worker
    /// swallows the broadcast and never uploads for that iteration, so
    /// the leader's gather deadline expires and (on a long enough streak)
    /// retires the device. `0.0` (the default) never stalls.
    pub stall_prob: f64,
    /// Seed of the private stall stream. Stall decisions draw from their
    /// own `Rng`, never from training randomness, so a stalling worker's
    /// served iterations stay bit-identical to a live worker's.
    pub stall_seed: u64,
    /// Deterministic churn: serve every broadcast whose iteration is
    /// below this, then swallow all later ones (the leader retires the
    /// slot after its miss streak fills). `None` (default) never departs.
    pub stall_after_iter: Option<u64>,
    /// Redial target after a lost connection (leader failover). `None`
    /// (the default) makes a lost connection fatal, as before.
    pub reconnect_addr: Option<String>,
    /// Redial attempts before giving up.
    pub reconnect_attempts: u32,
    /// Wait between redial attempts.
    pub reconnect_backoff: Duration,
    /// Observability sink. [`Obs::off`] (the default) is a no-op; a
    /// recording handle journals `worker_redial` events for every lost
    /// upload and failed reconnect attempt — pure telemetry, never on
    /// the compute or wire path.
    pub obs: Obs,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            stall_prob: 0.0,
            stall_seed: 0,
            stall_after_iter: None,
            reconnect_addr: None,
            reconnect_attempts: 0,
            reconnect_backoff: Duration::from_millis(250),
            obs: Obs::off(),
        }
    }
}

/// The `Hello` fields a worker acts on, plus the bytes it cost to read.
struct HelloInfo {
    dim: usize,
    byzantine: bool,
    device_compression: bool,
    comp_seed: u64,
    compression: crate::config::CompressionKind,
    rotate: bool,
    reset_stream: bool,
    dataset: Option<DatasetBlock>,
    bytes: u64,
}

/// Receive + validate one `Hello` on `link` (shared by the initial
/// handshake and the failover re-handshake).
fn recv_hello(
    link: &mut Box<dyn Transport>,
    device: usize,
    local_digest: Option<u64>,
) -> Result<HelloInfo> {
    let (hello, bytes) = link.recv().context("waiting for leader hello")?;
    let Msg::Hello {
        version,
        device: dev,
        n_devices: _,
        dim,
        byzantine,
        device_compression,
        comp_seed,
        digest,
        compression,
        rotate,
        reset_stream,
        resume_iter: _,
        iterate: _,
        dataset,
    } = hello
    else {
        bail!("expected hello from leader (protocol error)");
    };
    ensure!(
        version == WIRE_VERSION,
        "protocol version mismatch: leader {version}, us {WIRE_VERSION}"
    );
    ensure!(dev as usize == device, "leader assigned device {dev}, we are {device}");
    if let Some(local) = local_digest {
        ensure!(
            local == digest,
            "config digest mismatch: leader {digest:#018x}, local {local:#018x}"
        );
    }
    // reject degenerate operator params with an error, not a constructor
    // panic, since they arrive over the wire
    match compression {
        crate::config::CompressionKind::RandK { k }
        | crate::config::CompressionKind::TopK { k }
        | crate::config::CompressionKind::EfRandK { k }
        | crate::config::CompressionKind::EfTopK { k } => {
            ensure!(k >= 1, "hello carries a degenerate sparsifier (k = 0)");
        }
        crate::config::CompressionKind::Qsgd { levels }
        | crate::config::CompressionKind::EfQsgd { levels } => {
            ensure!(levels >= 1, "hello carries a degenerate quantizer (0 levels)");
        }
        crate::config::CompressionKind::None => {}
    }
    Ok(HelloInfo {
        dim: dim as usize,
        byzantine,
        device_compression,
        comp_seed,
        compression,
        rotate,
        reset_stream,
        dataset,
        bytes,
    })
}

/// Redial the leader after a lost connection: bounded attempts with a
/// fixed backoff, each attempt re-running the full `Join` → `Hello`
/// handshake. Returns the fresh link, its `Hello`, and the handshake
/// bytes `(up, down)`.
fn redial(
    device: usize,
    local_digest: Option<u64>,
    opts: &WorkerOpts,
) -> Result<(Box<dyn Transport>, HelloInfo, u64)> {
    let addr = opts.reconnect_addr.as_deref().expect("redial requires reconnect_addr");
    let mut last: anyhow::Error = anyhow::anyhow!("no reconnect attempts configured");
    // journal every failed attempt with its reason — the redial loop
    // used to swallow all but the last error
    let note = |attempt: u32, reason: String| {
        if opts.obs.enabled() {
            opts.obs.emit(Event::WorkerRedial { device, attempt: attempt as u64, reason });
        }
    };
    for attempt in 1..=opts.reconnect_attempts {
        std::thread::sleep(opts.reconnect_backoff);
        let mut link = match super::transport::connect(addr) {
            Ok(l) => l,
            Err(e) => {
                note(attempt, format!("connect to {addr} failed: {e:#}"));
                last = e.context(format!("reconnect attempt {attempt} to {addr}"));
                continue;
            }
        };
        let join_bytes = match link.send(&Msg::Join {
            version: WIRE_VERSION,
            device: device as u32,
            digest: local_digest.unwrap_or(0),
        }) {
            Ok(nb) => nb,
            Err(e) => {
                note(attempt, format!("join send failed: {e:#}"));
                last = e.context(format!("reconnect attempt {attempt}: join"));
                continue;
            }
        };
        match recv_hello(&mut link, device, local_digest) {
            Ok(h) => return Ok((link, h, join_bytes)),
            Err(e) => {
                note(attempt, format!("hello handshake failed: {e:#}"));
                last = e.context(format!("reconnect attempt {attempt}: hello"));
            }
        }
    }
    Err(last.context(format!(
        "worker {device}: leader unreachable after {} attempts",
        opts.reconnect_attempts
    )))
}

/// Run one device until the leader shuts the run down.
///
/// * `local_ds`: the dataset, when this process already holds it (the
///   in-process cluster borrows the leader's copy — no per-worker clone).
///   When `None`, the dataset must arrive in `Hello`.
/// * `local_digest`: digest of a locally loaded config (`--config`),
///   verified against the leader's; `None` trusts the leader.
pub fn run_worker(
    link: Box<dyn Transport>,
    device: usize,
    local_ds: Option<&LinRegDataset>,
    local_digest: Option<u64>,
) -> Result<WorkerReport> {
    run_worker_opts(link, device, local_ds, local_digest, &WorkerOpts::default())
}

/// [`run_worker`] with fault-injection and failover options.
pub fn run_worker_opts(
    mut link: Box<dyn Transport>,
    device: usize,
    local_ds: Option<&LinRegDataset>,
    local_digest: Option<u64>,
    opts: &WorkerOpts,
) -> Result<WorkerReport> {
    let mut up = 0u64;
    let mut down = 0u64;
    up += link.send(&Msg::Join {
        version: WIRE_VERSION,
        device: device as u32,
        digest: local_digest.unwrap_or(0),
    })?;
    let hello = recv_hello(&mut link, device, local_digest)?;
    down += hello.bytes;

    let owned: Option<LinRegDataset> = match (local_ds, hello.dataset) {
        (Some(_), _) => None,
        (None, Some(block)) => Some(block.into_dataset().context("decoding dataset block")?),
        (None, None) => bail!("leader sent no dataset and none was provided locally"),
    };
    let ds: &LinRegDataset = match local_ds {
        Some(d) => d,
        None => owned.as_ref().unwrap(),
    };
    ensure!(ds.dim() == hello.dim, "dataset dim {} != leader dim {}", ds.dim(), hello.dim);

    let comp = compress::from_kind(hello.compression);
    let mut comp_rng = Rng::new(hello.comp_seed);
    // worker-held EF residual memory (one row, this device): zero at
    // process start; a stalled iteration never touches it
    let mut ef = compress::EfState::for_kind(hello.compression, 1, ds.dim());
    let mut stall_rng = Rng::new(opts.stall_seed);
    // session-level role + mode; under rotation the per-broadcast bit
    // overrides the role each iteration
    let mut session_byz = hello.byzantine;
    let mut device_compression = hello.device_compression;
    let mut rotate = hello.rotate;
    let compression = hello.compression;
    let mut iters = 0usize;
    let mut stalled = 0usize;
    let mut reconnects = 0usize;

    loop {
        let (msg, n) = match link.recv() {
            Ok(v) => v,
            Err(e) => {
                if opts.reconnect_addr.is_none() || opts.reconnect_attempts == 0 {
                    return Err(e).context("connection to leader lost");
                }
                // leader failover: redial, re-handshake, and either keep
                // the live stream state (reset_stream: false — a warm
                // restart of the same run) or reinitialize it (a rejoin
                // into a reclaimed slot)
                let (new_link, h, join_bytes) = redial(device, local_digest, opts)?;
                ensure!(
                    h.compression == compression,
                    "leader changed the compression kind across a reconnect"
                );
                ensure!(h.dim == ds.dim(), "leader changed dim across a reconnect");
                link = new_link;
                up += join_bytes;
                down += h.bytes;
                if h.reset_stream {
                    comp_rng = Rng::new(h.comp_seed);
                    ef = compress::EfState::for_kind(compression, 1, ds.dim());
                }
                session_byz = h.byzantine;
                device_compression = h.device_compression;
                rotate = h.rotate;
                reconnects += 1;
                continue;
            }
        };
        down += n;
        match msg {
            Msg::Broadcast { iter, x, subsets, byzantine, cursor } => {
                // deterministic churn: from the departure iteration on,
                // swallow everything (no compute, no stall-stream draw)
                if opts.stall_after_iter.is_some_and(|c| iter as u64 >= c) {
                    stalled += 1;
                    continue;
                }
                // crash-fault emulation: swallow the broadcast before any
                // compute so a stalled iteration consumes no training
                // randomness (the stall stream is private)
                if opts.stall_prob > 0.0 && stall_rng.bernoulli(opts.stall_prob) {
                    stalled += 1;
                    continue;
                }
                ensure!(!subsets.is_empty(), "broadcast with no subsets");
                ensure!(x.len() == ds.dim(), "broadcast x has dim {}", x.len());
                // coded vector: mean of the assigned subset gradients —
                // identical arithmetic (axpy then scale) to the central
                // oracle, so traces stay bit-identical
                let mut coded = vec![0.0f32; ds.dim()];
                for &k in &subsets {
                    ensure!((k as usize) < ds.n(), "subset index {k} out of range {}", ds.n());
                    let g = ds.subset_grad(k as usize, &x);
                    axpy(1.0, &g, &mut coded);
                }
                scale(&mut coded, 1.0 / subsets.len() as f32);
                let role_byz = if rotate { byzantine } else { session_byz };
                let (payload, analytic_bits, echo) = if device_compression && !role_byz {
                    // a stream-cursor hand-off (rotation) replaces the
                    // local stream with the leader's mirror before
                    // compressing; the post-compression cursor is echoed
                    // back so the mirror stays exact
                    if let Some(st) = cursor {
                        comp_rng = Rng::restore(st);
                    }
                    let c = match ef.as_mut() {
                        Some(st) => st.step(0, &coded, comp.as_ref(), &mut comp_rng),
                        None => comp.compress(&coded, &mut comp_rng),
                    };
                    let echo = cursor.is_some().then(|| comp_rng.save_state());
                    (Payload::from_compressed(&c), c.bits as u64, echo)
                } else {
                    (Payload::Dense { values: coded }, 0, None)
                };
                let sent = link.send(&Msg::Upload {
                    iter,
                    device: device as u32,
                    analytic_bits,
                    cursor: echo,
                    payload,
                });
                match sent {
                    Ok(nb) => up += nb,
                    Err(e) => {
                        if opts.reconnect_addr.is_none() || opts.reconnect_attempts == 0 {
                            return Err(e).context("uploading to leader");
                        }
                        // the upload is lost (the leader's deadline covers
                        // it); recover the connection on the next recv.
                        // attempt 0 marks the triggering loss, before any
                        // numbered redial attempt runs
                        if opts.obs.enabled() {
                            opts.obs.emit(Event::WorkerRedial {
                                device,
                                attempt: 0,
                                reason: format!("upload for iter {iter} failed: {e:#}"),
                            });
                        }
                        eprintln!("worker {device}: upload failed ({e:#}), will redial");
                        continue;
                    }
                }
                iters += 1;
            }
            Msg::Shutdown => break,
            other => bail!("unexpected message from leader: {other:?}"),
        }
    }
    Ok(WorkerReport { device, iters, stalled, reconnects, up_bytes: up, down_bytes: down })
}
