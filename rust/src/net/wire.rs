//! Versioned little-endian binary codec for the leader–worker protocol.
//!
//! Every [`Msg`] encodes to one frame payload (see [`crate::net::frame`]).
//! All integers are little-endian; floats are IEEE-754 bit patterns. The
//! first byte of every payload is the message tag:
//!
//! | tag | message     | direction       | body                                     |
//! |-----|-------------|-----------------|------------------------------------------|
//! | 1   | `Join`      | worker → leader | version u8, device u32, config digest u64 |
//! | 2   | `Hello`     | leader → worker | version u8, device u32, N u32, Q u32, byzantine u8, device_compression u8, comp_seed u64, digest u64, compression kind, rotate u8, reset_stream u8, resume_iter u64, iterate option, dataset option |
//! | 3   | `Broadcast` | leader → worker | iter u32, x (u32 len + f32s), subsets (u32 len + u32s), byzantine u8, cursor option |
//! | 4   | `Upload`    | worker → leader | iter u32, device u32, analytic_bits u64, cursor option, payload |
//! | 5   | `Shutdown`  | leader → worker | —                                        |
//!
//! Version 2 grew the elasticity fields: `Hello` doubles as the
//! *Rejoin* reply (`resume_iter` > 0 plus the current `iterate` when a
//! late `Join` lands mid-run, `reset_stream` telling the worker whether
//! to reinitialize its compression stream and EF residual or keep the
//! state it already carries), `Broadcast` carries the device's
//! *per-iteration* Byzantine role bit plus an optional compression-stream
//! cursor (role rotation under device-side compression hands the leader's
//! mirror cursor to whichever device is honest this round), and `Upload`
//! optionally echoes the worker's post-compression cursor back. An
//! [`crate::util::rng::RngState`] cursor encodes as
//! `state u64 | inc u64 | spare flag u8 [| spare f64]`; options as a
//! presence byte.
//!
//! [`Payload`] is the uplink body: the *variant-specific* encoding of a
//! compressed message, chosen from [`crate::compress::WireEnc`] so the
//! serialized size tracks the operator's analytic bit accounting instead of
//! always paying dense f32 freight:
//!
//! | tag | payload     | body                                                   |
//! |-----|-------------|--------------------------------------------------------|
//! | 0   | `Dense`     | u32 len, len × f32 (Identity, and the exactness fallback) |
//! | 1   | `Sparse`    | u32 dim, u32 nnz, nnz × (u32 index, f32 value) — rand-K / top-K |
//! | 2   | `Quantized` | u32 dim, u32 levels, f32 ‖g‖, packed (1 sign bit + ⌈log₂(s+1)⌉ level bits) per coordinate — QSGD; empty when ‖g‖ = 0 |
//!
//! Decoding a payload reconstructs the compressor's dense output
//! **bit-identically**: [`Payload::from_compressed`] verifies the exact
//! f32 round trip at encode time and falls back to `Dense` on any
//! mismatch, so the remote path can never diverge from the central
//! trainer by a ulp. Decoders validate every length against the remaining
//! buffer before allocating, and [`Msg::decode`] requires the payload to
//! be fully consumed — trailing bytes are a protocol error, not slack.

use crate::compress::{CompressedMsg, WireEnc};
use crate::config::{CompressionKind, TrainConfig};
use crate::data::linreg::LinRegDataset;
use crate::util::math::Mat;
use crate::util::rng::RngState;
use crate::Result;
use anyhow::{bail, ensure};

/// Protocol version; bumped on any wire-format change. A `Join`/`Hello`
/// version mismatch aborts the handshake. v2 added the elasticity fields
/// (rejoin `Hello`, per-iteration role bit, stream cursors).
pub const WIRE_VERSION: u8 = 2;

/// Cap on any payload's claimed reconstruction dimension — the largest
/// vector a dense frame could carry (`frame::MAX_PAYLOAD` / 4 bytes per
/// f32). Sparse and quantized payloads state `dim` explicitly, so without
/// this bound a tiny hostile frame could claim a multi-GiB reconstruction
/// and OOM the decoder's `to_dense`.
pub const MAX_WIRE_DIM: usize = super::frame::MAX_PAYLOAD / 4;

// ---------------------------------------------------------------------------
// byte-level writer / reader
// ---------------------------------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn with_capacity(cap: usize) -> Self {
        Writer { buf: Vec::with_capacity(cap) }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
    /// u32 length prefix + raw f32s.
    fn f32_slice(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }
    /// Presence byte + RNG cursor (`state u64 | inc u64 | spare flag u8
    /// [| spare f64]`).
    fn opt_rng_state(&mut self, st: &Option<RngState>) {
        match st {
            None => self.u8(0),
            Some(st) => {
                self.u8(1);
                self.u64(st.state);
                self.u64(st.inc);
                match st.spare_gauss {
                    None => self.u8(0),
                    Some(g) => {
                        self.u8(1);
                        self.f64(g);
                    }
                }
            }
        }
    }
    fn finish(self) -> Vec<u8> {
        self.buf
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let left = self.remaining();
        ensure!(left >= n, "wire: short read ({left} of {n} bytes left)");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// A length prefix for `elem_size`-byte elements, validated against the
    /// remaining buffer so a corrupt count cannot drive a huge allocation.
    fn len_prefix(&mut self, elem_size: usize) -> Result<usize> {
        let len = self.u32()? as usize;
        ensure!(
            len.checked_mul(elem_size).is_some_and(|b| b <= self.remaining()),
            "wire: length {len} x {elem_size}B exceeds {} remaining bytes",
            self.remaining()
        );
        Ok(len)
    }
    fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let len = self.len_prefix(4)?;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.f32()?);
        }
        Ok(out)
    }
    fn u32_vec(&mut self) -> Result<Vec<u32>> {
        let len = self.len_prefix(4)?;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.u32()?);
        }
        Ok(out)
    }
    fn opt_rng_state(&mut self) -> Result<Option<RngState>> {
        match self.u8()? {
            0 => Ok(None),
            1 => {
                let state = self.u64()?;
                let inc = self.u64()?;
                let spare_gauss = match self.u8()? {
                    0 => None,
                    1 => Some(self.f64()?),
                    b => bail!("wire: bad spare-gauss flag {b}"),
                };
                Ok(Some(RngState { state, inc, spare_gauss }))
            }
            b => bail!("wire: bad rng-cursor presence byte {b}"),
        }
    }
    fn done(self) -> Result<()> {
        ensure!(self.remaining() == 0, "wire: {} trailing bytes after message", self.remaining());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// bit packing for the quantized payload
// ---------------------------------------------------------------------------

fn put_bits(buf: &mut [u8], pos: &mut usize, value: u32, nbits: usize) {
    for b in 0..nbits {
        if (value >> b) & 1 == 1 {
            buf[(*pos + b) / 8] |= 1 << ((*pos + b) % 8);
        }
    }
    *pos += nbits;
}

fn get_bits(buf: &[u8], pos: &mut usize, nbits: usize) -> u32 {
    let mut v = 0u32;
    for b in 0..nbits {
        let bit = (buf[(*pos + b) / 8] >> ((*pos + b) % 8)) & 1;
        v |= (bit as u32) << b;
    }
    *pos += nbits;
    v
}

/// ⌈log₂(levels + 1)⌉ — bits needed for one QSGD level index (the same
/// figure the operator's analytic bit accounting charges).
fn level_bits(levels: u32) -> usize {
    (32 - levels.leading_zeros()) as usize
}

fn pack_quantized(values: &[f32], levels: u32, norm: f32) -> Option<Vec<u8>> {
    if norm == 0.0 {
        // a zero-norm message decodes to all-zeros from the header alone;
        // shipping per-coordinate bits would overshoot the operator's
        // 32 + q analytic accounting for the degenerate case
        return Some(Vec::new());
    }
    let s = levels as f32;
    let lb = level_bits(levels);
    let total_bits = values.len() * (1 + lb);
    let mut buf = vec![0u8; total_bits.div_ceil(8)];
    let mut pos = 0usize;
    for &v in values {
        // v was produced as sign · level · ‖g‖ / s in f32 (norm > 0 here —
        // the zero-norm case returned above); the inverse rounds to the
        // exact integer whenever levels is sane, and the caller verifies
        // the round trip bitwise, falling back to Dense otherwise
        let a = (v.abs() * s / norm).round();
        if !a.is_finite() || a < 0.0 || a > s {
            return None;
        }
        let level = a as u32;
        put_bits(&mut buf, &mut pos, u32::from(v.is_sign_negative()), 1);
        put_bits(&mut buf, &mut pos, level, lb);
    }
    Some(buf)
}

fn unpack_quantized_into(out: &mut [f32], levels: u32, norm: f32, packed: &[u8]) {
    let s = levels as f32;
    let lb = level_bits(levels);
    let mut pos = 0usize;
    for slot in out.iter_mut() {
        let sign = get_bits(packed, &mut pos, 1) == 1;
        let level = get_bits(packed, &mut pos, lb);
        let sign_f: f32 = if sign { -1.0 } else { 1.0 };
        // same expression (and evaluation order) as Qsgd::compress, so the
        // reconstruction is bit-identical to the sender's dense output
        *slot = sign_f * level as f32 * norm / s;
    }
}

// ---------------------------------------------------------------------------
// payload
// ---------------------------------------------------------------------------

/// Wire body of one uplink message — the encoded form of a compressor's
/// output (see the module table for the byte layout of each variant).
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    Dense { values: Vec<f32> },
    Sparse { dim: u32, idx: Vec<u32>, values: Vec<f32> },
    Quantized { dim: u32, levels: u32, norm: f32, packed: Vec<u8> },
}

impl Payload {
    /// Encode a compressed message per its operator's [`WireEnc`]. The
    /// compact encodings are verified to reconstruct the dense vector
    /// bit-for-bit; any mismatch (degenerate norms, absurd level counts)
    /// falls back to `Dense`, trading bytes for guaranteed exactness.
    pub fn from_compressed(msg: &CompressedMsg) -> Payload {
        match msg.enc {
            WireEnc::Dense => Payload::Dense { values: msg.vec.clone() },
            WireEnc::Sparse => {
                // keep every entry with a nonzero bit pattern (including
                // -0.0), so the scatter reconstruction is exact by
                // construction
                let mut idx = Vec::new();
                let mut values = Vec::new();
                for (j, &v) in msg.vec.iter().enumerate() {
                    if v.to_bits() != 0 {
                        idx.push(j as u32);
                        values.push(v);
                    }
                }
                Payload::Sparse { dim: msg.vec.len() as u32, idx, values }
            }
            WireEnc::Quantized { levels, norm } => {
                if let Some(packed) = pack_quantized(&msg.vec, levels, norm) {
                    let cand = Payload::Quantized {
                        dim: msg.vec.len() as u32,
                        levels,
                        norm,
                        packed,
                    };
                    if let Ok(back) = cand.to_dense() {
                        let exact = back.len() == msg.vec.len()
                            && back
                                .iter()
                                .zip(&msg.vec)
                                .all(|(a, b)| a.to_bits() == b.to_bits());
                        if exact {
                            return cand;
                        }
                    }
                }
                Payload::Dense { values: msg.vec.clone() }
            }
        }
    }

    /// Reconstruct the dense vector the sender's compressor produced.
    pub fn to_dense(&self) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.dim()];
        self.decode_into(&mut out)?;
        Ok(out)
    }

    /// Reconstruct the dense vector directly into a caller-owned slice —
    /// the zero-copy uplink path: the leader hands each device's row of
    /// one contiguous gather slab, so decoding allocates nothing. `out`
    /// must have length [`Payload::dim`]; stale contents are fully
    /// overwritten (sparse scatters zero-fill first). On error the slice
    /// contents are unspecified.
    pub fn decode_into(&self, out: &mut [f32]) -> Result<()> {
        ensure!(
            out.len() == self.dim(),
            "payload dim {} does not match output slice len {}",
            self.dim(),
            out.len()
        );
        match self {
            Payload::Dense { values } => out.copy_from_slice(values),
            Payload::Sparse { dim, idx, values } => {
                ensure!(idx.len() == values.len(), "sparse payload index/value mismatch");
                let dim = *dim as usize;
                out.fill(0.0);
                for (&j, &v) in idx.iter().zip(values) {
                    ensure!((j as usize) < dim, "sparse index {j} out of range {dim}");
                    out[j as usize] = v;
                }
            }
            Payload::Quantized { dim, levels, norm, packed } => {
                ensure!(*levels >= 1, "quantized payload with zero levels");
                let dim = *dim as usize;
                if *norm == 0.0 {
                    ensure!(packed.is_empty(), "zero-norm quantized payload carries data");
                    out.fill(0.0);
                    return Ok(());
                }
                let need = (dim * (1 + level_bits(*levels))).div_ceil(8);
                ensure!(
                    packed.len() == need,
                    "quantized payload: {} bytes, need {need}",
                    packed.len()
                );
                unpack_quantized_into(out, *levels, *norm, packed);
            }
        }
        Ok(())
    }

    /// Exact serialized size of this payload in bytes (tag + body) — the
    /// per-variant wire-cost accessor the byte accounting is built on.
    pub fn encoded_len(&self) -> usize {
        match self {
            Payload::Dense { values } => 1 + 4 + 4 * values.len(),
            Payload::Sparse { idx, .. } => 1 + 4 + 4 + 8 * idx.len(),
            Payload::Quantized { packed, .. } => 1 + 4 + 4 + 4 + packed.len(),
        }
    }

    /// The reconstructed dimension.
    pub fn dim(&self) -> usize {
        match self {
            Payload::Dense { values } => values.len(),
            Payload::Sparse { dim, .. } | Payload::Quantized { dim, .. } => *dim as usize,
        }
    }

    fn encode_into(&self, w: &mut Writer) {
        match self {
            Payload::Dense { values } => {
                w.u8(0);
                w.f32_slice(values);
            }
            Payload::Sparse { dim, idx, values } => {
                w.u8(1);
                w.u32(*dim);
                w.u32(idx.len() as u32);
                for (&j, &v) in idx.iter().zip(values) {
                    w.u32(j);
                    w.f32(v);
                }
            }
            Payload::Quantized { dim, levels, norm, packed } => {
                w.u8(2);
                w.u32(*dim);
                w.u32(*levels);
                w.f32(*norm);
                w.bytes(packed);
            }
        }
    }

    fn decode(r: &mut Reader) -> Result<Payload> {
        match r.u8()? {
            0 => Ok(Payload::Dense { values: r.f32_vec()? }),
            1 => {
                let dim = r.u32()?;
                ensure!(dim as usize <= MAX_WIRE_DIM, "sparse payload: implausible dim {dim}");
                let nnz = r.len_prefix(8)?;
                ensure!(nnz <= dim as usize, "sparse payload: nnz {nnz} > dim {dim}");
                let mut idx = Vec::with_capacity(nnz);
                let mut values = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    idx.push(r.u32()?);
                    values.push(r.f32()?);
                }
                Ok(Payload::Sparse { dim, idx, values })
            }
            2 => {
                let dim = r.u32()?;
                ensure!(dim as usize <= MAX_WIRE_DIM, "quantized payload: implausible dim {dim}");
                let levels = r.u32()?;
                ensure!(levels >= 1, "quantized payload with zero levels");
                let norm = r.f32()?;
                let need = if norm == 0.0 {
                    0 // zero-norm messages carry no per-coordinate bits
                } else {
                    let bytes = (dim as usize)
                        .checked_mul(1 + level_bits(levels))
                        .map(|b| b.div_ceil(8));
                    match bytes {
                        Some(n) if n <= r.remaining() => n,
                        _ => bail!("quantized payload: implausible dim {dim}"),
                    }
                };
                Ok(Payload::Quantized { dim, levels, norm, packed: r.take(need)?.to_vec() })
            }
            tag => bail!("unknown payload tag {tag}"),
        }
    }
}

// ---------------------------------------------------------------------------
// dataset block
// ---------------------------------------------------------------------------

/// The §VII linear-regression workload, shipped to workers in `Hello` so a
/// remote process needs no local data file (tiny at experiment scale; for
/// real deployments workers would load shards locally and pass
/// `local_ds` to `run_worker` instead).
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetBlock {
    pub n: u32,
    pub q: u32,
    pub sigma_h: f64,
    pub z: Vec<f32>,
    pub y: Vec<f32>,
}

impl DatasetBlock {
    pub fn from_dataset(ds: &LinRegDataset) -> Self {
        DatasetBlock {
            n: ds.n() as u32,
            q: ds.dim() as u32,
            sigma_h: ds.sigma_h,
            z: ds.z.data.clone(),
            y: ds.y.clone(),
        }
    }

    pub fn into_dataset(self) -> Result<LinRegDataset> {
        let (n, q) = (self.n as usize, self.q as usize);
        ensure!(
            self.z.len() == n * q,
            "dataset block: z has {} entries, want {}",
            self.z.len(),
            n * q
        );
        ensure!(self.y.len() == n, "dataset block: y has {} entries, want {n}", self.y.len());
        Ok(LinRegDataset {
            z: Mat { rows: n, cols: q, data: self.z },
            y: self.y,
            sigma_h: self.sigma_h,
        })
    }

    fn encode_into(&self, w: &mut Writer) {
        w.u32(self.n);
        w.u32(self.q);
        w.f64(self.sigma_h);
        for &v in &self.z {
            w.f32(v);
        }
        for &v in &self.y {
            w.f32(v);
        }
    }

    fn decode(r: &mut Reader) -> Result<DatasetBlock> {
        let n = r.u32()?;
        let q = r.u32()?;
        let sigma_h = r.f64()?;
        let cells = (n as usize)
            .checked_mul(q as usize)
            .and_then(|c| c.checked_add(n as usize))
            .and_then(|c| c.checked_mul(4));
        match cells {
            Some(bytes) if bytes <= r.remaining() => {}
            _ => bail!("dataset block: implausible shape {n}x{q}"),
        }
        let mut z = Vec::with_capacity(n as usize * q as usize);
        for _ in 0..n as usize * q as usize {
            z.push(r.f32()?);
        }
        let mut y = Vec::with_capacity(n as usize);
        for _ in 0..n {
            y.push(r.f32()?);
        }
        Ok(DatasetBlock { n, q, sigma_h, z, y })
    }
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

fn encode_compression(kind: CompressionKind, w: &mut Writer) {
    match kind {
        CompressionKind::None => {
            w.u8(0);
            w.u32(0);
        }
        CompressionKind::RandK { k } => {
            w.u8(1);
            w.u32(k as u32);
        }
        CompressionKind::TopK { k } => {
            w.u8(2);
            w.u32(k as u32);
        }
        CompressionKind::Qsgd { levels } => {
            w.u8(3);
            w.u32(levels);
        }
        CompressionKind::EfRandK { k } => {
            w.u8(4);
            w.u32(k as u32);
        }
        CompressionKind::EfTopK { k } => {
            w.u8(5);
            w.u32(k as u32);
        }
        CompressionKind::EfQsgd { levels } => {
            w.u8(6);
            w.u32(levels);
        }
    }
}

fn decode_compression(r: &mut Reader) -> Result<CompressionKind> {
    let tag = r.u8()?;
    let param = r.u32()?;
    Ok(match tag {
        0 => CompressionKind::None,
        1 => CompressionKind::RandK { k: param as usize },
        2 => CompressionKind::TopK { k: param as usize },
        3 => CompressionKind::Qsgd { levels: param },
        4 => CompressionKind::EfRandK { k: param as usize },
        5 => CompressionKind::EfTopK { k: param as usize },
        6 => CompressionKind::EfQsgd { levels: param },
        other => bail!("unknown compression tag {other}"),
    })
}

/// One protocol message (see the module-level wire-format table).
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker → leader, first message after connect. `digest` is the
    /// worker's local config digest, or 0 when it has no local config and
    /// will trust `Hello`.
    Join { version: u8, device: u32, digest: u64 },
    /// Leader → worker handshake reply: identity, run shape, the device's
    /// private compression stream seed, and (optionally) the dataset.
    /// Doubles as the mid-run *Rejoin* reply: `resume_iter > 0` plus
    /// `iterate: Some(x)` admit a late joiner straight into a live run.
    Hello {
        version: u8,
        device: u32,
        n_devices: u32,
        dim: u32,
        /// This device plays the Byzantine role in the simulation (it
        /// uploads its true vector densely; the leader crafts its lie).
        /// Under role rotation this is only the *initial* role — the
        /// per-iteration bit in `Broadcast` is authoritative.
        byzantine: bool,
        /// Honest devices compress their own uplink (Com-LAD device-side)
        /// instead of shipping dense vectors for leader-side compression.
        device_compression: bool,
        comp_seed: u64,
        digest: u64,
        compression: CompressionKind,
        /// Byzantine roles rotate per iteration (watch the `Broadcast`
        /// role bit rather than trusting `byzantine` for the whole run).
        rotate: bool,
        /// Reinitialize compression stream + EF residual from `comp_seed`
        /// (a rejoin into a reclaimed slot); `false` on a leader-failover
        /// reconnect, where the worker keeps the state it already carries.
        reset_stream: bool,
        /// First iteration this device will serve (0 for a run start).
        resume_iter: u64,
        /// Current iterate, shipped on mid-run (re)joins so the device
        /// needs no history to serve the next broadcast.
        iterate: Option<Vec<f32>>,
        dataset: Option<DatasetBlock>,
    },
    /// Leader → worker, one per iteration: the iterate and the device's
    /// already-resolved subset list (the leader applies the cyclic task
    /// row and the slot permutation p^t before sending). `byzantine` is
    /// this device's role *for this iteration*; `cursor` (rotation under
    /// device-side compression only) is the compression-stream state the
    /// device must adopt before compressing this iteration's uplink.
    Broadcast {
        iter: u32,
        x: Vec<f32>,
        subsets: Vec<u32>,
        byzantine: bool,
        cursor: Option<RngState>,
    },
    /// Worker → leader: the coded (optionally compressed) uplink.
    /// `analytic_bits` is the operator's exact bit accounting for this
    /// message (0 when the payload is an uncompressed true vector).
    /// `cursor` echoes the worker's post-compression stream state when
    /// the leader asked for a hand-off via the `Broadcast` cursor.
    Upload {
        iter: u32,
        device: u32,
        analytic_bits: u64,
        cursor: Option<RngState>,
        payload: Payload,
    },
    /// Leader → worker: end of run.
    Shutdown,
}

impl Msg {
    /// Serialize to one frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(64);
        match self {
            Msg::Join { version, device, digest } => {
                w.u8(1);
                w.u8(*version);
                w.u32(*device);
                w.u64(*digest);
            }
            Msg::Hello {
                version,
                device,
                n_devices,
                dim,
                byzantine,
                device_compression,
                comp_seed,
                digest,
                compression,
                rotate,
                reset_stream,
                resume_iter,
                iterate,
                dataset,
            } => {
                w.u8(2);
                w.u8(*version);
                w.u32(*device);
                w.u32(*n_devices);
                w.u32(*dim);
                w.u8(u8::from(*byzantine));
                w.u8(u8::from(*device_compression));
                w.u64(*comp_seed);
                w.u64(*digest);
                encode_compression(*compression, &mut w);
                w.u8(u8::from(*rotate));
                w.u8(u8::from(*reset_stream));
                w.u64(*resume_iter);
                match iterate {
                    None => w.u8(0),
                    Some(x) => {
                        w.u8(1);
                        w.f32_slice(x);
                    }
                }
                match dataset {
                    None => w.u8(0),
                    Some(block) => {
                        w.u8(1);
                        block.encode_into(&mut w);
                    }
                }
            }
            Msg::Broadcast { iter, x, subsets, byzantine, cursor } => {
                w.u8(3);
                w.u32(*iter);
                w.f32_slice(x);
                w.u32(subsets.len() as u32);
                for &s in subsets {
                    w.u32(s);
                }
                w.u8(u8::from(*byzantine));
                w.opt_rng_state(cursor);
            }
            Msg::Upload { iter, device, analytic_bits, cursor, payload } => {
                w.u8(4);
                w.u32(*iter);
                w.u32(*device);
                w.u64(*analytic_bits);
                w.opt_rng_state(cursor);
                payload.encode_into(&mut w);
            }
            Msg::Shutdown => w.u8(5),
        }
        w.finish()
    }

    /// Parse one frame payload; the whole buffer must be consumed.
    pub fn decode(buf: &[u8]) -> Result<Msg> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            1 => Msg::Join { version: r.u8()?, device: r.u32()?, digest: r.u64()? },
            2 => {
                let version = r.u8()?;
                let device = r.u32()?;
                let n_devices = r.u32()?;
                let dim = r.u32()?;
                let byzantine = r.u8()? != 0;
                let device_compression = r.u8()? != 0;
                let comp_seed = r.u64()?;
                let digest = r.u64()?;
                let compression = decode_compression(&mut r)?;
                let rotate = r.u8()? != 0;
                let reset_stream = r.u8()? != 0;
                let resume_iter = r.u64()?;
                let iterate = match r.u8()? {
                    0 => None,
                    1 => Some(r.f32_vec()?),
                    other => bail!("bad iterate-presence byte {other}"),
                };
                let dataset = match r.u8()? {
                    0 => None,
                    1 => Some(DatasetBlock::decode(&mut r)?),
                    other => bail!("bad dataset-presence byte {other}"),
                };
                Msg::Hello {
                    version,
                    device,
                    n_devices,
                    dim,
                    byzantine,
                    device_compression,
                    comp_seed,
                    digest,
                    compression,
                    rotate,
                    reset_stream,
                    resume_iter,
                    iterate,
                    dataset,
                }
            }
            3 => Msg::Broadcast {
                iter: r.u32()?,
                x: r.f32_vec()?,
                subsets: r.u32_vec()?,
                byzantine: r.u8()? != 0,
                cursor: r.opt_rng_state()?,
            },
            4 => Msg::Upload {
                iter: r.u32()?,
                device: r.u32()?,
                analytic_bits: r.u64()?,
                cursor: r.opt_rng_state()?,
                payload: Payload::decode(&mut r)?,
            },
            5 => Msg::Shutdown,
            tag => bail!("unknown message tag {tag}"),
        };
        r.done()?;
        Ok(msg)
    }
}

// ---------------------------------------------------------------------------
// shared x-frame splice
// ---------------------------------------------------------------------------

/// The device-independent prefix of a `Broadcast` payload: tag, iteration
/// and the full iterate (`tag 3 | iter u32 | u32 len | len × f32`). The
/// leader encodes this once per iteration and shares it across all devices;
/// a per-device [`broadcast_tail`] completes the payload. By construction
/// `prefix ‖ tail` is byte-identical to
/// `Msg::Broadcast { iter, x, subsets, byzantine, cursor }.encode()`
/// (pinned by a test below), so a receiver cannot tell which path produced
/// its frame.
pub fn broadcast_prefix(iter: u32, x: &[f32]) -> Vec<u8> {
    let mut w = Writer::with_capacity(1 + 4 + 4 + 4 * x.len());
    w.u8(3);
    w.u32(iter);
    w.f32_slice(x);
    w.finish()
}

/// The per-device suffix of a `Broadcast` payload: the resolved subset list
/// (`u32 len | len × u32`), the per-iteration role bit and the optional
/// stream-cursor hand-off. See [`broadcast_prefix`].
pub fn broadcast_tail(subsets: &[u32], byzantine: bool, cursor: &Option<RngState>) -> Vec<u8> {
    let mut w = Writer::with_capacity(4 + 4 * subsets.len() + 2 + 26);
    w.u32(subsets.len() as u32);
    for &s in subsets {
        w.u32(s);
    }
    w.u8(u8::from(byzantine));
    w.opt_rng_state(cursor);
    w.finish()
}

// ---------------------------------------------------------------------------
// config digest
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit hash — shared by [`config_digest`] and the sweep engine's
/// content-addressed job ids (`sweep::spec`).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

/// FNV-1a digest of the semantic run configuration (and the wire version),
/// exchanged during the handshake so a leader and a worker launched with
/// diverging configs fail fast instead of training different problems.
/// Execution-local knobs (`threads`, the `[net]` table) are excluded — two
/// nodes may legitimately differ there.
pub fn config_digest(cfg: &TrainConfig) -> u64 {
    let canon = format!(
        "v{}|n{}|h{}|d{}|q{}|t{}|lr{:016x}|sh{:016x}|tf{:016x}|agg:{}|nnm{}|atk:{:?}|comp:{:?}|orc:{:?}|seed{:016x}|log{}",
        WIRE_VERSION,
        cfg.n_devices,
        cfg.n_honest,
        cfg.d,
        cfg.dim,
        cfg.iters,
        cfg.lr.to_bits(),
        cfg.sigma_h.to_bits(),
        cfg.trim_frac.to_bits(),
        cfg.aggregator.name(),
        cfg.nnm,
        cfg.attack,
        cfg.compression,
        cfg.oracle,
        cfg.seed,
        cfg.log_every,
    );
    fnv1a64(canon.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Identity, Qsgd, RandK, TopK};
    use crate::util::rng::Rng;

    fn round_trip(msg: &Msg) -> Msg {
        Msg::decode(&msg.encode()).unwrap()
    }

    #[test]
    fn join_and_shutdown_round_trip() {
        let j = Msg::Join { version: WIRE_VERSION, device: 17, digest: 0xDEAD_BEEF_0BAD_F00D };
        assert_eq!(round_trip(&j), j);
        assert_eq!(round_trip(&Msg::Shutdown), Msg::Shutdown);
    }

    #[test]
    fn hello_round_trip_with_and_without_dataset() {
        let mut rng = Rng::new(3);
        let ds = LinRegDataset::generate(5, 4, 0.3, &mut rng);
        for dataset in [None, Some(DatasetBlock::from_dataset(&ds))] {
            let h = Msg::Hello {
                version: WIRE_VERSION,
                device: 3,
                n_devices: 5,
                dim: 4,
                byzantine: true,
                device_compression: true,
                comp_seed: 42,
                digest: 7,
                compression: CompressionKind::Qsgd { levels: 16 },
                rotate: false,
                reset_stream: false,
                resume_iter: 0,
                iterate: None,
                dataset,
            };
            assert_eq!(round_trip(&h), h);
        }
    }

    #[test]
    fn rejoin_hello_round_trips_iterate_and_resume_fields() {
        let h = Msg::Hello {
            version: WIRE_VERSION,
            device: 2,
            n_devices: 6,
            dim: 3,
            byzantine: false,
            device_compression: true,
            comp_seed: 0xA5A5,
            digest: 9,
            compression: CompressionKind::EfTopK { k: 2 },
            rotate: true,
            reset_stream: true,
            resume_iter: 37,
            iterate: Some(vec![1.5, -0.25, 0.0]),
            dataset: None,
        };
        assert_eq!(round_trip(&h), h);
    }

    #[test]
    fn every_compression_kind_round_trips_in_hello() {
        for compression in [
            CompressionKind::None,
            CompressionKind::RandK { k: 5 },
            CompressionKind::TopK { k: 6 },
            CompressionKind::Qsgd { levels: 16 },
            CompressionKind::EfRandK { k: 5 },
            CompressionKind::EfTopK { k: 6 },
            CompressionKind::EfQsgd { levels: 16 },
        ] {
            let h = Msg::Hello {
                version: WIRE_VERSION,
                device: 0,
                n_devices: 4,
                dim: 8,
                byzantine: false,
                device_compression: true,
                comp_seed: 1,
                digest: 2,
                compression,
                rotate: false,
                reset_stream: false,
                resume_iter: 0,
                iterate: None,
                dataset: None,
            };
            assert_eq!(round_trip(&h), h, "{compression:?}");
        }
    }

    #[test]
    fn dataset_block_reconstructs_exactly() {
        let mut rng = Rng::new(9);
        let ds = LinRegDataset::generate(7, 6, 0.5, &mut rng);
        let back = DatasetBlock::from_dataset(&ds).into_dataset().unwrap();
        assert_eq!(back.z.data, ds.z.data);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.sigma_h, ds.sigma_h);
    }

    #[test]
    fn broadcast_and_upload_round_trip() {
        let cursors = [
            None,
            Some(RngState { state: 3, inc: 5, spare_gauss: None }),
            Some(RngState { state: 7, inc: 9, spare_gauss: Some(-1.25) }),
        ];
        for cursor in cursors {
            let b = Msg::Broadcast {
                iter: 12,
                x: vec![1.5, -2.25, 0.0],
                subsets: vec![4, 0, 2],
                byzantine: cursor.is_none(),
                cursor,
            };
            assert_eq!(round_trip(&b), b);
            let u = Msg::Upload {
                iter: 12,
                device: 2,
                analytic_bits: 999,
                cursor,
                payload: Payload::Sparse { dim: 6, idx: vec![1, 4], values: vec![2.0, -3.0] },
            };
            assert_eq!(round_trip(&u), u);
        }
    }

    #[test]
    fn payload_encodings_reconstruct_bit_identically() {
        let mut rng = Rng::new(11);
        let g: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.37).sin() * 5.0).collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(RandK::new(9)),
            Box::new(TopK::new(9)),
            Box::new(Qsgd::new(16)),
        ];
        for comp in &comps {
            let c = comp.compress(&g, &mut rng);
            let p = Payload::from_compressed(&c);
            let back = p.to_dense().unwrap();
            assert_eq!(back.len(), c.vec.len(), "{}", comp.name());
            for (a, b) in back.iter().zip(&c.vec) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", comp.name());
            }
        }
    }

    #[test]
    fn broadcast_splice_parts_concat_to_the_full_encoding() {
        let cases: [(u32, Vec<f32>, Vec<u32>, bool, Option<RngState>); 4] = [
            (0, vec![], vec![], false, None),
            (7, vec![1.5, -2.25, 0.0], vec![4, 0, 2], true, None),
            (
                11,
                vec![0.5],
                vec![1, 2],
                false,
                Some(RngState { state: 17, inc: 19, spare_gauss: Some(0.5) }),
            ),
            (u32::MAX, vec![f32::MIN_POSITIVE; 17], vec![9], false, None),
        ];
        for (iter, x, subsets, byzantine, cursor) in cases {
            let msg = Msg::Broadcast {
                iter,
                x: x.clone(),
                subsets: subsets.clone(),
                byzantine,
                cursor,
            };
            let mut spliced = broadcast_prefix(iter, &x);
            spliced.extend_from_slice(&broadcast_tail(&subsets, byzantine, &cursor));
            assert_eq!(spliced, msg.encode(), "iter {iter}");
        }
    }

    #[test]
    fn decode_into_matches_to_dense_over_stale_slabs() {
        let mut rng = Rng::new(21);
        let g: Vec<f32> = (0..96).map(|i| ((i as f32) * 0.61).cos() * 3.0).collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(RandK::new(7)),
            Box::new(TopK::new(7)),
            Box::new(Qsgd::new(16)),
        ];
        for comp in &comps {
            let c = comp.compress(&g, &mut rng);
            let p = Payload::from_compressed(&c);
            let dense = p.to_dense().unwrap();
            // slab row pre-filled with stale garbage: must be fully overwritten
            let mut row = vec![f32::NAN; p.dim()];
            p.decode_into(&mut row).unwrap();
            assert_eq!(
                row.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{}",
                comp.name()
            );
            // wrong-size slab is rejected, not silently truncated
            let mut bad = vec![0.0f32; p.dim() + 1];
            assert!(p.decode_into(&mut bad).is_err(), "{}", comp.name());
        }
        // zero-norm quantized payload also overwrites stale contents
        let c = Qsgd::new(4).compress(&[0.0f32; 10], &mut rng);
        let p = Payload::from_compressed(&c);
        let mut row = vec![9.0f32; 10];
        p.decode_into(&mut row).unwrap();
        assert_eq!(row, vec![0.0f32; 10]);
    }

    #[test]
    fn compact_payloads_are_actually_compact() {
        let mut rng = Rng::new(12);
        let g: Vec<f32> = (0..256).map(|i| (i as f32) * 0.01 - 1.0).collect();
        let dense = Identity.compress(&g, &mut rng);
        let dense_p = Payload::from_compressed(&dense);
        let sparse = RandK::new(16).compress(&g, &mut rng);
        let sparse_p = Payload::from_compressed(&sparse);
        let quant = Qsgd::new(8).compress(&g, &mut rng);
        let quant_p = Payload::from_compressed(&quant);
        let (d, s, q) = (dense_p.encoded_len(), sparse_p.encoded_len(), quant_p.encoded_len());
        assert!(s < d, "sparse {s} !< dense {d}");
        assert!(q < d, "quantized {q} !< dense {d}");
        // encoded_len is exact, not an estimate
        for p in [&dense_p, &sparse_p, &quant_p] {
            let mut w = Writer::with_capacity(0);
            p.encode_into(&mut w);
            assert_eq!(w.finish().len(), p.encoded_len());
        }
    }

    #[test]
    fn zero_norm_qsgd_payload_round_trips() {
        let mut rng = Rng::new(13);
        let c = Qsgd::new(4).compress(&[0.0f32; 10], &mut rng);
        let p = Payload::from_compressed(&c);
        assert!(matches!(p, Payload::Quantized { .. }));
        assert_eq!(p.to_dense().unwrap(), vec![0.0f32; 10]);
        // degenerate messages carry no per-coordinate bits on the wire
        assert_eq!(p.encoded_len(), 13, "header only");
        let msg = Msg::Upload {
            iter: 0,
            device: 0,
            analytic_bits: c.bits as u64,
            cursor: None,
            payload: p,
        };
        assert_eq!(Msg::decode(&msg.encode()).unwrap(), msg);
    }

    #[test]
    fn corrupt_payload_lengths_are_rejected() {
        // sparse with nnz > dim
        let mut w = Writer::with_capacity(16);
        w.u8(4); // Upload
        w.u32(0);
        w.u32(0);
        w.u64(0);
        w.u8(0); // no cursor
        w.u8(1); // Sparse
        w.u32(2); // dim
        w.u32(3); // nnz > dim
        for _ in 0..3 {
            w.u32(0);
            w.f32(0.0);
        }
        assert!(Msg::decode(&w.finish()).is_err());
        // truncated broadcast
        let b = Msg::Broadcast {
            iter: 0,
            x: vec![1.0; 8],
            subsets: vec![1, 2],
            byzantine: false,
            cursor: None,
        };
        let enc = b.encode();
        assert!(Msg::decode(&enc[..enc.len() - 3]).is_err());
        // trailing garbage
        let mut enc2 = b.encode();
        enc2.push(0xFF);
        assert!(Msg::decode(&enc2).is_err());
    }

    #[test]
    fn digest_tracks_semantic_fields_only() {
        let a = TrainConfig::default();
        let mut b = a.clone();
        assert_eq!(config_digest(&a), config_digest(&b));
        b.threads = 32; // execution-local: digest unchanged
        assert_eq!(config_digest(&a), config_digest(&b));
        b.d = a.d + 1; // semantic: digest changes
        assert_ne!(config_digest(&a), config_digest(&b));
        let mut c = a.clone();
        c.seed ^= 1;
        assert_ne!(config_digest(&a), config_digest(&c));
    }
}
