//! Message transports: one trait, three implementations.
//!
//! * [`ChannelTransport`] — in-process `mpsc` channels carrying *framed
//!   bytes* (not decoded structs), so the in-process cluster simulation
//!   exercises the exact codec + CRC path the socket transports use and
//!   pays the same byte accounting.
//! * [`TcpTransport`] — framed messages over a `TcpStream`
//!   (`TCP_NODELAY`; one `write_all` per frame).
//! * [`UdsTransport`] — the same over a Unix-domain socket (unix only).
//!
//! Addresses select the transport: `tcp://HOST:PORT` (or a bare
//! `HOST:PORT`) binds/connects TCP; `uds:PATH` (or `uds://PATH` /
//! `unix:PATH`) a Unix-domain socket. [`NetListener::bind`] +
//! [`connect`] are the only entry points the leader/worker loops need.
//!
//! [`Transport::split`] divides a connection into independently owned
//! send and receive halves (socket clones; channel halves), which is how
//! the leader runs one blocking reader thread per worker while sending
//! broadcasts from the training loop.

use super::frame;
use super::wire::Msg;
use crate::Result;
use anyhow::{anyhow, Context};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

/// A bidirectional, message-oriented connection. `send`/`recv` return the
/// number of wire bytes moved (frame header included) for byte accounting.
pub trait Transport: Send {
    /// Encode, frame and transmit one message; returns bytes written.
    fn send(&mut self, msg: &Msg) -> Result<u64>;
    /// Transmit one pre-built frame verbatim (header + payload, already
    /// [`frame::encode_frame`]d). The shared-x-frame broadcast path
    /// assembles per-device frames from a common prefix and hands them
    /// here, so the iterate is encoded once per iteration instead of once
    /// per device; the receiver cannot distinguish this from [`Transport::send`].
    /// Returns bytes written (= `fr.len()`).
    fn send_frame(&mut self, fr: &[u8]) -> Result<u64>;
    /// Block for the next message; returns it with the bytes read.
    fn recv(&mut self) -> Result<(Msg, u64)>;
    /// Split into `(send half, receive half)`. Each half supports only its
    /// own direction; using the wrong direction is an error.
    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>)>;
    /// Bound blocking sends: with a timeout set, a peer that stops
    /// draining its socket makes `send` error out instead of blocking the
    /// caller forever (the leader sets this in crash-tolerant mode). A
    /// no-op for in-process channels, whose queue is unbounded.
    fn set_send_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        let _ = t;
        Ok(())
    }
    /// Bound blocking receives: with a timeout set, `recv` errors out
    /// instead of waiting forever on a silent peer. The leader sets this
    /// during the Join handshake (`LeaderOpts::join_deadline`) so a stray
    /// connection that never joins cannot occupy a device slot, and
    /// clears it before the training loop's reader threads take over.
    fn set_recv_timeout(&mut self, t: Option<Duration>) -> Result<()>;
    /// Read up to `buf.len()` **raw** bytes — no frame header, no CRC.
    /// Returns the number of bytes read; `0` means the peer closed.
    /// Honors the receive timeout set by [`Transport::set_recv_timeout`]
    /// (a timeout is an error, as in [`Transport::recv`]). This is the
    /// read half of the status endpoint's newline protocol, where the
    /// peer may be a bare `nc`; training traffic stays framed.
    fn recv_raw(&mut self, buf: &mut [u8]) -> Result<usize>;
    /// Human-readable peer description for diagnostics.
    fn peer(&self) -> String;
}

// ---------------------------------------------------------------------------
// in-process channels
// ---------------------------------------------------------------------------

/// In-process transport: a cross-wired pair of byte channels. Frames (and
/// therefore CRCs and byte counts) are identical to the socket transports.
pub struct ChannelTransport {
    tx: Option<mpsc::Sender<Vec<u8>>>,
    rx: Option<mpsc::Receiver<Vec<u8>>>,
    recv_timeout: Option<Duration>,
    /// Undelivered tail of the last chunk [`Transport::recv_raw`] read:
    /// channel messages arrive whole, raw reads may want less.
    raw_pending: Vec<u8>,
}

impl ChannelTransport {
    /// A connected pair (leader half, worker half).
    pub fn pair() -> (ChannelTransport, ChannelTransport) {
        let (a_tx, b_rx) = mpsc::channel();
        let (b_tx, a_rx) = mpsc::channel();
        (
            ChannelTransport {
                tx: Some(a_tx),
                rx: Some(a_rx),
                recv_timeout: None,
                raw_pending: Vec::new(),
            },
            ChannelTransport {
                tx: Some(b_tx),
                rx: Some(b_rx),
                recv_timeout: None,
                raw_pending: Vec::new(),
            },
        )
    }
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &Msg) -> Result<u64> {
        let tx = self.tx.as_ref().context("send on a receive-only channel half")?;
        let bytes = frame::encode_frame(&msg.encode());
        let n = bytes.len() as u64;
        tx.send(bytes).map_err(|_| anyhow!("channel peer disconnected"))?;
        Ok(n)
    }

    fn send_frame(&mut self, fr: &[u8]) -> Result<u64> {
        let tx = self.tx.as_ref().context("send on a receive-only channel half")?;
        tx.send(fr.to_vec()).map_err(|_| anyhow!("channel peer disconnected"))?;
        Ok(fr.len() as u64)
    }

    fn recv(&mut self) -> Result<(Msg, u64)> {
        let rx = self.rx.as_ref().context("recv on a send-only channel half")?;
        let bytes = match self.recv_timeout {
            None => rx.recv().map_err(|_| anyhow!("channel peer disconnected"))?,
            Some(d) => match rx.recv_timeout(d) {
                Ok(b) => b,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    return Err(anyhow!("channel recv timed out after {d:?}"))
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("channel peer disconnected"))
                }
            },
        };
        let n = bytes.len() as u64;
        let payload = frame::decode_frame(&bytes)?;
        Ok((Msg::decode(payload)?, n))
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>)> {
        let me = *self;
        Ok((
            Box::new(ChannelTransport {
                tx: me.tx,
                rx: None,
                recv_timeout: None,
                raw_pending: Vec::new(),
            }),
            Box::new(ChannelTransport {
                tx: None,
                rx: me.rx,
                recv_timeout: me.recv_timeout,
                raw_pending: me.raw_pending,
            }),
        ))
    }

    fn set_recv_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.recv_timeout = t;
        Ok(())
    }

    fn recv_raw(&mut self, buf: &mut [u8]) -> Result<usize> {
        if self.raw_pending.is_empty() {
            let rx = self.rx.as_ref().context("recv on a send-only channel half")?;
            let chunk = match self.recv_timeout {
                None => match rx.recv() {
                    Ok(b) => b,
                    Err(_) => return Ok(0), // disconnect == EOF for raw reads
                },
                Some(d) => match rx.recv_timeout(d) {
                    Ok(b) => b,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        return Err(anyhow!("channel recv timed out after {d:?}"))
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => return Ok(0),
                },
            };
            self.raw_pending = chunk;
        }
        let n = buf.len().min(self.raw_pending.len());
        buf[..n].copy_from_slice(&self.raw_pending[..n]);
        self.raw_pending.drain(..n);
        Ok(n)
    }

    fn peer(&self) -> String {
        "channel".into()
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// Framed messages over TCP.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> TcpTransport {
        // latency matters more than throughput for per-iteration messages
        let _ = stream.set_nodelay(true);
        TcpTransport { stream }
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<u64> {
        let bytes = frame::encode_frame(&msg.encode());
        self.stream.write_all(&bytes).context("tcp send")?;
        Ok(bytes.len() as u64)
    }

    fn send_frame(&mut self, fr: &[u8]) -> Result<u64> {
        self.stream.write_all(fr).context("tcp send")?;
        Ok(fr.len() as u64)
    }

    fn recv(&mut self) -> Result<(Msg, u64)> {
        let (payload, n) = frame::read_frame(&mut self.stream, frame::MAX_PAYLOAD)?;
        Ok((Msg::decode(&payload)?, n))
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>)> {
        let clone = self.stream.try_clone().context("cloning tcp stream for split")?;
        Ok((
            Box::new(TcpTransport { stream: clone }),
            Box::new(TcpTransport { stream: self.stream }),
        ))
    }

    fn set_send_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.stream.set_write_timeout(t).context("setting tcp write timeout")?;
        Ok(())
    }

    fn set_recv_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(t).context("setting tcp read timeout")?;
        Ok(())
    }

    fn recv_raw(&mut self, buf: &mut [u8]) -> Result<usize> {
        self.stream.read(buf).context("tcp raw read")
    }

    fn peer(&self) -> String {
        self.stream
            .peer_addr()
            .map(|a| format!("tcp://{a}"))
            .unwrap_or_else(|_| "tcp://?".into())
    }
}

// ---------------------------------------------------------------------------
// Unix-domain sockets
// ---------------------------------------------------------------------------

/// Framed messages over a Unix-domain socket.
#[cfg(unix)]
pub struct UdsTransport {
    stream: std::os::unix::net::UnixStream,
    path: String,
}

#[cfg(unix)]
impl Transport for UdsTransport {
    fn send(&mut self, msg: &Msg) -> Result<u64> {
        let bytes = frame::encode_frame(&msg.encode());
        self.stream.write_all(&bytes).context("uds send")?;
        Ok(bytes.len() as u64)
    }

    fn send_frame(&mut self, fr: &[u8]) -> Result<u64> {
        self.stream.write_all(fr).context("uds send")?;
        Ok(fr.len() as u64)
    }

    fn recv(&mut self) -> Result<(Msg, u64)> {
        let (payload, n) = frame::read_frame(&mut self.stream, frame::MAX_PAYLOAD)?;
        Ok((Msg::decode(&payload)?, n))
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>)> {
        let clone = self.stream.try_clone().context("cloning uds stream for split")?;
        let path = self.path.clone();
        Ok((
            Box::new(UdsTransport { stream: clone, path }),
            Box::new(UdsTransport { stream: self.stream, path: self.path }),
        ))
    }

    fn set_send_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.stream.set_write_timeout(t).context("setting uds write timeout")?;
        Ok(())
    }

    fn set_recv_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(t).context("setting uds read timeout")?;
        Ok(())
    }

    fn recv_raw(&mut self, buf: &mut [u8]) -> Result<usize> {
        self.stream.read(buf).context("uds raw read")
    }

    fn peer(&self) -> String {
        format!("uds:{}", self.path)
    }
}

// ---------------------------------------------------------------------------
// address scheme + listener
// ---------------------------------------------------------------------------

enum Addr<'a> {
    Tcp(&'a str),
    Uds(&'a str),
}

fn parse_addr(addr: &str) -> Addr<'_> {
    for prefix in ["uds://", "unix://", "uds:", "unix:"] {
        if let Some(rest) = addr.strip_prefix(prefix) {
            return Addr::Uds(rest);
        }
    }
    Addr::Tcp(addr.strip_prefix("tcp://").unwrap_or(addr))
}

/// A bound accept socket for either transport. Dropping a UDS listener
/// removes its socket file.
pub enum NetListener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(std::os::unix::net::UnixListener, String),
}

impl NetListener {
    /// Bind `tcp://host:port` (port 0 picks a free port — read it back via
    /// [`NetListener::local_addr`]) or `uds:/path/to.sock` (a stale socket
    /// file at the path is removed first).
    pub fn bind(addr: &str) -> Result<NetListener> {
        match parse_addr(addr) {
            Addr::Tcp(hostport) => {
                let l = TcpListener::bind(hostport)
                    .with_context(|| format!("binding tcp listener on {hostport}"))?;
                Ok(NetListener::Tcp(l))
            }
            #[cfg(unix)]
            Addr::Uds(path) => {
                let _ = std::fs::remove_file(path);
                let l = std::os::unix::net::UnixListener::bind(path)
                    .with_context(|| format!("binding uds listener on {path}"))?;
                Ok(NetListener::Uds(l, path.to_string()))
            }
            #[cfg(not(unix))]
            Addr::Uds(path) => {
                Err(anyhow!("unix-domain sockets unavailable on this platform: {path}"))
            }
        }
    }

    /// The bound address in connectable form (`tcp://ip:port` / `uds:path`).
    pub fn local_addr(&self) -> Result<String> {
        match self {
            NetListener::Tcp(l) => Ok(format!("tcp://{}", l.local_addr()?)),
            #[cfg(unix)]
            NetListener::Uds(_, path) => Ok(format!("uds:{path}")),
        }
    }

    /// Accept one connection.
    pub fn accept(&self) -> Result<Box<dyn Transport>> {
        match self {
            NetListener::Tcp(l) => {
                let (stream, _) = l.accept().context("tcp accept")?;
                Ok(Box::new(TcpTransport::new(stream)))
            }
            #[cfg(unix)]
            NetListener::Uds(l, path) => {
                let (stream, _) = l.accept().context("uds accept")?;
                Ok(Box::new(UdsTransport { stream, path: path.clone() }))
            }
        }
    }

    /// Switch the accept socket between blocking and non-blocking mode.
    /// Non-blocking mode makes [`NetListener::try_accept`] usable from a
    /// polling acceptor thread that also has to observe a stop flag.
    pub fn set_nonblocking(&self, nonblocking: bool) -> Result<()> {
        match self {
            NetListener::Tcp(l) => l.set_nonblocking(nonblocking).context("tcp nonblocking"),
            #[cfg(unix)]
            NetListener::Uds(l, _) => {
                l.set_nonblocking(nonblocking).context("uds nonblocking")
            }
        }
    }

    /// Accept one connection if one is pending; `Ok(None)` when the
    /// listener is non-blocking and nobody is waiting. The accepted stream
    /// is always switched back to blocking mode regardless of what it
    /// inherited from the listener (platform-dependent).
    pub fn try_accept(&self) -> Result<Option<Box<dyn Transport>>> {
        match self {
            NetListener::Tcp(l) => match l.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).context("tcp accepted-stream blocking")?;
                    Ok(Some(Box::new(TcpTransport::new(stream))))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e).context("tcp accept"),
            },
            #[cfg(unix)]
            NetListener::Uds(l, path) => match l.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).context("uds accepted-stream blocking")?;
                    Ok(Some(Box::new(UdsTransport { stream, path: path.clone() })))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e).context("uds accept"),
            },
        }
    }
}

impl Drop for NetListener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let NetListener::Uds(_, path) = self {
            let _ = std::fs::remove_file(path.as_str());
        }
    }
}

/// Connect to a leader at `tcp://host:port` / `host:port` / `uds:path`.
pub fn connect(addr: &str) -> Result<Box<dyn Transport>> {
    match parse_addr(addr) {
        Addr::Tcp(hostport) => {
            let stream = TcpStream::connect(hostport)
                .with_context(|| format!("connecting to tcp leader at {hostport}"))?;
            Ok(Box::new(TcpTransport::new(stream)))
        }
        #[cfg(unix)]
        Addr::Uds(path) => {
            let stream = std::os::unix::net::UnixStream::connect(path)
                .with_context(|| format!("connecting to uds leader at {path}"))?;
            Ok(Box::new(UdsTransport { stream, path: path.to_string() }))
        }
        #[cfg(not(unix))]
        Addr::Uds(path) => Err(anyhow!("unix-domain sockets unavailable on this platform: {path}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_pair_round_trips_messages() {
        let (mut a, mut b) = ChannelTransport::pair();
        let msg = Msg::Broadcast {
            iter: 3,
            x: vec![1.0, 2.0],
            subsets: vec![0, 1],
            byzantine: false,
            cursor: None,
        };
        let sent = a.send(&msg).unwrap();
        let (got, read) = b.recv().unwrap();
        assert_eq!(got, msg);
        assert_eq!(sent, read);
        // and the reverse direction
        b.send(&Msg::Shutdown).unwrap();
        assert_eq!(a.recv().unwrap().0, Msg::Shutdown);
    }

    #[test]
    fn send_frame_is_indistinguishable_from_send() {
        let (mut a, mut b) = ChannelTransport::pair();
        let msg = Msg::Broadcast {
            iter: 9,
            x: vec![0.5, -1.0],
            subsets: vec![3],
            byzantine: true,
            cursor: None,
        };
        let f = frame::encode_frame(&msg.encode());
        let sent = a.send_frame(&f).unwrap();
        let (got, read) = b.recv().unwrap();
        assert_eq!(got, msg);
        assert_eq!(sent, f.len() as u64);
        assert_eq!(read, sent);
        // byte accounting matches the encode-and-send path exactly
        assert_eq!(a.send(&msg).unwrap(), sent);
        assert_eq!(b.recv().unwrap().0, msg);
    }

    #[test]
    fn channel_split_enforces_directions() {
        let (a, mut b) = ChannelTransport::pair();
        let (mut tx, mut rx) = (Box::new(a) as Box<dyn Transport>).split().unwrap();
        assert!(tx.recv().is_err());
        assert!(rx.send(&Msg::Shutdown).is_err());
        tx.send(&Msg::Shutdown).unwrap();
        assert_eq!(b.recv().unwrap().0, Msg::Shutdown);
        b.send(&Msg::Join { version: 1, device: 0, digest: 0 }).unwrap();
        assert!(matches!(rx.recv().unwrap().0, Msg::Join { .. }));
    }

    #[test]
    fn channel_disconnect_is_an_error() {
        let (mut a, b) = ChannelTransport::pair();
        drop(b);
        assert!(a.send(&Msg::Shutdown).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn tcp_loopback_round_trip() {
        let listener = NetListener::bind("tcp://127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut t = connect(&addr).unwrap();
            t.send(&Msg::Join { version: 1, device: 5, digest: 9 }).unwrap();
            t.recv().unwrap().0
        });
        let mut server = listener.accept().unwrap();
        let (msg, _) = server.recv().unwrap();
        assert_eq!(msg, Msg::Join { version: 1, device: 5, digest: 9 });
        server.send(&Msg::Shutdown).unwrap();
        assert_eq!(h.join().unwrap(), Msg::Shutdown);
    }

    #[cfg(unix)]
    #[test]
    fn uds_loopback_round_trip() {
        let path = std::env::temp_dir().join(format!("lad_uds_rt_{}.sock", std::process::id()));
        let addr = format!("uds:{}", path.display());
        let listener = NetListener::bind(&addr).unwrap();
        let addr2 = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut t = connect(&addr2).unwrap();
            t.send(&Msg::Shutdown).unwrap();
        });
        let mut server = listener.accept().unwrap();
        assert_eq!(server.recv().unwrap().0, Msg::Shutdown);
        h.join().unwrap();
        drop(server);
        drop(listener); // removes the socket file
        assert!(!path.exists());
    }

    #[test]
    fn channel_recv_timeout_fires_and_clears() {
        let (a, mut b) = ChannelTransport::pair();
        let mut a = Box::new(a) as Box<dyn Transport>;
        a.set_recv_timeout(Some(Duration::from_millis(20))).unwrap();
        assert!(a.recv().is_err(), "silent peer must time out");
        a.set_recv_timeout(None).unwrap();
        b.send(&Msg::Shutdown).unwrap();
        assert_eq!(a.recv().unwrap().0, Msg::Shutdown);
        // the timeout survives a split onto the receive half
        let mut c = Box::new(b) as Box<dyn Transport>;
        c.set_recv_timeout(Some(Duration::from_millis(20))).unwrap();
        let (_tx, mut rx) = c.split().unwrap();
        assert!(rx.recv().is_err(), "split receive half keeps the timeout");
    }

    #[test]
    fn try_accept_polls_without_blocking() {
        let listener = NetListener::bind("tcp://127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        assert!(listener.try_accept().unwrap().is_none(), "no pending connection");
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut t = connect(&addr).unwrap();
            t.send(&Msg::Shutdown).unwrap();
        });
        // poll until the connection lands
        let mut server = loop {
            if let Some(t) = listener.try_accept().unwrap() {
                break t;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        // the accepted stream is blocking even though the listener is not
        assert_eq!(server.recv().unwrap().0, Msg::Shutdown);
        h.join().unwrap();
    }

    #[test]
    fn recv_raw_reads_unframed_bytes() {
        // channel half: chunk split across short reads, then EOF
        let (mut a, mut b) = ChannelTransport::pair();
        a.send_frame(b"WATCH\nrest").unwrap();
        let mut buf = [0u8; 6];
        assert_eq!(b.recv_raw(&mut buf).unwrap(), 6);
        assert_eq!(&buf, b"WATCH\n");
        let mut buf = [0u8; 16];
        assert_eq!(b.recv_raw(&mut buf).unwrap(), 4);
        assert_eq!(&buf[..4], b"rest");
        drop(a);
        assert_eq!(b.recv_raw(&mut buf).unwrap(), 0, "disconnect is EOF");

        // tcp: raw bytes pass through with no frame header, timeout honored
        let listener = NetListener::bind("tcp://127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut t = connect(&addr).unwrap();
            t.send_frame(b"hello").unwrap();
            t // keep the connection open until the reader is done
        });
        let mut server = listener.accept().unwrap();
        let mut buf = [0u8; 5];
        assert_eq!(server.recv_raw(&mut buf).unwrap(), 5);
        assert_eq!(&buf, b"hello");
        server.set_recv_timeout(Some(Duration::from_millis(20))).unwrap();
        assert!(server.recv_raw(&mut buf).is_err(), "silent peer must time out");
        drop(h.join().unwrap());
    }

    #[test]
    fn addr_scheme_parses() {
        assert!(matches!(parse_addr("tcp://1.2.3.4:5"), Addr::Tcp("1.2.3.4:5")));
        assert!(matches!(parse_addr("1.2.3.4:5"), Addr::Tcp("1.2.3.4:5")));
        assert!(matches!(parse_addr("uds:/tmp/x.sock"), Addr::Uds("/tmp/x.sock")));
        assert!(matches!(parse_addr("uds:///tmp/x.sock"), Addr::Uds("/tmp/x.sock")));
        assert!(matches!(parse_addr("unix:/tmp/x.sock"), Addr::Uds("/tmp/x.sock")));
    }
}
