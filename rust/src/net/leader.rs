//! The server-side event loop (Fig. 1, left-hand side; Algorithms 1–2
//! over real connections).
//!
//! [`Leader::run`] drives one training run over any set of [`Transport`]
//! connections — in-process channels (the refactored `server::cluster`),
//! TCP, or Unix-domain sockets (`lad node-leader`). Per iteration it
//! draws the random assignment (T^t, p^t), broadcasts the iterate plus
//! each device's resolved subset list, gathers the coded uplinks, emulates
//! the Byzantine devices (crafting their lies centrally from the gathered
//! messages — the omniscient adversary cannot live on a real node),
//! compresses whatever is still uncompressed, aggregates with the
//! configured κ-robust rule and steps the model.
//!
//! **Gather deadline.** With [`LeaderOpts::gather_deadline`] set, a
//! stalled (crash-Byzantine) worker cannot hang an iteration: when the
//! deadline expires the leader proceeds with the messages it has, counts
//! the missing devices as anomalies, and keeps the run alive — exactly
//! the partial-participation stress the robust aggregators are built to
//! absorb. Late uploads for old iterations are discarded by iteration
//! tag. Without a deadline (the default, and the trace-parity mode) the
//! leader waits for every device, and a disconnect is an error.
//!
//! **Join handshakes.** [`Leader::serve`] owns the accept loop and runs
//! one short-lived thread per accepted connection for the `Join`
//! handshake, so a slow or silent connector can never block other
//! devices from joining. [`LeaderOpts::join_deadline`] is an overall
//! per-handshake wall-clock budget: it bounds the whole handshake (the
//! read timeout is re-checked against elapsed time once the `Join`
//! lands), so a deliberate byte-at-a-time trickler is cut off at the
//! deadline too. A connection that fails validation — bad version,
//! out-of-range device id, config digest mismatch, or a claimed slot —
//! is dropped with a log line and its slot stays open.
//!
//! **Elastic membership.** Three mechanisms compose so the roster can
//! change mid-run without disturbing the incumbents' RNG streams:
//!
//! * *Mid-run join*: the accept loop keeps running after the roster
//!   fills, and a late `Join` naming a **retired** slot is re-admitted.
//!   The rejoin `Hello` ships the dataset shard (serve mode), the current
//!   iterate, the iteration counter, and a fresh compression-stream seed
//!   derived from the slot's base seed and a per-slot rejoin epoch
//!   ([`rejoin_seed`] — a splitmix64 finalizer, never a run-RNG draw, so
//!   no-churn traces stay bit-identical). The slot's EF residual and
//!   miss streak reset; the device serves from the next broadcast.
//! * *Checkpointed warm restart*: [`LeaderOpts::checkpoint_every`] > 0
//!   writes an atomic (tmp + rename) [`Checkpoint`] every K iterations
//!   carrying the run-RNG cursor, the per-device compression-stream
//!   cursors, the EF residual mirror, the aggregator's momentum state,
//!   the roster bitmap and the trace so far. [`Leader::resume`] /
//!   [`Leader::serve_resume`] restart from it; the cut sits after
//!   craft(t) and before the staged draw(t+1), so resumed runs consume
//!   the run RNG in exactly the uninterrupted order whether or not the
//!   pipeline is on. Resume handshake bytes are *not* counted, so the
//!   final trace's wire totals are bit-identical to an uninterrupted
//!   run's (leader-side compression; under device-side compression a
//!   reconnecting worker must carry its own live stream —
//!   `reset_stream: false` — which the failover drill exercises).
//! * *Role rotation*: [`LeaderOpts::rotate_byzantine`] redraws the
//!   Byzantine identity set each iteration (one run-RNG draw, same
//!   order as the central trainer) and announces each device's role in
//!   its `Broadcast`. Under device-side compression the broadcast also
//!   hands the leader's mirror cursor to honest-role devices and the
//!   `Upload` echoes the post-compression cursor back, keeping every
//!   stream consumed exactly once per iteration regardless of who
//!   compressed. Rotation + device compression + error feedback is
//!   rejected at startup (a residual is tied to an honest stream).
//!
//! **Determinism.** With every device live, traces are bit-identical to
//! `Trainer::run`'s central fast path: the leader consumes the run RNG in
//! the same order (assignment, then Byzantine identities, then attack
//! crafting — fixed identities consume nothing), per-device compression
//! randomness comes from the same pre-split streams (`Rng::split_seeds`),
//! messages enter the aggregation family in device-id order, and the wire
//! codec reconstructs every message bit-exactly.
//!
//! **Pipeline.** By default ([`LeaderOpts::pipeline`]) the leader runs the
//! iteration as a software pipeline: the Q-sized iterate section of the
//! `Broadcast` is encoded **once** per iteration
//! ([`super::wire::broadcast_prefix`]) and each device's frame splices its
//! tiny subset/role/cursor tail on ([`super::wire::broadcast_tail`] +
//! [`super::frame::encode_frame_parts`]), with frame assembly and the
//! socket writes fanned out on [`Leader::pool`]; uplinks decode straight
//! into a contiguous per-device slab; and the next iteration's assignment,
//! identity set and subset tails are drawn into a staging buffer while the
//! current iteration is still aggregating. The staged draw sits **after**
//! the current iteration's attack craft, so the run RNG sees
//! `draw(0), byz(0), craft(0), draw(1), …` — exactly the phase-serial
//! order — and every byte on the wire is identical to the per-device
//! encoding (`pipeline: false`). Both invariants are pinned by
//! `tests/fuzz_determinism.rs` and `tests/net_cluster.rs`.
//!
//! **Error feedback.** Under an `ef-*` compression kind the leader keeps
//! an [`EfState`] mirror: under leader-side compression it holds every
//! device's residual; under device-side compression honest workers hold
//! their own rows (`net::worker`) and the leader steps only the Byzantine
//! rows when compressing the crafted lies. Residual-reset semantics,
//! pinned by `tests/net_cluster.rs`: a device that merely misses a gather
//! deadline keeps its residual (mirroring its untouched RNG stream), but
//! a **retired** device's residual is zeroed the moment it is dropped —
//! and a rejoin starts from a zero residual — so a slot can never replay
//! stale memory.

use super::frame::encode_frame_parts;
use super::transport::{NetListener, Transport};
use super::wire::{
    broadcast_prefix, broadcast_tail, config_digest, DatasetBlock, Msg, WIRE_VERSION,
};
use crate::aggregation::Aggregator;
use crate::attack::{Attack, AttackContext};
use crate::coding::{Assignment, TaskMatrix};
use crate::compress::{compress_batch, compress_batch_ef, Compressor, EfState};
use crate::config::{CompressionKind, TrainConfig};
use crate::data::linreg::LinRegDataset;
use crate::obs::{Event, Obs};
use crate::server::checkpoint::{Checkpoint, RosterEntry, TraceBlock};
use crate::server::metrics::TrainTrace;
use crate::server::trainer::byz_set;
use crate::util::math::norm;
use crate::util::parallel::Pool;
use crate::util::rng::{Rng, RngState};
use crate::util::timer::Timer;
use crate::Result;
use anyhow::{anyhow, bail, ensure, Context};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Consecutive gather-deadline misses after which a device is retired
/// (deadline mode): a permanently stalled worker costs this many timeouts
/// total, not one per remaining iteration — and its broadcast queue stops
/// growing once it is dead.
pub const MISS_RETIRE_STREAK: usize = 3;

/// Salt folded into a slot's base compression seed when deriving
/// rejoin-epoch seeds — see [`rejoin_seed`].
const REJOIN_SEED_SALT: u64 = 0xE1A5_71C0_5EED_0001;

/// Fresh compression-stream seed for rejoin epoch `epoch` of the slot
/// whose base seed is `base`: a splitmix64 finalizer over the pair, so it
/// is deterministic, disjoint across epochs, and — crucially — consumes
/// nothing from the run RNG (no-churn traces stay bit-identical).
fn rejoin_seed(base: u64, epoch: u64) -> u64 {
    let mut z = base ^ REJOIN_SEED_SALT ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One reader-thread event: `(device, rejoin_epoch, payload)`; a `None`
/// payload means the connection died. The epoch tag lets the gather loop
/// discard ghost events from a connection that a rejoin has since
/// replaced (the old reader thread may outlive its slot).
type RxEvent = (usize, u64, Option<(Msg, u64)>);

/// A validated mid-run `Join` waiting for admission into a retired slot.
/// The `Join` frame has **already been consumed** from `link` by whoever
/// produced the request (the serve accept loop's handshake thread, or an
/// in-process churn harness).
pub struct RejoinRequest {
    /// The slot the connector asked for.
    pub device: usize,
    /// Earliest iteration at which the leader may activate the slot
    /// (0 = as soon as it is free) — lets tests pin churn timing.
    pub not_before: u64,
    /// Bytes of the already-consumed `Join` frame (uplink accounting).
    pub join_bytes: u64,
    /// The connection, positioned just after its `Join`.
    pub link: Box<dyn Transport>,
}

/// Retire a device mid-run (deadline mode only): it is never broadcast to
/// again, its EF residual (when error feedback is active) is zeroed so the
/// slot can never replay stale memory, and if its upload was still pending
/// this iteration the miss is charged to the trace as an anomaly
/// immediately so the gather can stop waiting on it. The retirement —
/// with the structured `reason` the old silent path dropped — is
/// journaled as a [`Event::DeviceRetired`] and counted in
/// `trace.retirements`.
#[allow(clippy::too_many_arguments)]
fn drop_device(
    dev: usize,
    iter: u64,
    reason: &str,
    dead: &mut [bool],
    expecting: &mut [bool],
    have: &[Option<u64>],
    want: &mut usize,
    trace: &mut TrainTrace,
    ef: Option<&mut EfState>,
    obs: &Obs,
) {
    if !dead[dev] {
        trace.retirements += 1;
        if obs.enabled() {
            obs.emit(Event::DeviceRetired { device: dev, iter, reason: reason.to_string() });
            if let Some(st) = obs.status() {
                st.device_retired(dev);
            }
        }
    }
    dead[dev] = true;
    if let Some(st) = ef {
        st.reset(dev);
    }
    if expecting[dev] && have[dev].is_none() {
        expecting[dev] = false;
        trace.anomalies += 1;
        *want -= 1;
    }
}

/// Leader-side policy knobs that are not part of the training semantics.
#[derive(Debug, Clone)]
pub struct LeaderOpts {
    /// Per-iteration gather budget. `None` waits for every device.
    pub gather_deadline: Option<Duration>,
    /// Honest devices compress their own uplink (Com-LAD device-side);
    /// `false` reproduces the leader-side compression of the historical
    /// cluster simulation (and keeps omniscient attacks exact).
    pub device_compression: bool,
    /// Overall wall-clock budget for each `Join` handshake. `None` waits
    /// forever (the trusting default for pre-connected in-process
    /// links). Under [`Leader::serve`] every handshake runs on its own
    /// thread, so one slow connector never delays another; the budget
    /// bounds the whole handshake, not just a single read.
    pub join_deadline: Option<Duration>,
    /// Pipelined iteration scheduling (the default): shared x-frame
    /// broadcast with pool-parallel frame assembly, slab uplink decode,
    /// and double-buffered staging of the next assignment's subset tails.
    /// `false` selects the phase-serial schedule — kept as the reference
    /// implementation the pipeline is pinned bit-identical to. Pure
    /// scheduling: traces, wire bytes and RNG consumption are unaffected,
    /// so the toggle is deliberately outside `config_digest` and the
    /// sweep job identity.
    pub pipeline: bool,
    /// Redraw the Byzantine identity set each iteration (one run-RNG
    /// draw) and announce each device's role in its `Broadcast` frame.
    /// `false` (the default) keeps the fixed last-(N−H) identities and
    /// consumes nothing, preserving historical traces bit-for-bit.
    pub rotate_byzantine: bool,
    /// Write a [`Checkpoint`] every K iterations (0 = off). Requires
    /// [`LeaderOpts::checkpoint_path`].
    pub checkpoint_every: u64,
    /// Where checkpoints land (written atomically: tmp + rename).
    pub checkpoint_path: Option<PathBuf>,
    /// Halt with an error — *without* sending `Shutdown`, so workers stay
    /// up and reconnect — after completing iteration K and writing a
    /// final checkpoint: the leader-kill half of the failover drill.
    pub halt_after: Option<u64>,
    /// Observability context ([`Obs::off`] by default): event journal,
    /// metrics registry, span profiler, live status endpoint.
    /// Wall-clock telemetry only — traces, wire bytes, RNG order and
    /// checkpoints are bit-identical with it on or off (fuzz-pinned).
    pub obs: Obs,
}

impl Default for LeaderOpts {
    fn default() -> Self {
        LeaderOpts {
            gather_deadline: None,
            device_compression: false,
            join_deadline: None,
            pipeline: true,
            rotate_byzantine: false,
            checkpoint_every: 0,
            checkpoint_path: None,
            halt_after: None,
            obs: Obs::off(),
        }
    }
}

/// Mutable loop state threaded into [`Leader::train`] — fresh for a cold
/// start, reconstructed from a [`Checkpoint`] for a warm restart.
struct TrainInit {
    start_iter: usize,
    comp_cursors: Option<Vec<RngState>>,
    ef_rows: Option<Vec<Vec<f32>>>,
    dead: Vec<bool>,
    miss_streak: Vec<usize>,
    rejoin_epoch: Vec<u64>,
    trace: TrainTrace,
    bits_total: u64,
    wire_up: u64,
    wire_down: u64,
}

impl TrainInit {
    fn fresh(n: usize, label: &str) -> Self {
        TrainInit {
            start_iter: 0,
            comp_cursors: None,
            ef_rows: None,
            dead: vec![false; n],
            miss_streak: vec![0; n],
            rejoin_epoch: vec![0; n],
            trace: TrainTrace::new(label),
            bits_total: 0,
            wire_up: 0,
            wire_down: 0,
        }
    }
}

/// The in-flight trace + byte counters, as a checkpoint trace section.
fn trace_to_block(tr: &TrainTrace, bits_total: u64, up: u64, down: u64) -> TraceBlock {
    TraceBlock {
        label: tr.label.clone(),
        iters: tr.iters.iter().map(|&i| i as u64).collect(),
        loss: tr.loss.clone(),
        grad_update_norm: tr.grad_update_norm.clone(),
        bits: tr.bits.clone(),
        anomalies: tr.anomalies as u64,
        bits_total,
        wire_up_bytes: up,
        wire_down_bytes: down,
    }
}

/// Inverse of [`trace_to_block`]: `(trace, bits_total, wire_up, wire_down)`.
/// Phase timings are telemetry, not state — they restart from zero, and so
/// do the deadline-miss / retirement / rejoin breakdown counters.
fn block_to_trace(b: &TraceBlock) -> (TrainTrace, u64, u64, u64) {
    let mut tr = TrainTrace::new(b.label.clone());
    tr.iters = b.iters.iter().map(|&i| i as usize).collect();
    tr.loss = b.loss.clone();
    tr.grad_update_norm = b.grad_update_norm.clone();
    tr.bits = b.bits.clone();
    tr.anomalies = b.anomalies as usize;
    (tr, b.bits_total, b.wire_up_bytes, b.wire_down_bytes)
}

/// Spawn the detached reader thread for one device connection, tagging
/// every forwarded event with the slot's current rejoin epoch.
fn spawn_reader(
    dev: usize,
    epoch: u64,
    mut rx_half: Box<dyn Transport>,
    fwd: mpsc::Sender<RxEvent>,
) -> Result<()> {
    std::thread::Builder::new()
        .name(format!("lad-net-rx-{dev}"))
        .spawn(move || loop {
            match rx_half.recv() {
                Ok(item) => {
                    if fwd.send((dev, epoch, Some(item))).is_err() {
                        return;
                    }
                }
                Err(_) => {
                    let _ = fwd.send((dev, epoch, None));
                    return;
                }
            }
        })
        .context("spawning reader thread")?;
    Ok(())
}

/// Validate one `Join` message; returns the claimed device id.
fn validate_join(msg: &Msg, n: usize, digest: u64) -> Result<usize> {
    let (version, device, worker_digest) = match msg {
        Msg::Join { version, device, digest } => (*version, *device, *digest),
        other => bail!("expected join, got {other:?}"),
    };
    ensure!(
        version == WIRE_VERSION,
        "protocol version mismatch: worker {version}, leader {WIRE_VERSION}"
    );
    let device = device as usize;
    ensure!(device < n, "worker joined as device {device}, config has {n}");
    ensure!(
        worker_digest == 0 || worker_digest == digest,
        "config digest mismatch: worker {device} has {worker_digest:#018x}, \
         leader {digest:#018x}"
    );
    Ok(device)
}

/// Run one `Join` handshake on a freshly accepted connection, within an
/// overall wall-clock `budget`, and forward the validated connection.
/// Runs on its own detached thread so a slow or trickling connector never
/// blocks the accept loop or any other handshake. Failed handshakes are
/// logged and dropped; the slot stays open.
fn handshake_join(
    mut link: Box<dyn Transport>,
    n: usize,
    digest: u64,
    budget: Option<Duration>,
    out: mpsc::Sender<RejoinRequest>,
) {
    let peer = link.peer();
    let t0 = Instant::now();
    if budget.is_some() && link.set_recv_timeout(budget).is_err() {
        return;
    }
    let (msg, join_bytes) = match link.recv() {
        Ok(v) => v,
        Err(e) => {
            eprintln!("leader: dropping connection from {peer}: {e:#} — slot reclaimed");
            return;
        }
    };
    if let Some(d) = budget {
        // the recv timeout bounds each read; re-check the overall budget
        // so a byte-at-a-time trickler is cut off at the deadline too
        if t0.elapsed() > d {
            eprintln!("leader: dropping {peer}: handshake exceeded {d:?} — slot reclaimed");
            return;
        }
        if link.set_recv_timeout(None).is_err() {
            return;
        }
    }
    match validate_join(&msg, n, digest) {
        Ok(device) => {
            let _ = out.send(RejoinRequest { device, not_before: 0, join_bytes, link });
        }
        Err(e) => {
            eprintln!("leader: dropping connection from {peer}: {e:#} — slot reclaimed")
        }
    }
}

/// The server of a multi-node run: configuration, dataset, and the
/// injected aggregation rule / attack / compression operator.
pub struct Leader<'a> {
    pub cfg: &'a TrainConfig,
    pub ds: &'a LinRegDataset,
    pub agg: &'a dyn Aggregator,
    pub attack: &'a dyn Attack,
    pub comp: &'a dyn Compressor,
    pub opts: LeaderOpts,
    /// Worker pool for the leader-side compression batch (share a budgeted
    /// slice via [`Pool::borrow`] to respect a process-level thread budget).
    pub pool: Pool,
    /// Ship the dataset in `Hello` (remote workers); the in-process
    /// cluster passes `false` and workers borrow the leader's copy.
    pub send_dataset: bool,
}

impl Leader<'_> {
    /// Shape + option checks shared by every entry point.
    fn check_shapes(&self, x0: &[f32]) -> Result<()> {
        let cfg = self.cfg;
        cfg.validate()?;
        let n = cfg.n_devices;
        ensure!(self.ds.n() == n, "dataset has {} subsets, config {n}", self.ds.n());
        ensure!(self.ds.dim() == cfg.dim, "dataset dim {} != config {}", self.ds.dim(), cfg.dim);
        ensure!(x0.len() == cfg.dim, "x0 dim {} != config {}", x0.len(), cfg.dim);
        let ef_kind = matches!(
            cfg.compression,
            CompressionKind::EfRandK { .. }
                | CompressionKind::EfTopK { .. }
                | CompressionKind::EfQsgd { .. }
        );
        ensure!(
            !(self.opts.rotate_byzantine && self.opts.device_compression && ef_kind),
            "rotate-byzantine + device compression is incompatible with error-feedback \
             compressors: a residual is tied to its device's honest stream, which a \
             rotating role bit would corrupt"
        );
        if self.opts.checkpoint_every > 0 || self.opts.halt_after.is_some() {
            ensure!(
                self.opts.checkpoint_path.is_some(),
                "checkpoint_every / halt_after require a checkpoint_path"
            );
        }
        Ok(())
    }

    /// Receive and validate one `Join` (honoring the join deadline);
    /// returns the claimed device id and the bytes read. The recv timeout
    /// is cleared again before the link joins the training loop, whose
    /// reader threads must block indefinitely.
    fn recv_join(&self, link: &mut Box<dyn Transport>, digest: u64) -> Result<(usize, u64)> {
        if let Some(d) = self.opts.join_deadline {
            link.set_recv_timeout(Some(d))?;
        }
        let (msg, nb) = link.recv().context("waiting for a worker join")?;
        if self.opts.join_deadline.is_some() {
            link.set_recv_timeout(None)?;
        }
        let device = validate_join(&msg, self.cfg.n_devices, digest)
            .with_context(|| format!("join from {}", link.peer()))?;
        Ok((device, nb))
    }

    /// Send the `Hello` that completes one device's handshake; returns
    /// bytes written. `resume_iter` / `iterate` / `reset_stream` turn it
    /// into the mid-run rejoin or warm-restart reply (see `net::wire`).
    fn send_hello(
        &self,
        link: &mut dyn Transport,
        device: usize,
        digest: u64,
        comp_seed: u64,
        reset_stream: bool,
        resume_iter: u64,
        iterate: Option<Vec<f32>>,
    ) -> Result<u64> {
        let cfg = self.cfg;
        let hello = Msg::Hello {
            version: WIRE_VERSION,
            device: device as u32,
            n_devices: cfg.n_devices as u32,
            dim: cfg.dim as u32,
            byzantine: device >= cfg.n_honest,
            device_compression: self.opts.device_compression,
            comp_seed,
            digest,
            compression: cfg.compression,
            rotate: self.opts.rotate_byzantine,
            reset_stream,
            resume_iter,
            iterate,
            dataset: if self.send_dataset {
                Some(DatasetBlock::from_dataset(self.ds))
            } else {
                None
            },
        };
        link.send(&hello)
    }

    /// Handshake every pre-established connection, then run `cfg.iters`
    /// iterations of Algorithm 1/2 and return the metric trace (final
    /// iterate in `x0`). A handshake failure — including a join-deadline
    /// expiry — is an error here, since the fixed link set leaves no way
    /// to refill the slot; use [`Leader::serve`] to own the accept loop
    /// and reclaim slots instead.
    pub fn run(
        &self,
        links: Vec<Box<dyn Transport>>,
        x0: &mut Vec<f32>,
        label: &str,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        self.run_rejoin(links, None, x0, label, rng)
    }

    /// [`Leader::run`] plus an optional intake channel for mid-run joins:
    /// the in-process churn harness pre-loads replacement connections
    /// (with a `not_before` activation iteration) through `rejoin`.
    pub fn run_rejoin(
        &self,
        links: Vec<Box<dyn Transport>>,
        rejoin: Option<&mpsc::Receiver<RejoinRequest>>,
        x0: &mut Vec<f32>,
        label: &str,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        let cfg = self.cfg;
        self.check_shapes(x0)?;
        let n = cfg.n_devices;
        ensure!(links.len() == n, "need {n} connections, got {}", links.len());
        let digest = config_digest(cfg);
        // Same pre-split per-device compression streams as Trainer::run —
        // the seeds go to honest devices in Hello (device-side mode), the
        // leader keeps the streams for everything it compresses itself.
        let comp_seeds = rng.split_seeds(n);
        let mut init = TrainInit::fresh(n, label);

        // ---- handshake: Join in, Hello out, order links by device id ----
        let mut by_dev: Vec<Option<Box<dyn Transport>>> = (0..n).map(|_| None).collect();
        for mut link in links {
            let (device, nb) = self.recv_join(&mut link, digest)?;
            init.wire_up += nb;
            ensure!(by_dev[device].is_none(), "device {device} joined twice");
            init.wire_down +=
                self.send_hello(link.as_mut(), device, digest, comp_seeds[device], false, 0, None)?;
            by_dev[device] = Some(link);
        }
        self.train(by_dev, &comp_seeds, init, rejoin, x0, rng)
    }

    /// Warm restart over pre-established links: reconstructs the loop
    /// state from `ckpt`, handshakes every link with a resume `Hello`
    /// (`reset_stream: false` — a reconnecting worker keeps any live
    /// stream state it carries), and continues training. Resume handshake
    /// bytes are **not** counted, so the finished trace's wire totals are
    /// bit-identical to an uninterrupted run's.
    pub fn resume(
        &self,
        links: Vec<Box<dyn Transport>>,
        ckpt: &Checkpoint,
        x0: &mut Vec<f32>,
        label: &str,
    ) -> Result<TrainTrace> {
        let n = self.cfg.n_devices;
        ensure!(links.len() == n, "need {n} connections, got {}", links.len());
        let (comp_seeds, mut rng, init) = self.resume_init(ckpt, label, x0)?;
        let digest = config_digest(self.cfg);
        let mut by_dev: Vec<Option<Box<dyn Transport>>> = (0..n).map(|_| None).collect();
        for mut link in links {
            let (device, _nb) = self.recv_join(&mut link, digest)?;
            ensure!(by_dev[device].is_none(), "device {device} joined twice");
            self.send_hello(
                link.as_mut(),
                device,
                digest,
                comp_seeds[device],
                false,
                init.start_iter as u64,
                Some(x0.clone()),
            )?;
            by_dev[device] = Some(link);
        }
        self.train(by_dev, &comp_seeds, init, None, x0, &mut rng)
    }

    /// [`Leader::run`], but owning the accept loop: accept connections
    /// until all `n` device slots hold a handshaked worker, then train —
    /// with the accept loop kept alive for the whole run so a retired
    /// slot can be reclaimed by a late joiner.
    pub fn serve(
        &self,
        listener: &NetListener,
        x0: &mut Vec<f32>,
        label: &str,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        self.check_shapes(x0)?;
        let comp_seeds = rng.split_seeds(self.cfg.n_devices);
        let init = TrainInit::fresh(self.cfg.n_devices, label);
        self.serve_inner(listener, &comp_seeds, init, x0, rng)
    }

    /// [`Leader::serve`] from a checkpoint: the leader-failover path.
    /// Workers reconnect with a plain `Join` carrying their device id;
    /// the `Hello` ships the checkpointed iterate and resume iteration
    /// (`reset_stream: false`).
    pub fn serve_resume(
        &self,
        listener: &NetListener,
        ckpt: &Checkpoint,
        x0: &mut Vec<f32>,
        label: &str,
    ) -> Result<TrainTrace> {
        let (comp_seeds, mut rng, init) = self.resume_init(ckpt, label, x0)?;
        self.serve_inner(listener, &comp_seeds, init, x0, &mut rng)
    }

    /// Reconstruct `(comp seeds, run RNG, loop state)` from a checkpoint,
    /// restoring the iterate into `x0` and the aggregator's state.
    fn resume_init(
        &self,
        ckpt: &Checkpoint,
        label: &str,
        x0: &mut Vec<f32>,
    ) -> Result<(Vec<u64>, Rng, TrainInit)> {
        let cfg = self.cfg;
        let n = cfg.n_devices;
        ensure!(
            ckpt.digest == config_digest(cfg),
            "checkpoint config digest {:#018x} != this config's {:#018x}",
            ckpt.digest,
            config_digest(cfg)
        );
        ensure!(
            ckpt.seed == cfg.seed,
            "checkpoint seed {} != config seed {}",
            ckpt.seed,
            cfg.seed
        );
        ensure!(
            (ckpt.iter as usize) < cfg.iters,
            "checkpoint is at iteration {}, but the run has only {} iterations",
            ckpt.iter,
            cfg.iters
        );
        let run_rng = ckpt
            .run_rng
            .ok_or_else(|| anyhow!("checkpoint lacks a run-RNG cursor (not a warm-restart v2)"))?;
        let streams = ckpt
            .comp_streams
            .as_ref()
            .ok_or_else(|| anyhow!("checkpoint lacks compression-stream cursors"))?;
        ensure!(streams.len() == n, "checkpoint has {} streams, config {n}", streams.len());
        ensure!(
            ckpt.params.len() == cfg.dim,
            "checkpoint iterate dim {} != config {}",
            ckpt.params.len(),
            cfg.dim
        );
        *x0 = ckpt.params.clone();
        self.check_shapes(x0)?;
        let comp_seeds: Vec<u64> = streams.iter().map(|&(s, _)| s).collect();
        let cursors: Vec<RngState> = streams.iter().map(|&(_, c)| c).collect();
        let mut init = TrainInit::fresh(n, label);
        init.start_iter = ckpt.iter as usize;
        init.comp_cursors = Some(cursors);
        init.ef_rows = ckpt.ef_residuals.clone();
        if let Some(roster) = &ckpt.roster {
            ensure!(roster.len() == n, "checkpoint roster has {} slots, config {n}", roster.len());
            for (i, e) in roster.iter().enumerate() {
                init.dead[i] = e.dead;
                init.miss_streak[i] = e.miss_streak as usize;
                init.rejoin_epoch[i] = e.rejoin_epoch;
            }
        }
        // restoring an empty Vec resets a stateful aggregator to fresh
        // (momentum re-initializes on its next call); no-op for the rest
        self.agg.state_restore(ckpt.momentum.clone().unwrap_or_default());
        if let Some(b) = &ckpt.trace {
            let (tr, bits, up, down) = block_to_trace(b);
            init.trace = tr;
            init.bits_total = bits;
            init.wire_up = up;
            init.wire_down = down;
        }
        if self.opts.obs.enabled() {
            self.opts.obs.emit(Event::LeaderFailover {
                iter: ckpt.iter,
                checkpoint: self
                    .opts
                    .checkpoint_path
                    .as_ref()
                    .map(|p| p.display().to_string())
                    .unwrap_or_else(|| "<checkpoint>".to_string()),
            });
        }
        Ok((comp_seeds, Rng::restore(run_rng), init))
    }

    /// Shared serve body: a nonblocking accept loop with one handshake
    /// thread per connection feeds a single intake channel; the roster
    /// fill consumes it first, and the training loop keeps draining it
    /// for mid-run joins afterwards.
    fn serve_inner(
        &self,
        listener: &NetListener,
        comp_seeds: &[u64],
        mut init: TrainInit,
        x0: &mut Vec<f32>,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        let n = self.cfg.n_devices;
        let digest = config_digest(self.cfg);
        let budget = self.opts.join_deadline;
        // handshake bytes count only on a cold start: a resumed run's wire
        // totals must match the uninterrupted run's
        let count_handshake = init.start_iter == 0;
        listener.set_nonblocking(true)?;
        let stop = AtomicBool::new(false);
        let (hs_tx, hs_rx) = mpsc::channel::<RejoinRequest>();
        let result = std::thread::scope(|scope| {
            let acceptor_tx = hs_tx.clone();
            let stop_ref = &stop;
            scope.spawn(move || {
                while !stop_ref.load(Ordering::Relaxed) {
                    match listener.try_accept() {
                        Ok(Some(link)) => {
                            let out = acceptor_tx.clone();
                            let _ = std::thread::Builder::new()
                                .name("lad-net-join".into())
                                .spawn(move || handshake_join(link, n, digest, budget, out));
                        }
                        Ok(None) => std::thread::sleep(Duration::from_millis(5)),
                        Err(e) => {
                            eprintln!("leader: accept loop terminated: {e:#}");
                            return;
                        }
                    }
                }
            });
            drop(hs_tx);
            let body = (|| -> Result<TrainTrace> {
                let mut by_dev: Vec<Option<Box<dyn Transport>>> = (0..n).map(|_| None).collect();
                let mut filled = 0usize;
                while filled < n {
                    let req = hs_rx.recv().map_err(|_| {
                        anyhow!("accept loop terminated before all {n} devices joined")
                    })?;
                    let device = req.device;
                    if by_dev[device].is_some() {
                        eprintln!("leader: dropping duplicate join for device {device}");
                        continue;
                    }
                    let mut link = req.link;
                    let peer = link.peer();
                    let iterate = (init.start_iter > 0).then(|| x0.clone());
                    match self.send_hello(
                        link.as_mut(),
                        device,
                        digest,
                        comp_seeds[device],
                        false,
                        init.start_iter as u64,
                        iterate,
                    ) {
                        Ok(nb) => {
                            if count_handshake {
                                init.wire_up += req.join_bytes;
                                init.wire_down += nb;
                            }
                            by_dev[device] = Some(link);
                            filled += 1;
                            eprintln!("leader: [{filled}/{n}] device {device} joined ({peer})");
                        }
                        Err(e) => eprintln!("leader: dropping device {device} ({peer}): {e:#}"),
                    }
                }
                self.train(by_dev, comp_seeds, init, Some(&hs_rx), x0, rng)
            })();
            stop.store(true, Ordering::Relaxed);
            body
        });
        listener.set_nonblocking(false)?;
        result
    }

    /// The training loop proper, over a fully handshaked device set.
    fn train(
        &self,
        by_dev: Vec<Option<Box<dyn Transport>>>,
        comp_seeds: &[u64],
        init: TrainInit,
        rejoin: Option<&mpsc::Receiver<RejoinRequest>>,
        x0: &mut Vec<f32>,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        let cfg = self.cfg;
        let n = cfg.n_devices;
        let timer = Timer::start();
        let obs = &self.opts.obs;
        // hand the aggregation rules the obs context so their internal
        // kernels (Gram fill, Krum scoring, NNM mixing, Weiszfeld) span
        // + histogram themselves; a no-op when obs is off
        self.agg.set_obs(obs);
        let hand_off = self.opts.rotate_byzantine && self.opts.device_compression;
        let TrainInit {
            start_iter,
            comp_cursors,
            ef_rows,
            mut dead,
            mut miss_streak,
            mut rejoin_epoch,
            mut trace,
            mut bits_total,
            mut wire_up,
            mut wire_down,
        } = init;
        // metrics export high-water marks: counters get per-iteration
        // deltas so a live status poll sees wire bytes grow
        let (mut obs_up_mark, mut obs_down_mark) = (wire_up, wire_down);
        if let Some(st) = obs.status() {
            st.begin_run(&trace.label, cfg.iters as u64, n);
            st.set_iter(start_iter as u64);
            for i in 0..n {
                st.set_device(
                    i,
                    crate::obs::DeviceStatus {
                        dead: dead[i],
                        miss_streak: miss_streak[i] as u64,
                        epoch: rejoin_epoch[i],
                    },
                );
            }
        }
        // per-device compression streams: restored cursors on a warm
        // restart, fresh from the pre-split seeds otherwise
        let mut comp_rngs: Vec<Rng> = match &comp_cursors {
            Some(cur) => cur.iter().map(|&st| Rng::restore(st)).collect(),
            None => comp_seeds.iter().map(|&s| Rng::new(s)).collect(),
        };
        // EF residual mirror (Some only for ef-* kinds): leader-side
        // compression steps every row; device-side compression steps only
        // the Byzantine rows (honest workers hold their own). Rows are
        // zeroed on retirement — see the module docs.
        let mut ef = EfState::for_kind(cfg.compression, n, cfg.dim);
        if let (Some(st), Some(rows)) = (ef.as_mut(), ef_rows) {
            st.restore(rows);
        }

        // ---- split: sends stay here, one detached reader per device ----
        // Readers forward (device, epoch, Some((msg, bytes))) into a single
        // queue — the gather deadline is then one recv_timeout on that
        // queue, so a stalled connection never blocks the others — and a
        // final (device, epoch, None) when their connection dies. The
        // epoch tag discards ghost events from connections a rejoin has
        // since replaced.
        let (fwd_tx, fwd_rx) = mpsc::channel::<RxEvent>();
        let mut txs: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        for (dev, link) in by_dev.into_iter().enumerate() {
            let (mut tx_half, rx_half) = link.expect("handshake fills every slot").split()?;
            if let Some(d) = self.opts.gather_deadline {
                // crash tolerance must also cover a worker that stops
                // draining its socket: bound blocking broadcast writes so
                // the send fails (and the device is retired) instead of
                // wedging the leader in write_all
                tx_half.set_send_timeout(Some(d))?;
            }
            txs.push(tx_half);
            spawn_reader(dev, rejoin_epoch[dev], rx_half, fwd_tx.clone())?;
        }
        let rejoin_fwd = rejoin.map(|_| fwd_tx.clone());
        drop(fwd_tx);

        // ---- training loop ----
        let s_hat = TaskMatrix::cyclic(n, cfg.d);
        let pipeline = self.opts.pipeline;
        // contiguous uplink slab: device i's reconstruction decodes straight
        // into row i, so attack crafting / compression / aggregation all
        // read out of one allocation reused across iterations
        let mut slab = vec![0.0f32; n * cfg.dim];
        // double-buffer staging (pipeline mode): iteration t+1's
        // assignment, identity set and pre-encoded per-device tails,
        // drawn after craft(t)
        let mut staged: Option<(Assignment, Vec<bool>, Vec<Vec<u8>>)> = None;
        let mut pending_rejoin: Vec<RejoinRequest> = Vec::new();
        let subsets_u32 = |assign: &Assignment, i: usize| -> Vec<u32> {
            assign.subsets_for(s_hat.row(assign.tasks[i])).map(|k| k as u32).collect()
        };
        let encode_tails =
            |assign: &Assignment, is_byz: &[bool], comp_rngs: &[Rng]| -> Vec<Vec<u8>> {
                (0..n)
                    .map(|i| {
                        let cursor =
                            (hand_off && !is_byz[i]).then(|| comp_rngs[i].save_state());
                        broadcast_tail(&subsets_u32(assign, i), is_byz[i], &cursor)
                    })
                    .collect()
            };

        for t in start_iter..cfg.iters {
            // ---- mid-run join intake (before the broadcast, so an
            // activated device serves this very iteration) ----
            if let Some(ch) = rejoin {
                while let Ok(req) = ch.try_recv() {
                    pending_rejoin.push(req);
                }
            }
            if !pending_rejoin.is_empty() {
                let mut keep = Vec::new();
                for req in pending_rejoin.drain(..) {
                    if req.not_before > t as u64 {
                        keep.push(req);
                        continue;
                    }
                    let dev = req.device;
                    if !dead[dev] {
                        eprintln!("leader: dropping rejoin for live device {dev}");
                        continue;
                    }
                    // a fresh epoch invalidates the dead connection's
                    // reader events and derives a fresh stream seed —
                    // without touching the run RNG
                    rejoin_epoch[dev] += 1;
                    let seed = rejoin_seed(comp_seeds[dev], rejoin_epoch[dev]);
                    let mut link = req.link;
                    match self.send_hello(
                        link.as_mut(),
                        dev,
                        config_digest(cfg),
                        seed,
                        true,
                        t as u64,
                        Some(x0.clone()),
                    ) {
                        Ok(nb) => {
                            wire_up += req.join_bytes;
                            wire_down += nb;
                            let (mut tx_half, rx_half) = link.split()?;
                            if let Some(d) = self.opts.gather_deadline {
                                tx_half.set_send_timeout(Some(d))?;
                            }
                            if let Some(fwd) = &rejoin_fwd {
                                spawn_reader(dev, rejoin_epoch[dev], rx_half, fwd.clone())?;
                            }
                            txs[dev] = tx_half;
                            if let Some(st) = ef.as_mut() {
                                st.reset(dev);
                            }
                            comp_rngs[dev] = Rng::new(seed);
                            dead[dev] = false;
                            miss_streak[dev] = 0;
                            // a staged tail for this slot was encoded
                            // against the old stream — re-encode it
                            if let Some((assign, is_byz, tails)) = staged.as_mut() {
                                let cursor = (hand_off && !is_byz[dev])
                                    .then(|| comp_rngs[dev].save_state());
                                tails[dev] = broadcast_tail(
                                    &subsets_u32(assign, dev),
                                    is_byz[dev],
                                    &cursor,
                                );
                            }
                            trace.rejoins += 1;
                            if obs.enabled() {
                                obs.emit(Event::DeviceRejoined {
                                    device: dev,
                                    iter: t as u64,
                                    epoch: rejoin_epoch[dev],
                                });
                                if let Some(st) = obs.status() {
                                    st.device_rejoined(dev, rejoin_epoch[dev]);
                                }
                            }
                            eprintln!("leader: device {dev} rejoined at iteration {t}");
                        }
                        Err(e) => {
                            eprintln!("leader: rejoin hello for device {dev} failed: {e:#}")
                        }
                    }
                }
                pending_rejoin = keep;
            }

            if let Some(st) = obs.status() {
                st.set_iter(t as u64);
                st.set_phase("broadcast");
            }
            let sp_bcast = obs.span("broadcast");
            let (assign, is_byz, tails) = match staged.take() {
                Some(s) => s,
                None => {
                    let a = Assignment::draw(n, rng);
                    let b = byz_set(cfg, self.opts.rotate_byzantine, rng);
                    if self.opts.rotate_byzantine && obs.enabled() {
                        obs.emit(Event::ByzantineRoleDrawn {
                            iter: t as u64,
                            byzantine: (0..n).filter(|&i| b[i]).collect(),
                        });
                    }
                    let tails = if pipeline {
                        encode_tails(&a, &b, &comp_rngs)
                    } else {
                        Vec::new()
                    };
                    (a, b, tails)
                }
            };
            let mut expecting = vec![false; n];
            if pipeline {
                // shared x-frame: the Q-sized iterate section is encoded
                // exactly once per iteration; each device's frame splices
                // its pre-encoded subset/role/cursor tail on, and both the
                // splice and the socket write fan out on the pool. Results
                // come back in device order, so retirement semantics match
                // the phase-serial loop below.
                let prefix = broadcast_prefix(t as u32, x0);
                let sends: Vec<Option<Result<u64>>> = self.pool.par_map_mut(&mut txs, |i, tx| {
                    if dead[i] {
                        return None;
                    }
                    let frame = encode_frame_parts(&[prefix.as_slice(), tails[i].as_slice()]);
                    Some(tx.send_frame(&frame))
                });
                for (i, res) in sends.into_iter().enumerate() {
                    match res {
                        None => {}
                        Some(Ok(nb)) => {
                            wire_down += nb;
                            expecting[i] = true;
                        }
                        Some(Err(e)) => {
                            if self.opts.gather_deadline.is_some() {
                                // crash-Byzantine: drop the device, keep going
                                dead[i] = true;
                                if let Some(st) = ef.as_mut() {
                                    st.reset(i);
                                }
                                trace.anomalies += 1;
                                trace.retirements += 1;
                                if obs.enabled() {
                                    obs.emit(Event::DeviceRetired {
                                        device: i,
                                        iter: t as u64,
                                        reason: format!("broadcast send failed: {e:#}"),
                                    });
                                    if let Some(st) = obs.status() {
                                        st.device_retired(i);
                                    }
                                }
                            } else {
                                return Err(e).context(format!("broadcast to device {i}"));
                            }
                        }
                    }
                }
            } else {
                for i in 0..n {
                    if dead[i] {
                        continue;
                    }
                    let cursor =
                        (hand_off && !is_byz[i]).then(|| comp_rngs[i].save_state());
                    let msg = Msg::Broadcast {
                        iter: t as u32,
                        x: x0.clone(),
                        subsets: subsets_u32(&assign, i),
                        byzantine: is_byz[i],
                        cursor,
                    };
                    match txs[i].send(&msg) {
                        Ok(nb) => {
                            wire_down += nb;
                            expecting[i] = true;
                        }
                        Err(e) => {
                            if self.opts.gather_deadline.is_some() {
                                // crash-Byzantine: drop the device, keep going
                                dead[i] = true;
                                if let Some(st) = ef.as_mut() {
                                    st.reset(i);
                                }
                                trace.anomalies += 1;
                                trace.retirements += 1;
                                if obs.enabled() {
                                    obs.emit(Event::DeviceRetired {
                                        device: i,
                                        iter: t as u64,
                                        reason: format!("broadcast send failed: {e:#}"),
                                    });
                                    if let Some(st) = obs.status() {
                                        st.device_retired(i);
                                    }
                                }
                            } else {
                                return Err(e).context(format!("broadcast to device {i}"));
                            }
                        }
                    }
                }
            }
            let bcast_ns = sp_bcast.done();
            trace.broadcast_ns += bcast_ns;
            let mut want = expecting.iter().filter(|&&b| b).count();
            let frames_sent = want as u64;
            ensure!(want > 0, "iteration {t}: no live workers left");

            // gather until complete or the deadline expires; uploads decode
            // straight into their device's slab row, `have[dev]` records the
            // analytic bit count of a landed upload
            if let Some(st) = obs.status() {
                st.set_phase("gather");
            }
            let sp_gather = obs.span("gather");
            let mut have: Vec<Option<u64>> = (0..n).map(|_| None).collect();
            let deadline = self.opts.gather_deadline.map(|d| Instant::now() + d);
            while want > 0 {
                let item = match deadline {
                    None => match fwd_rx.recv() {
                        Ok(x) => x,
                        Err(_) => bail!("iteration {t}: all workers disconnected"),
                    },
                    Some(dl) => {
                        let now = Instant::now();
                        if now >= dl {
                            break;
                        }
                        match fwd_rx.recv_timeout(dl - now) {
                            Ok(x) => x,
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                bail!("iteration {t}: all workers disconnected")
                            }
                        }
                    }
                };
                let (dev, epoch, event) = item;
                if epoch != rejoin_epoch[dev] {
                    // ghost event from a connection that a rejoin has since
                    // replaced; not counted anywhere (determinism) — but no
                    // longer silent: the discard reason is journaled
                    if obs.enabled() {
                        let upload_iter = match &event {
                            Some((Msg::Upload { iter, .. }, _)) => *iter as u64,
                            _ => t as u64,
                        };
                        obs.emit(Event::StaleUploadDiscarded {
                            device: dev,
                            iter: t as u64,
                            upload_iter,
                            epoch,
                            reason: format!(
                                "ghost epoch {epoch} (slot re-filled, now epoch {})",
                                rejoin_epoch[dev]
                            ),
                        });
                    }
                    continue;
                }
                let (msg, nb) = match event {
                    Some(x) => x,
                    None => {
                        // this device's connection died (EOF / corrupt frame)
                        if self.opts.gather_deadline.is_none() {
                            bail!(
                                "iteration {t}: device {dev} disconnected or sent a \
                                 corrupt frame"
                            );
                        }
                        drop_device(
                            dev,
                            t as u64,
                            "connection died (EOF or corrupt frame)",
                            &mut dead,
                            &mut expecting,
                            &have,
                            &mut want,
                            &mut trace,
                            ef.as_mut(),
                            obs,
                        );
                        continue;
                    }
                };
                wire_up += nb;
                match msg {
                    Msg::Upload { iter, device, analytic_bits, cursor, payload } => {
                        if iter as usize != t || device as usize != dev {
                            // stale upload from a past deadline miss (or a
                            // mislabeled sender) — journal the reason
                            if obs.enabled() {
                                obs.emit(Event::StaleUploadDiscarded {
                                    device: dev,
                                    iter: t as u64,
                                    upload_iter: iter as u64,
                                    epoch,
                                    reason: if device as usize != dev {
                                        format!("upload labeled device {device} on link {dev}")
                                    } else {
                                        "late upload for a past iteration".to_string()
                                    },
                                });
                            }
                            continue;
                        }
                        if !expecting[dev] || have[dev].is_some() {
                            if obs.enabled() {
                                obs.emit(Event::StaleUploadDiscarded {
                                    device: dev,
                                    iter: t as u64,
                                    upload_iter: iter as u64,
                                    epoch,
                                    reason: "duplicate or unexpected upload".to_string(),
                                });
                            }
                            continue;
                        }
                        // dimension checked on the cheap accessor BEFORE
                        // reconstructing, so a hostile dim never touches the
                        // slab; decode_into fully overwrites the row, so a
                        // stale value from a past iteration can never leak
                        let row = &mut slab[dev * cfg.dim..(dev + 1) * cfg.dim];
                        if payload.dim() == cfg.dim && payload.decode_into(row).is_ok() {
                            if hand_off && !is_byz[dev] {
                                if let Some(st) = cursor {
                                    // adopt the device's post-compression
                                    // stream state into the leader mirror
                                    comp_rngs[dev] = Rng::restore(st);
                                }
                            }
                            have[dev] = Some(analytic_bits);
                            want -= 1;
                        } else {
                            if self.opts.gather_deadline.is_none() {
                                bail!(
                                    "device {dev} sent an invalid upload \
                                     (payload dim != {})",
                                    cfg.dim
                                );
                            }
                            drop_device(
                                dev,
                                t as u64,
                                "invalid upload (payload dim mismatch or decode failure)",
                                &mut dead,
                                &mut expecting,
                                &have,
                                &mut want,
                                &mut trace,
                                ef.as_mut(),
                                obs,
                            );
                        }
                    }
                    other => {
                        // a protocol deviation from one worker must not
                        // kill the run when crash tolerance was asked for
                        if self.opts.gather_deadline.is_none() {
                            bail!("unexpected mid-run message from device {dev}: {other:?}");
                        }
                        let reason = format!("protocol deviation: {other:?}");
                        drop_device(
                            dev,
                            t as u64,
                            &reason,
                            &mut dead,
                            &mut expecting,
                            &have,
                            &mut want,
                            &mut trace,
                            ef.as_mut(),
                            obs,
                        );
                    }
                }
            }
            trace.anomalies += want; // devices that missed the deadline
            let gather_ns = sp_gather.done();
            trace.gather_ns += gather_ns;
            if want > 0 {
                if let Some(st) = obs.status() {
                    st.add_anomalies(want as u64);
                }
            }
            // retire chronic stragglers so a permanently stalled worker
            // costs a bounded number of timeouts, not one per iteration
            for i in 0..n {
                if !expecting[i] {
                    continue;
                }
                if have[i].is_some() {
                    if miss_streak[i] != 0 {
                        if let Some(st) = obs.status() {
                            st.device_answered(i);
                        }
                    }
                    miss_streak[i] = 0;
                } else {
                    miss_streak[i] += 1;
                    trace.deadline_misses += 1;
                    if obs.enabled() {
                        obs.emit(Event::DeadlineMiss {
                            device: i,
                            iter: t as u64,
                            streak: miss_streak[i] as u64,
                        });
                        if let Some(st) = obs.status() {
                            st.device_miss(i, miss_streak[i] as u64);
                        }
                    }
                    if miss_streak[i] >= MISS_RETIRE_STREAK {
                        dead[i] = true;
                        trace.retirements += 1;
                        // retirement zeroes the slot's residual; a mere
                        // deadline miss (above) leaves it untouched
                        if let Some(st) = ef.as_mut() {
                            st.reset(i);
                        }
                        if obs.enabled() {
                            obs.emit(Event::DeviceRetired {
                                device: i,
                                iter: t as u64,
                                reason: format!(
                                    "{} consecutive deadline misses",
                                    miss_streak[i]
                                ),
                            });
                            if let Some(st) = obs.status() {
                                st.device_retired(i);
                            }
                        }
                        eprintln!(
                            "leader: retiring device {i} after {} consecutive misses",
                            miss_streak[i]
                        );
                    }
                }
            }

            let present: Vec<usize> = (0..n).filter(|&i| have[i].is_some()).collect();
            ensure!(!present.is_empty(), "iteration {t}: no uploads before the deadline");
            let honest_ids: Vec<usize> =
                present.iter().copied().filter(|&i| !is_byz[i]).collect();
            let byz_ids: Vec<usize> =
                present.iter().copied().filter(|&i| is_byz[i]).collect();

            // View the uploads as slab rows, craft the lies, compress what
            // is still uncompressed, and stitch the family back into
            // DEVICE-ID order — which equals the historical
            // honest-then-lies order under fixed identities (honest ids
            // all precede Byzantine ids) and the central trainer's family
            // order under rotation.
            if let Some(st) = obs.status() {
                st.set_phase("aggregate");
            }
            let sp_agg = obs.span("aggregate");
            let row = |i: usize| -> &[f32] { &slab[i * cfg.dim..(i + 1) * cfg.dim] };
            let msgs: Vec<Vec<f32>> = if self.opts.device_compression {
                let honest_rec: Vec<&[f32]> = honest_ids.iter().map(|&i| row(i)).collect();
                for &i in &honest_ids {
                    bits_total += have[i].expect("present");
                }
                let byz_true: Vec<&[f32]> = byz_ids.iter().map(|&i| row(i)).collect();
                let lies = if byz_true.is_empty() {
                    Vec::new()
                } else {
                    let mut ctx =
                        AttackContext { honest: &honest_rec, own_true: &byz_true, rng };
                    self.attack.craft(&mut ctx)
                };
                // the emulated Byzantine uplinks are compressed with their
                // own device streams, exactly as the central path does —
                // under EF, with their own residual rows too (honest rows
                // live on the workers in this mode)
                let mut lie_rec: Vec<Vec<f32>> = Vec::with_capacity(lies.len());
                if let Some(st) = ef.as_mut() {
                    for (j, &i) in byz_ids.iter().enumerate() {
                        let c = st.step(i, &lies[j], self.comp, &mut comp_rngs[i]);
                        bits_total += c.bits as u64;
                        lie_rec.push(c.vec);
                    }
                } else if byz_ids.iter().copied().eq(cfg.n_honest..n) {
                    let refs: Vec<&[f32]> = lies.iter().map(|l| l.as_slice()).collect();
                    let (rec, bits) = compress_batch(
                        self.comp,
                        &refs,
                        &mut comp_rngs[cfg.n_honest..],
                        &self.pool,
                    );
                    bits_total += bits;
                    lie_rec = rec;
                } else {
                    for (j, &i) in byz_ids.iter().enumerate() {
                        let c = self.comp.compress(&lies[j], &mut comp_rngs[i]);
                        bits_total += c.bits as u64;
                        lie_rec.push(c.vec);
                    }
                }
                let mut out: Vec<Vec<f32>> = Vec::with_capacity(present.len());
                let (mut hi, mut li) = (0usize, 0usize);
                for &i in &present {
                    if is_byz[i] {
                        out.push(std::mem::take(&mut lie_rec[li]));
                        li += 1;
                    } else {
                        out.push(honest_rec[hi].to_vec());
                        hi += 1;
                    }
                }
                out
            } else {
                let honest_true: Vec<&[f32]> = honest_ids.iter().map(|&i| row(i)).collect();
                let byz_true: Vec<&[f32]> = byz_ids.iter().map(|&i| row(i)).collect();
                let lies = if byz_true.is_empty() {
                    Vec::new()
                } else {
                    let mut ctx =
                        AttackContext { honest: &honest_true, own_true: &byz_true, rng };
                    self.attack.craft(&mut ctx)
                };
                if present.len() == n {
                    // full gather: one device-order batch — the exact call
                    // shape of the central fast path (and, under fixed
                    // identities, of the historical honest-then-lies batch)
                    let mut all: Vec<&[f32]> = Vec::with_capacity(n);
                    let (mut hi, mut li) = (0usize, 0usize);
                    for i in 0..n {
                        if is_byz[i] {
                            all.push(lies[li].as_slice());
                            li += 1;
                        } else {
                            all.push(honest_true[hi]);
                            hi += 1;
                        }
                    }
                    let (msgs, bits) = match ef.as_mut() {
                        Some(st) => {
                            compress_batch_ef(self.comp, st, &all, &mut comp_rngs, &self.pool)
                        }
                        None => compress_batch(self.comp, &all, &mut comp_rngs, &self.pool),
                    };
                    bits_total += bits;
                    msgs
                } else {
                    // partial gather: per-device compression in device-id
                    // order consumes only the present devices' streams (and
                    // EF residual rows) — an absent device's stream and
                    // residual stay untouched
                    let mut out = Vec::with_capacity(present.len());
                    let (mut hi, mut li) = (0usize, 0usize);
                    for &i in &present {
                        let src: &[f32] = if is_byz[i] {
                            let s = lies[li].as_slice();
                            li += 1;
                            s
                        } else {
                            let s = honest_true[hi];
                            hi += 1;
                            s
                        };
                        let c = match ef.as_mut() {
                            Some(st) => st.step(i, src, self.comp, &mut comp_rngs[i]),
                            None => self.comp.compress(src, &mut comp_rngs[i]),
                        };
                        bits_total += c.bits as u64;
                        out.push(c.vec);
                    }
                    out
                }
            };

            // ---- checkpoint cut ----
            // Snapshot the RNG cursors HERE: after craft(t), before the
            // staged draw(t+1). A resumed run redraws t+1 at its loop top,
            // so the run-RNG order is identical whether or not the
            // pipeline is on. Everything else (iterate, momentum, trace)
            // is captured after the update below.
            let ckpt_due = (self.opts.checkpoint_every > 0
                && (t as u64 + 1) % self.opts.checkpoint_every == 0)
                || self.opts.halt_after == Some(t as u64);
            let pending_ckpt = ckpt_due.then(|| {
                (
                    rng.save_state(),
                    comp_rngs.iter().map(|r| r.save_state()).collect::<Vec<_>>(),
                    ef.as_ref().map(|st| st.snapshot()),
                )
            });

            // double-buffer: draw iteration t+1's assignment + identities
            // and pre-encode its tails while this iteration still has
            // aggregation ahead of it. The draw sits AFTER this iteration's
            // attack craft, so the run RNG sees draw(0), byz(0), craft(0),
            // draw(1), … — exactly the phase-serial order.
            if pipeline && t + 1 < cfg.iters {
                let a = Assignment::draw(n, rng);
                let b = byz_set(cfg, self.opts.rotate_byzantine, rng);
                if self.opts.rotate_byzantine && obs.enabled() {
                    obs.emit(Event::ByzantineRoleDrawn {
                        iter: t as u64 + 1,
                        byzantine: (0..n).filter(|&i| b[i]).collect(),
                    });
                }
                let tails = encode_tails(&a, &b, &comp_rngs);
                staged = Some((a, b, tails));
            }

            let update = if obs.enabled() {
                // per-rule kernel histogram (power-of-2 ns buckets)
                let t_kernel = Instant::now();
                let u = self.agg.aggregate(&msgs);
                let kernel_ns = t_kernel.elapsed().as_nanos() as u64;
                obs.observe_ns(&format!("aggregate_kernel/{}", self.agg.name()), kernel_ns);
                u
            } else {
                self.agg.aggregate(&msgs)
            };
            for (xi, ui) in x0.iter_mut().zip(&update) {
                *xi -= cfg.lr as f32 * ui;
            }
            let agg_ns = sp_agg.done();
            trace.aggregate_ns += agg_ns;
            if obs.enabled() {
                obs.add("wire_up_bytes", wire_up - obs_up_mark);
                obs.add("wire_down_bytes", wire_down - obs_down_mark);
                obs_up_mark = wire_up;
                obs_down_mark = wire_down;
                obs.add(
                    if pipeline { "frames_spliced" } else { "frames_encoded" },
                    frames_sent,
                );
                if let Some(st) = ef.as_ref() {
                    // float work, deliberately gated behind `enabled()` so
                    // the recorder-off hot path does no extra math
                    let total: f64 = (0..n).map(|i| norm(st.residual(i))).sum();
                    obs.gauge("ef_residual_norm", total);
                }
                if let Some(st) = obs.status() {
                    st.add_phase_ns(bcast_ns, gather_ns, agg_ns);
                    st.set_iter(t as u64 + 1);
                }
            }
            if (cfg.log_every > 0 && t % cfg.log_every == 0) || t + 1 == cfg.iters {
                trace.record(t, self.ds.loss(x0), norm(&update), bits_total);
            }

            if let Some((run_st, cursors, ef_snap)) = pending_ckpt {
                let path = self
                    .opts
                    .checkpoint_path
                    .as_ref()
                    .expect("check_shapes enforced checkpoint_path");
                let mut ck = Checkpoint::new(t as u64 + 1, cfg.seed, x0.clone());
                ck.digest = config_digest(cfg);
                ck.run_rng = Some(run_st);
                ck.comp_streams = Some(comp_seeds.iter().copied().zip(cursors).collect());
                ck.ef_residuals = ef_snap;
                ck.momentum = self.agg.state_snapshot();
                ck.roster = Some(
                    (0..n)
                        .map(|i| RosterEntry {
                            dead: dead[i],
                            miss_streak: miss_streak[i] as u64,
                            rejoin_epoch: rejoin_epoch[i],
                        })
                        .collect(),
                );
                ck.trace = Some(trace_to_block(&trace, bits_total, wire_up, wire_down));
                let sp_ckpt = obs.span("checkpoint");
                ck.save(path)
                    .with_context(|| format!("writing checkpoint to {}", path.display()))?;
                let ckpt_ns = sp_ckpt.done();
                if obs.enabled() {
                    let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                    obs.emit(Event::CheckpointWritten { iter: t as u64 + 1, bytes, ns: ckpt_ns });
                }
            }
            if self.opts.halt_after == Some(t as u64) {
                // the leader-kill drill: exit WITHOUT Shutdown, so the
                // workers stay up and reconnect to a restarted leader
                bail!("leader halted at iteration {t} (halt-after drill; checkpoint written)");
            }
        }

        for tx in txs.iter_mut() {
            if let Ok(nb) = tx.send(&Msg::Shutdown) {
                wire_down += nb;
            }
        }
        trace.final_loss = self.ds.loss(x0);
        trace.wall_s = timer.elapsed_s();
        trace.wire_up_bytes = wire_up;
        trace.wire_down_bytes = wire_down;
        if obs.enabled() {
            obs.add("wire_up_bytes", wire_up - obs_up_mark);
            obs.add("wire_down_bytes", wire_down - obs_down_mark);
            if let Some(st) = obs.status() {
                st.set_phase("done");
            }
        }
        Ok(trace)
    }
}
