//! The server-side event loop (Fig. 1, left-hand side; Algorithms 1–2
//! over real connections).
//!
//! [`Leader::run`] drives one training run over any set of [`Transport`]
//! connections — in-process channels (the refactored `server::cluster`),
//! TCP, or Unix-domain sockets (`lad node-leader`). Per iteration it
//! draws the random assignment (T^t, p^t), broadcasts the iterate plus
//! each device's resolved subset list, gathers the coded uplinks, emulates
//! the Byzantine devices (crafting their lies centrally from the gathered
//! messages — the omniscient adversary cannot live on a real node),
//! compresses whatever is still uncompressed, aggregates with the
//! configured κ-robust rule and steps the model.
//!
//! **Gather deadline.** With [`LeaderOpts::gather_deadline`] set, a
//! stalled (crash-Byzantine) worker cannot hang an iteration: when the
//! deadline expires the leader proceeds with the messages it has, counts
//! the missing devices as anomalies, and keeps the run alive — exactly
//! the partial-participation stress the robust aggregators are built to
//! absorb. Late uploads for old iterations are discarded by iteration
//! tag. Without a deadline (the default, and the trace-parity mode) the
//! leader waits for every device, and a disconnect is an error.
//!
//! **Join deadline.** With [`LeaderOpts::join_deadline`] set, a
//! connection that goes silent before completing a valid `Join` is
//! dropped after the deadline instead of blocking startup forever;
//! under [`Leader::serve`] (which owns the accept loop) the device slot
//! is then reclaimed by the next connection, so a stray connector
//! cannot permanently occupy one of the N slots. The deadline is
//! per-read, not per-handshake — a deliberate byte-trickling adversary
//! still needs concurrent handshakes to defeat (ROADMAP).
//!
//! **Determinism.** With every device live, traces are bit-identical to
//! `Trainer::run`'s central fast path: the leader consumes the run RNG in
//! the same order (assignment, then attack crafting), per-device
//! compression randomness comes from the same pre-split streams
//! (`Rng::split_seeds` — honest devices consume their stream on-device
//! under device-side compression, the leader consumes the Byzantine
//! streams when compressing the crafted lies), and the wire codec
//! reconstructs every message bit-exactly. Under device-side compression
//! the attack context sees the *post-compression* honest reconstructions
//! (all a device-side adversary could see); omniscient attacks that read
//! `ctx.honest` therefore match the central path only under leader-side
//! compression or the Identity operator.
//!
//! **Pipeline.** By default ([`LeaderOpts::pipeline`]) the leader runs the
//! iteration as a software pipeline: the Q-sized iterate section of the
//! `Broadcast` is encoded **once** per iteration
//! ([`super::wire::broadcast_prefix`]) and each device's frame splices its
//! tiny subset tail on ([`super::wire::broadcast_tail`] +
//! [`super::frame::encode_frame_parts`]), with frame
//! assembly and the socket writes fanned out on [`Leader::pool`]; uplinks
//! decode straight into a contiguous per-device slab
//! ([`super::wire::Payload::decode_into`], no per-device `Vec`); and the
//! next iteration's assignment + subset tails are drawn into a staging
//! buffer while the current iteration is still aggregating. The staged draw
//! sits **after** the current iteration's attack craft, so the run RNG sees
//! `draw(0), craft(0), draw(1), craft(1), …` — exactly the phase-serial
//! order — and every byte on the wire is identical to the per-device
//! encoding (`pipeline: false`). Both invariants are pinned by
//! `tests/fuzz_determinism.rs` and `tests/net_cluster.rs`.
//!
//! **Error feedback.** Under an `ef-*` compression kind the leader keeps
//! an [`EfState`] mirror: under leader-side compression it holds every
//! device's residual; under device-side compression honest workers hold
//! their own rows (`net::worker`) and the leader steps only the Byzantine
//! rows when compressing the crafted lies — so full-participation runs
//! stay bit-identical to `Trainer::run`. Residual-reset semantics, pinned
//! by `tests/net_cluster.rs`: a device that merely misses a gather
//! deadline keeps its residual (mirroring its untouched RNG stream), but
//! a **retired** device's residual is zeroed the moment it is dropped, so
//! a slot can never replay stale memory.

use super::frame::encode_frame_parts;
use super::transport::Transport;
use super::wire::{
    broadcast_prefix, broadcast_tail, config_digest, DatasetBlock, Msg, WIRE_VERSION,
};
use crate::aggregation::Aggregator;
use crate::attack::{Attack, AttackContext};
use crate::coding::{Assignment, TaskMatrix};
use crate::compress::{compress_batch, compress_batch_ef, Compressor, EfState};
use crate::config::TrainConfig;
use crate::data::linreg::LinRegDataset;
use crate::server::metrics::TrainTrace;
use crate::util::math::norm;
use crate::util::parallel::Pool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Consecutive gather-deadline misses after which a device is retired
/// (deadline mode): a permanently stalled worker costs this many timeouts
/// total, not one per remaining iteration — and its broadcast queue stops
/// growing once it is dead.
pub const MISS_RETIRE_STREAK: usize = 3;

/// Retire a device mid-run (deadline mode only): it is never broadcast to
/// again, its EF residual (when error feedback is active) is zeroed so the
/// slot can never replay stale memory, and if its upload was still pending
/// this iteration the miss is charged to the trace as an anomaly
/// immediately so the gather can stop waiting on it.
fn drop_device(
    dev: usize,
    dead: &mut [bool],
    expecting: &mut [bool],
    have: &[Option<u64>],
    want: &mut usize,
    trace: &mut TrainTrace,
    ef: Option<&mut EfState>,
) {
    dead[dev] = true;
    if let Some(st) = ef {
        st.reset(dev);
    }
    if expecting[dev] && have[dev].is_none() {
        expecting[dev] = false;
        trace.anomalies += 1;
        *want -= 1;
    }
}

/// Leader-side policy knobs that are not part of the training semantics.
#[derive(Debug, Clone)]
pub struct LeaderOpts {
    /// Per-iteration gather budget. `None` waits for every device.
    pub gather_deadline: Option<Duration>,
    /// Honest devices compress their own uplink (Com-LAD device-side);
    /// `false` reproduces the leader-side compression of the historical
    /// cluster simulation (and keeps omniscient attacks exact).
    pub device_compression: bool,
    /// Per-link Join-handshake budget. `None` waits forever (the
    /// trusting default for pre-connected in-process links). With a
    /// deadline set, a connection that goes **silent** for this long
    /// before completing a valid `Join` is dropped — and under
    /// [`Leader::serve`] its device slot is reclaimed by the accept
    /// loop, so a stray connection cannot wedge startup (ROADMAP
    /// transport-hardening item). Note the deadline bounds each *read*,
    /// not the handshake as a whole: an adversary trickling one byte per
    /// deadline can still hold the serial accept loop (see ROADMAP —
    /// concurrent handshakes are the remaining hardening step).
    pub join_deadline: Option<Duration>,
    /// Pipelined iteration scheduling (the default): shared x-frame
    /// broadcast with pool-parallel frame assembly, slab uplink decode,
    /// and double-buffered staging of the next assignment's subset tails.
    /// `false` selects the phase-serial schedule (per-device `Broadcast`
    /// encode on the leader thread, per-device `Vec` reconstruction) —
    /// kept as the reference implementation the pipeline is pinned
    /// bit-identical to. Pure scheduling: traces, wire bytes and RNG
    /// consumption are unaffected, so the toggle is deliberately outside
    /// `config_digest` and the sweep job identity.
    pub pipeline: bool,
}

impl Default for LeaderOpts {
    fn default() -> Self {
        LeaderOpts {
            gather_deadline: None,
            device_compression: false,
            join_deadline: None,
            pipeline: true,
        }
    }
}

/// The server of a multi-node run: configuration, dataset, and the
/// injected aggregation rule / attack / compression operator.
pub struct Leader<'a> {
    pub cfg: &'a TrainConfig,
    pub ds: &'a LinRegDataset,
    pub agg: &'a dyn Aggregator,
    pub attack: &'a dyn Attack,
    pub comp: &'a dyn Compressor,
    pub opts: LeaderOpts,
    /// Worker pool for the leader-side compression batch (share a budgeted
    /// slice via [`Pool::borrow`] to respect a process-level thread budget).
    pub pool: Pool,
    /// Ship the dataset in `Hello` (remote workers); the in-process
    /// cluster passes `false` and workers borrow the leader's copy.
    pub send_dataset: bool,
}

impl Leader<'_> {
    /// Shape checks shared by the [`Leader::run`] / [`Leader::serve`]
    /// entry points.
    fn check_shapes(&self, x0: &[f32]) -> Result<()> {
        let cfg = self.cfg;
        cfg.validate()?;
        let n = cfg.n_devices;
        ensure!(self.ds.n() == n, "dataset has {} subsets, config {n}", self.ds.n());
        ensure!(self.ds.dim() == cfg.dim, "dataset dim {} != config {}", self.ds.dim(), cfg.dim);
        ensure!(x0.len() == cfg.dim, "x0 dim {} != config {}", x0.len(), cfg.dim);
        Ok(())
    }

    /// Receive and validate one `Join` (honoring the join deadline);
    /// returns the claimed device id and the bytes read. The recv timeout
    /// is cleared again before the link joins the training loop, whose
    /// reader threads must block indefinitely.
    fn recv_join(&self, link: &mut Box<dyn Transport>, digest: u64) -> Result<(usize, u64)> {
        let n = self.cfg.n_devices;
        if let Some(d) = self.opts.join_deadline {
            link.set_recv_timeout(Some(d))?;
        }
        let (msg, nb) = link.recv().context("waiting for a worker join")?;
        if self.opts.join_deadline.is_some() {
            link.set_recv_timeout(None)?;
        }
        let (version, device, worker_digest) = match msg {
            Msg::Join { version, device, digest } => (version, device, digest),
            other => bail!("expected join, got {other:?} from {}", link.peer()),
        };
        ensure!(
            version == WIRE_VERSION,
            "protocol version mismatch: worker {version}, leader {WIRE_VERSION}"
        );
        let device = device as usize;
        ensure!(device < n, "worker joined as device {device}, config has {n}");
        ensure!(
            worker_digest == 0 || worker_digest == digest,
            "config digest mismatch: worker {device} has {worker_digest:#018x}, \
             leader {digest:#018x}"
        );
        Ok((device, nb))
    }

    /// Send the `Hello` that completes one device's handshake; returns
    /// bytes written.
    fn send_hello(
        &self,
        link: &mut Box<dyn Transport>,
        device: usize,
        digest: u64,
        comp_seed: u64,
    ) -> Result<u64> {
        let cfg = self.cfg;
        let hello = Msg::Hello {
            version: WIRE_VERSION,
            device: device as u32,
            n_devices: cfg.n_devices as u32,
            dim: cfg.dim as u32,
            byzantine: device >= cfg.n_honest,
            device_compression: self.opts.device_compression,
            comp_seed,
            digest,
            compression: cfg.compression,
            dataset: if self.send_dataset {
                Some(DatasetBlock::from_dataset(self.ds))
            } else {
                None
            },
        };
        link.send(&hello)
    }

    /// Handshake every pre-established connection, then run `cfg.iters`
    /// iterations of Algorithm 1/2 and return the metric trace (final
    /// iterate in `x0`). A handshake failure — including a join-deadline
    /// expiry — is an error here, since the fixed link set leaves no way
    /// to refill the slot; use [`Leader::serve`] to own the accept loop
    /// and reclaim slots instead.
    pub fn run(
        &self,
        links: Vec<Box<dyn Transport>>,
        x0: &mut Vec<f32>,
        label: &str,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        let cfg = self.cfg;
        self.check_shapes(x0)?;
        let n = cfg.n_devices;
        ensure!(links.len() == n, "need {n} connections, got {}", links.len());
        let digest = config_digest(cfg);
        // Same pre-split per-device compression streams as Trainer::run —
        // the seeds go to honest devices in Hello (device-side mode), the
        // leader keeps the streams for everything it compresses itself.
        let comp_seeds = rng.split_seeds(n);
        let mut wire_up = 0u64;
        let mut wire_down = 0u64;

        // ---- handshake: Join in, Hello out, order links by device id ----
        let mut by_dev: Vec<Option<Box<dyn Transport>>> = (0..n).map(|_| None).collect();
        for mut link in links {
            let (device, nb) = self.recv_join(&mut link, digest)?;
            wire_up += nb;
            ensure!(by_dev[device].is_none(), "device {device} joined twice");
            wire_down += self.send_hello(&mut link, device, digest, comp_seeds[device])?;
            by_dev[device] = Some(link);
        }
        self.train(by_dev, &comp_seeds, wire_up, wire_down, x0, label, rng)
    }

    /// [`Leader::run`], but owning the accept loop: keep accepting
    /// connections until all `n` device slots hold a handshaked worker.
    /// A connection that fails its handshake — never sends a `Join`
    /// within [`LeaderOpts::join_deadline`], sends garbage, or claims an
    /// occupied slot — is dropped and its slot stays open for the next
    /// connection, so a stray or hostile connector cannot permanently
    /// occupy one of the N slots.
    pub fn serve(
        &self,
        listener: &super::transport::NetListener,
        x0: &mut Vec<f32>,
        label: &str,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        let cfg = self.cfg;
        self.check_shapes(x0)?;
        let n = cfg.n_devices;
        let digest = config_digest(cfg);
        let comp_seeds = rng.split_seeds(n);
        let mut wire_up = 0u64;
        let mut wire_down = 0u64;
        let mut by_dev: Vec<Option<Box<dyn Transport>>> = (0..n).map(|_| None).collect();
        let mut filled = 0usize;
        while filled < n {
            let mut link = listener.accept()?;
            let peer = link.peer();
            match self.recv_join(&mut link, digest) {
                Ok((device, join_bytes)) => {
                    if by_dev[device].is_some() {
                        eprintln!(
                            "leader: dropping duplicate join for device {device} from {peer}"
                        );
                        continue;
                    }
                    match self.send_hello(&mut link, device, digest, comp_seeds[device]) {
                        Ok(nb) => {
                            // count handshake bytes only for admitted
                            // devices — rejected connections are not part
                            // of the run the trace measures
                            wire_up += join_bytes;
                            wire_down += nb;
                            by_dev[device] = Some(link);
                            filled += 1;
                            eprintln!("leader: [{filled}/{n}] device {device} joined ({peer})");
                        }
                        Err(e) => {
                            eprintln!("leader: dropping device {device} ({peer}): {e:#}")
                        }
                    }
                }
                Err(e) => {
                    eprintln!("leader: dropping connection from {peer}: {e:#} — slot reclaimed")
                }
            }
        }
        self.train(by_dev, &comp_seeds, wire_up, wire_down, x0, label, rng)
    }

    /// The training loop proper, over a fully handshaked device set.
    fn train(
        &self,
        by_dev: Vec<Option<Box<dyn Transport>>>,
        comp_seeds: &[u64],
        mut wire_up: u64,
        mut wire_down: u64,
        x0: &mut Vec<f32>,
        label: &str,
        rng: &mut Rng,
    ) -> Result<TrainTrace> {
        let cfg = self.cfg;
        let n = cfg.n_devices;
        let timer = Timer::start();
        let mut comp_rngs: Vec<Rng> = comp_seeds.iter().map(|&s| Rng::new(s)).collect();
        // EF residual mirror (Some only for ef-* kinds): leader-side
        // compression steps every row; device-side compression steps only
        // the Byzantine rows (honest workers hold their own). Rows are
        // zeroed on retirement — see the module docs.
        let mut ef = EfState::for_kind(cfg.compression, n, cfg.dim);

        // ---- split: sends stay here, one detached reader per device ----
        // Readers forward (device, Some((msg, bytes))) into a single
        // queue — the gather deadline is then one recv_timeout on that
        // queue, so a stalled connection never blocks the others — and a
        // final (device, None) when their connection dies (EOF, reset, or
        // a corrupt frame), so the leader fails fast (or, in deadline
        // mode, drops the device) instead of waiting on a reader that
        // silently exited.
        type RxEvent = (usize, Option<(Msg, u64)>);
        let (fwd_tx, fwd_rx) = mpsc::channel::<RxEvent>();
        let mut txs: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        for (dev, link) in by_dev.into_iter().enumerate() {
            let (mut tx_half, mut rx_half) = link.expect("handshake fills every slot").split()?;
            if let Some(d) = self.opts.gather_deadline {
                // crash tolerance must also cover a worker that stops
                // draining its socket: bound blocking broadcast writes so
                // the send fails (and the device is retired) instead of
                // wedging the leader in write_all
                tx_half.set_send_timeout(Some(d))?;
            }
            txs.push(tx_half);
            let fwd = fwd_tx.clone();
            std::thread::Builder::new()
                .name(format!("lad-net-rx-{dev}"))
                .spawn(move || loop {
                    match rx_half.recv() {
                        Ok(item) => {
                            if fwd.send((dev, Some(item))).is_err() {
                                return;
                            }
                        }
                        Err(_) => {
                            let _ = fwd.send((dev, None));
                            return;
                        }
                    }
                })
                .context("spawning reader thread")?;
        }
        drop(fwd_tx);

        // ---- training loop ----
        let mut trace = TrainTrace::new(label);
        let s_hat = TaskMatrix::cyclic(n, cfg.d);
        let mut bits_total = 0u64;
        let mut dead = vec![false; n];
        let mut miss_streak = vec![0usize; n];
        let pipeline = self.opts.pipeline;
        // contiguous uplink slab: device i's reconstruction decodes straight
        // into row i, so attack crafting / compression / aggregation all
        // read out of one allocation reused across iterations
        let mut slab = vec![0.0f32; n * cfg.dim];
        // double-buffer staging (pipeline mode): iteration t+1's assignment
        // and pre-encoded per-device subset tails, drawn after craft(t)
        let mut staged: Option<(Assignment, Vec<Vec<u8>>)> = None;
        let encode_tails = |assign: &Assignment| -> Vec<Vec<u8>> {
            (0..n)
                .map(|i| {
                    let subsets: Vec<u32> = assign
                        .subsets_for(s_hat.row(assign.tasks[i]))
                        .map(|k| k as u32)
                        .collect();
                    broadcast_tail(&subsets)
                })
                .collect()
        };

        for t in 0..cfg.iters {
            let t_bcast = Instant::now();
            let (assign, tails) = match staged.take() {
                Some(s) => s,
                None => {
                    let a = Assignment::draw(n, rng);
                    let tails = if pipeline { encode_tails(&a) } else { Vec::new() };
                    (a, tails)
                }
            };
            let mut expecting = vec![false; n];
            if pipeline {
                // shared x-frame: the Q-sized iterate section is encoded
                // exactly once per iteration; each device's frame splices
                // its pre-encoded subset tail on, and both the splice and
                // the socket write fan out on the pool. Results come back
                // in device order, so retirement semantics match the
                // phase-serial loop below.
                let prefix = broadcast_prefix(t as u32, x0);
                let sends: Vec<Option<Result<u64>>> = self.pool.par_map_mut(&mut txs, |i, tx| {
                    if dead[i] {
                        return None;
                    }
                    let frame = encode_frame_parts(&[prefix.as_slice(), tails[i].as_slice()]);
                    Some(tx.send_frame(&frame))
                });
                for (i, res) in sends.into_iter().enumerate() {
                    match res {
                        None => {}
                        Some(Ok(nb)) => {
                            wire_down += nb;
                            expecting[i] = true;
                        }
                        Some(Err(e)) => {
                            if self.opts.gather_deadline.is_some() {
                                // crash-Byzantine: drop the device, keep going
                                dead[i] = true;
                                if let Some(st) = ef.as_mut() {
                                    st.reset(i);
                                }
                                trace.anomalies += 1;
                            } else {
                                return Err(e).context(format!("broadcast to device {i}"));
                            }
                        }
                    }
                }
            } else {
                for i in 0..n {
                    if dead[i] {
                        continue;
                    }
                    let subsets: Vec<u32> = assign
                        .subsets_for(s_hat.row(assign.tasks[i]))
                        .map(|k| k as u32)
                        .collect();
                    let msg = Msg::Broadcast { iter: t as u32, x: x0.clone(), subsets };
                    match txs[i].send(&msg) {
                        Ok(nb) => {
                            wire_down += nb;
                            expecting[i] = true;
                        }
                        Err(e) => {
                            if self.opts.gather_deadline.is_some() {
                                // crash-Byzantine: drop the device, keep going
                                dead[i] = true;
                                if let Some(st) = ef.as_mut() {
                                    st.reset(i);
                                }
                                trace.anomalies += 1;
                            } else {
                                return Err(e).context(format!("broadcast to device {i}"));
                            }
                        }
                    }
                }
            }
            trace.broadcast_ns += t_bcast.elapsed().as_nanos() as u64;
            let mut want = expecting.iter().filter(|&&b| b).count();
            ensure!(want > 0, "iteration {t}: no live workers left");

            // gather until complete or the deadline expires; uploads decode
            // straight into their device's slab row, `have[dev]` records the
            // analytic bit count of a landed upload
            let t_gather = Instant::now();
            let mut have: Vec<Option<u64>> = (0..n).map(|_| None).collect();
            let deadline = self.opts.gather_deadline.map(|d| Instant::now() + d);
            while want > 0 {
                let item = match deadline {
                    None => match fwd_rx.recv() {
                        Ok(x) => x,
                        Err(_) => bail!("iteration {t}: all workers disconnected"),
                    },
                    Some(dl) => {
                        let now = Instant::now();
                        if now >= dl {
                            break;
                        }
                        match fwd_rx.recv_timeout(dl - now) {
                            Ok(x) => x,
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                bail!("iteration {t}: all workers disconnected")
                            }
                        }
                    }
                };
                let (dev, event) = item;
                let (msg, nb) = match event {
                    Some(x) => x,
                    None => {
                        // this device's connection died (EOF / corrupt frame)
                        if self.opts.gather_deadline.is_none() {
                            bail!(
                                "iteration {t}: device {dev} disconnected or sent a \
                                 corrupt frame"
                            );
                        }
                        drop_device(
                            dev,
                            &mut dead,
                            &mut expecting,
                            &have,
                            &mut want,
                            &mut trace,
                            ef.as_mut(),
                        );
                        continue;
                    }
                };
                wire_up += nb;
                match msg {
                    Msg::Upload { iter, device, analytic_bits, payload } => {
                        if iter as usize != t || device as usize != dev {
                            continue; // stale upload from a past deadline miss
                        }
                        if !expecting[dev] || have[dev].is_some() {
                            continue;
                        }
                        // dimension checked on the cheap accessor BEFORE
                        // reconstructing, so a hostile dim never touches the
                        // slab; decode_into fully overwrites the row, so a
                        // stale value from a past iteration can never leak
                        let row = &mut slab[dev * cfg.dim..(dev + 1) * cfg.dim];
                        if payload.dim() == cfg.dim && payload.decode_into(row).is_ok() {
                            have[dev] = Some(analytic_bits);
                            want -= 1;
                        } else {
                            if self.opts.gather_deadline.is_none() {
                                bail!(
                                    "device {dev} sent an invalid upload \
                                     (payload dim != {})",
                                    cfg.dim
                                );
                            }
                            drop_device(
                                dev,
                                &mut dead,
                                &mut expecting,
                                &have,
                                &mut want,
                                &mut trace,
                                ef.as_mut(),
                            );
                        }
                    }
                    other => {
                        // a protocol deviation from one worker must not
                        // kill the run when crash tolerance was asked for
                        if self.opts.gather_deadline.is_none() {
                            bail!("unexpected mid-run message from device {dev}: {other:?}");
                        }
                        drop_device(
                            dev,
                            &mut dead,
                            &mut expecting,
                            &have,
                            &mut want,
                            &mut trace,
                            ef.as_mut(),
                        );
                    }
                }
            }
            trace.anomalies += want; // devices that missed the deadline
            trace.gather_ns += t_gather.elapsed().as_nanos() as u64;
            // retire chronic stragglers so a permanently stalled worker
            // costs a bounded number of timeouts, not one per iteration
            for i in 0..n {
                if !expecting[i] {
                    continue;
                }
                if have[i].is_some() {
                    miss_streak[i] = 0;
                } else {
                    miss_streak[i] += 1;
                    if miss_streak[i] >= MISS_RETIRE_STREAK {
                        dead[i] = true;
                        // retirement zeroes the slot's residual; a mere
                        // deadline miss (above) leaves it untouched
                        if let Some(st) = ef.as_mut() {
                            st.reset(i);
                        }
                    }
                }
            }

            let present: Vec<usize> = (0..n).filter(|&i| have[i].is_some()).collect();
            ensure!(!present.is_empty(), "iteration {t}: no uploads before the deadline");
            let honest_ids: Vec<usize> =
                present.iter().copied().filter(|&i| i < cfg.n_honest).collect();
            let byz_ids: Vec<usize> =
                present.iter().copied().filter(|&i| i >= cfg.n_honest).collect();

            // Fixed identities (last N−H Byzantine, as Trainer defaults):
            // view the uploads as slab rows, craft the lies, compress what
            // is still uncompressed, and stitch back into device order
            // (honest ids all precede Byzantine ids, so concatenation IS
            // device order).
            let t_agg = Instant::now();
            let row = |i: usize| -> &[f32] { &slab[i * cfg.dim..(i + 1) * cfg.dim] };
            let msgs: Vec<Vec<f32>> = if self.opts.device_compression {
                let honest_rec: Vec<&[f32]> = honest_ids.iter().map(|&i| row(i)).collect();
                for &i in &honest_ids {
                    bits_total += have[i].expect("present");
                }
                let byz_true: Vec<&[f32]> = byz_ids.iter().map(|&i| row(i)).collect();
                let lies = if byz_true.is_empty() {
                    Vec::new()
                } else {
                    let mut ctx =
                        AttackContext { honest: &honest_rec, own_true: &byz_true, rng };
                    self.attack.craft(&mut ctx)
                };
                // the emulated Byzantine uplinks are compressed with their
                // own device streams, exactly as the central path does —
                // under EF, with their own residual rows too (honest rows
                // live on the workers in this mode)
                let mut out: Vec<Vec<f32>> =
                    honest_rec.iter().map(|r| r.to_vec()).collect();
                if let Some(st) = ef.as_mut() {
                    for (j, &i) in byz_ids.iter().enumerate() {
                        let c = st.step(i, &lies[j], self.comp, &mut comp_rngs[i]);
                        bits_total += c.bits as u64;
                        out.push(c.vec);
                    }
                } else if byz_ids.iter().copied().eq(cfg.n_honest..n) {
                    let refs: Vec<&[f32]> = lies.iter().map(|l| l.as_slice()).collect();
                    let (rec, bits) = compress_batch(
                        self.comp,
                        &refs,
                        &mut comp_rngs[cfg.n_honest..],
                        &self.pool,
                    );
                    bits_total += bits;
                    out.extend(rec);
                } else {
                    for (j, &i) in byz_ids.iter().enumerate() {
                        let c = self.comp.compress(&lies[j], &mut comp_rngs[i]);
                        bits_total += c.bits as u64;
                        out.push(c.vec);
                    }
                }
                out
            } else {
                let honest_true: Vec<&[f32]> = honest_ids.iter().map(|&i| row(i)).collect();
                let byz_true: Vec<&[f32]> = byz_ids.iter().map(|&i| row(i)).collect();
                let lies = if byz_true.is_empty() {
                    Vec::new()
                } else {
                    let mut ctx =
                        AttackContext { honest: &honest_true, own_true: &byz_true, rng };
                    self.attack.craft(&mut ctx)
                };
                if present.len() == n {
                    // full gather: the exact leader-side compression batch
                    // of the historical cluster path (and the fast trainer)
                    // — every honest ref still points into the slab, so the
                    // batch reads one contiguous allocation
                    let all: Vec<&[f32]> = honest_true
                        .iter()
                        .copied()
                        .chain(lies.iter().map(|m| m.as_slice()))
                        .collect();
                    let (msgs, bits) = match ef.as_mut() {
                        Some(st) => {
                            compress_batch_ef(self.comp, st, &all, &mut comp_rngs, &self.pool)
                        }
                        None => compress_batch(self.comp, &all, &mut comp_rngs, &self.pool),
                    };
                    bits_total += bits;
                    msgs
                } else {
                    // partial gather: per-device compression consumes only
                    // the present devices' streams (and EF residual rows) —
                    // an absent device's stream and residual stay untouched
                    let mut out = Vec::with_capacity(present.len());
                    for (j, &i) in honest_ids.iter().enumerate() {
                        let c = match ef.as_mut() {
                            Some(st) => st.step(i, honest_true[j], self.comp, &mut comp_rngs[i]),
                            None => self.comp.compress(honest_true[j], &mut comp_rngs[i]),
                        };
                        bits_total += c.bits as u64;
                        out.push(c.vec);
                    }
                    for (j, &i) in byz_ids.iter().enumerate() {
                        let c = match ef.as_mut() {
                            Some(st) => st.step(i, &lies[j], self.comp, &mut comp_rngs[i]),
                            None => self.comp.compress(&lies[j], &mut comp_rngs[i]),
                        };
                        bits_total += c.bits as u64;
                        out.push(c.vec);
                    }
                    out
                }
            };

            // double-buffer: draw iteration t+1's assignment and pre-encode
            // its subset tails while this iteration still has aggregation
            // ahead of it. The draw sits AFTER this iteration's attack
            // craft, so the run RNG sees draw(0), craft(0), draw(1), … —
            // exactly the phase-serial order (pinned by fuzz_determinism).
            if pipeline && t + 1 < cfg.iters {
                let a = Assignment::draw(n, rng);
                let tails = encode_tails(&a);
                staged = Some((a, tails));
            }

            let update = self.agg.aggregate(&msgs);
            for (xi, ui) in x0.iter_mut().zip(&update) {
                *xi -= cfg.lr as f32 * ui;
            }
            trace.aggregate_ns += t_agg.elapsed().as_nanos() as u64;
            if (cfg.log_every > 0 && t % cfg.log_every == 0) || t + 1 == cfg.iters {
                trace.record(t, self.ds.loss(x0), norm(&update), bits_total);
            }
        }

        for tx in txs.iter_mut() {
            if let Ok(nb) = tx.send(&Msg::Shutdown) {
                wire_down += nb;
            }
        }
        trace.final_loss = self.ds.loss(x0);
        trace.wall_s = timer.elapsed_s();
        trace.wire_up_bytes = wire_up;
        trace.wire_down_bytes = wire_down;
        Ok(trace)
    }
}
