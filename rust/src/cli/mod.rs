//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `lad <subcommand> [--key value | --key=value | --flag] ...`.
//! Typed accessors with defaults; unknown options are an error so typos
//! fail loudly.

use crate::Result;
use anyhow::{bail, Context};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Options that were read at least once (for unknown-option detection).
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().insert(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(name.to_string());
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} must be an integer, got {s:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} must be an integer, got {s:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} must be a number, got {s:?}")),
        }
    }

    /// Error out if any provided --option/--flag was never consumed.
    pub fn reject_unknown(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.options.keys() {
            if !consumed.contains(k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !consumed.contains(f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["fig4", "--iters", "500", "--lr=1e-6", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("fig4"));
        assert_eq!(a.get_usize("iters", 0).unwrap(), 500);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 1e-6);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn defaults_when_missing() {
        let a = parse(&["train"]);
        assert_eq!(a.get_usize("iters", 7).unwrap(), 7);
        assert_eq!(a.get_str("agg", "cwtm"), "cwtm");
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse(&["x", "--oops", "1"]);
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["x", "--iters", "abc"]);
        assert!(a.get_usize("iters", 0).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse(&["run", "one", "two", "--k", "v"]);
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--check"]);
        assert!(a.has_flag("fast"));
        assert!(a.has_flag("check"));
    }
}
